# One entry point for the builder, CI, and future PRs.
#
#   make test         - tier-1 verify (ROADMAP.md)
#   make test-tier1   - same suite, fail-fast off (the target CI calls);
#                       kernel parity (tests/test_kernels.py, incl. the fused
#                       intersect+support sweeps) runs first for fast signal
#   make bench-smoke  - paper-figure benchmark at tiny scale (sanity, not numbers)
#   make bench-json   - emit the BENCH_PR3.json perf trajectory (kernel micro-
#                       bench + warm-engine miner timings) for future PRs to diff
#   make mine-smoke   - every CLI-selectable miner on a small synth dataset

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-tier1 bench-smoke bench-json mine-smoke

test:
	$(PY) -m pytest -x -q

test-tier1:
	$(PY) -m pytest -q tests/test_kernels.py
	$(PY) -m pytest -q --ignore=tests/test_kernels.py

bench-smoke:
	$(PY) -c "from benchmarks.bench_paper import run; run(quick=True)"

bench-json:
	$(PY) -c "from benchmarks.run import emit_json; print(emit_json())"

mine-smoke:
	for a in hprepost prepost fpgrowth apriori; do \
		$(PY) -m repro.launch.mine --algo $$a --dataset mushroom --scale 0.05 --min-sup 0.3 --top 3 || exit 1; \
	done
