# One entry point for the builder, CI, and future PRs.
#
#   make test         - tier-1 verify (ROADMAP.md)
#   make test-tier1   - same suite, fail-fast off (the target CI calls);
#                       kernel parity (tests/test_kernels.py, incl. the fused
#                       intersect+support sweeps) runs first for fast signal
#   make bench-smoke  - paper-figure benchmark at tiny scale (sanity, not numbers)
#   make bench-json   - emit the BENCH_PR6.json perf trajectory (kernel micro-
#                       bench + service overlap/warm-start rows + streaming
#                       append/query/compaction rows + distributed 1/2/4-worker
#                       scale-out rows) for future PRs to diff; earlier
#                       trajectories (BENCH_PR3/4/5.json) stay put
#   make mine-smoke   - every CLI-selectable miner on a small synth dataset
#   make serve-smoke  - MiningService end-to-end: concurrent submits incl. a
#                       sweep + a host-algorithm request, drain, then a second
#                       process that must warm-start from the snapshot store
#                       with zero prep stages
#   make stream-smoke - streaming ingestion end-to-end: append 3 batches in
#                       one process (each preps only its own segment), then a
#                       second process replays the append log and must
#                       warm-start every segment from the snapshot dir with
#                       zero prep stages
#   make dist-smoke   - distributed mining end-to-end: 2 spawned worker
#                       processes behind the coordinator, stream 3 batches,
#                       sweep, hard-kill one worker, re-mine — fails unless
#                       the answers are bit-identical and the re-assigned
#                       segments restored from snapshots without a rebuild
#   make window-smoke - continuous mining end-to-end: append 5 batches into a
#                       2-batch sliding window with a standing query watching
#                       (per-append expiry + MineDiff delivery), verifying the
#                       windowed answer bit-identical to a one-shot over the
#                       window's rows and the diff stream replaying from empty
#                       to the live answer; a second process repeats the run
#                       and must warm-start every segment from the snapshot
#                       dir with zero prep stages
#   make chaos-smoke  - hardened-service soak: a fixed-seed ChaosInjector over
#                       every service failure point (enqueue/prep/serve/wave/
#                       snapshot read), an overload flood against a tiny
#                       admission queue, and a continuous-mining round with
#                       chaos on the expiry/diff points — fails unless every
#                       accepted Future resolves (result or typed error),
#                       successes are bit-identical to a clean run,
#                       backpressure is immediate typed Overloaded, and every
#                       delivered diff chain replays exactly
#   make obs-smoke    - observability end-to-end: a short serve with the
#                       periodic stats emitter (JSON-lines every 0.2s) and
#                       the request tracer attached — fails unless >=2
#                       periodic snapshots landed during the run, the trace
#                       file is a valid Chrome trace-event list, and the
#                       queue-wait / prep / mine latency histograms in
#                       stats()["histograms"] are populated with quantiles
#   make tune-smoke   - kernel autotuner end-to-end: a cold process runs the
#                       timed block search and persists kernel_plans.json
#                       next to the snapshot dir; a second process must serve
#                       every plan from disk with zero search trials
#   make bench-gate   - regression gate: diff the current BENCH_PR*.json
#                       against the previous PR's trajectory and fail if a
#                       tracked row slowed past tolerance

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

SERVE_SNAP := .serve-smoke-snapshots
STREAM_SNAP := .stream-smoke-snapshots
DIST_SNAP := .dist-smoke-snapshots
TUNE_SNAP := .tune-smoke-snapshots
WINDOW_SNAP := .window-smoke-snapshots
OBS_OUT := .obs-smoke-out

.PHONY: test test-tier1 bench-smoke bench-json bench-gate mine-smoke serve-smoke stream-smoke dist-smoke tune-smoke window-smoke chaos-smoke obs-smoke

test:
	$(PY) -m pytest -x -q

test-tier1:
	$(PY) -m pytest -q tests/test_kernels.py
	$(PY) -m pytest -q --ignore=tests/test_kernels.py

bench-smoke:
	$(PY) -c "from benchmarks.bench_paper import run; run(quick=True)"

bench-json:
	$(PY) -c "from benchmarks.run import emit_json; print(emit_json())"

mine-smoke:
	for a in hprepost prepost fpgrowth apriori; do \
		$(PY) -m repro.launch.mine --algo $$a --dataset mushroom --scale 0.05 --min-sup 0.3 --top 3 || exit 1; \
	done

serve-smoke:
	rm -rf $(SERVE_SNAP)
	$(PY) -m repro.launch.mine --serve --snapshot-dir $(SERVE_SNAP) \
		--dataset mushroom --scale 0.05 --sweep 0.4,0.3,0.2 --max-k 4
	$(PY) -m repro.launch.mine --serve --snapshot-dir $(SERVE_SNAP) \
		--dataset mushroom --scale 0.05 --sweep 0.4,0.3,0.2 --max-k 4 --expect-warm
	rm -rf $(SERVE_SNAP)

stream-smoke:
	rm -rf $(STREAM_SNAP)
	$(PY) -m repro.launch.mine --append 3 --snapshot-dir $(STREAM_SNAP) \
		--dataset mushroom --scale 0.05 --sweep 0.4,0.3 --max-k 4
	$(PY) -m repro.launch.mine --append 3 --snapshot-dir $(STREAM_SNAP) \
		--dataset mushroom --scale 0.05 --sweep 0.4,0.3 --max-k 4 --expect-warm
	rm -rf $(STREAM_SNAP)

dist-smoke:
	rm -rf $(DIST_SNAP)
	$(PY) -m repro.launch.mine --append 3 --workers 2 --kill-worker \
		--snapshot-dir $(DIST_SNAP) \
		--dataset mushroom --scale 0.05 --sweep 0.4,0.3 --max-k 4
	rm -rf $(DIST_SNAP)

tune-smoke:
	rm -rf $(TUNE_SNAP)
	$(PY) -m repro.launch.mine --tune --snapshot-dir $(TUNE_SNAP) \
		--dataset mushroom --scale 0.05 --min-sup 0.3 --max-k 4 --expect-plans cold
	$(PY) -m repro.launch.mine --tune --snapshot-dir $(TUNE_SNAP) \
		--dataset mushroom --scale 0.05 --min-sup 0.3 --max-k 4 --expect-plans warm
	rm -rf $(TUNE_SNAP)

window-smoke:
	rm -rf $(WINDOW_SNAP)
	$(PY) -m repro.launch.mine --append 5 --window 2 --watch \
		--snapshot-dir $(WINDOW_SNAP) \
		--dataset mushroom --scale 0.05 --min-sup 0.3 --max-k 4
	$(PY) -m repro.launch.mine --append 5 --window 2 --watch \
		--snapshot-dir $(WINDOW_SNAP) \
		--dataset mushroom --scale 0.05 --min-sup 0.3 --max-k 4 --expect-warm
	rm -rf $(WINDOW_SNAP)

chaos-smoke:
	$(PY) -m benchmarks.chaos_soak

obs-smoke:
	rm -rf $(OBS_OUT)
	$(PY) -m repro.launch.mine --serve \
		--dataset mushroom --scale 0.05 --sweep 0.4,0.3,0.2 --max-k 4 \
		--stats-interval 0.2 --stats-out $(OBS_OUT)/stats.jsonl \
		--trace $(OBS_OUT)/trace.json --expect-obs
	rm -rf $(OBS_OUT)

bench-gate:
	$(PY) -m benchmarks.bench_gate
