# One entry point for the builder, CI, and future PRs.
#
#   make test         - tier-1 verify (ROADMAP.md)
#   make test-tier1   - same suite, fail-fast off (the target CI calls)
#   make bench-smoke  - paper-figure benchmark at tiny scale (sanity, not numbers)
#   make mine-smoke   - every CLI-selectable miner on a small synth dataset

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-tier1 bench-smoke mine-smoke

test:
	$(PY) -m pytest -x -q

test-tier1:
	$(PY) -m pytest -q

bench-smoke:
	$(PY) -c "from benchmarks.bench_paper import run; run(quick=True)"

mine-smoke:
	for a in hprepost prepost fpgrowth apriori; do \
		$(PY) -m repro.launch.mine --algo $$a --dataset mushroom --scale 0.05 --min-sup 0.3 --top 3 || exit 1; \
	done
