"""Paper Figs 3-10: runtime + memory vs min-sup on the four datasets.

One run produces both tables (runtime figures 3-6, memory figures 7-10):
HPrepost (vectorized JAX, this paper) vs PrePost (host N-list baseline) vs
FP-growth (pointer baseline), all through the unified ``repro.mining``
front-door on one ``MiningEngine`` — so the HPrepost timings are jit-warm
across the threshold sweep, exactly like repeated production traffic.
Datasets are offline FIMI surrogates matched on Table-3 characteristics
(see repro/data/synth.py).
"""
from __future__ import annotations

import json

# dataset -> min-sup fractions (paper sweeps; bounded so CPU finishes)
SWEEPS = {
    "chess": [0.9, 0.8, 0.7, 0.6],
    "mushroom": [0.4, 0.3, 0.2, 0.15],
    "pumsb": [0.45, 0.35, 0.3],
    "kosarak": [0.05, 0.02, 0.01],
}
SCALES = {"chess": 1.0, "mushroom": 1.0, "pumsb": 0.1, "kosarak": 0.05}
ALGOS = ("hprepost", "prepost", "fpgrowth")


def run(out_path: str | None = None, quick: bool = False) -> list[dict]:
    from repro.data.synth import load
    from repro.mining import MineSpec, MiningEngine

    engine = MiningEngine()
    rows_out = []
    sweeps = {k: v[:2] for k, v in SWEEPS.items()} if quick else SWEEPS
    for name, sweeps_v in sweeps.items():
        rows, n_items = load(name, scale=SCALES[name] * (0.3 if quick else 1.0))
        for frac in sweeps_v:
            spec = MineSpec(min_sup=frac, max_k=5)
            rec = {"dataset": name, "min_sup": frac, "rows": len(rows),
                   "min_count": spec.resolve(len(rows))}

            results = {}
            for algo in ALGOS:
                res = engine.submit(rows, n_items, spec.with_(algorithm=algo))
                results[algo] = res
                rec[f"{algo}_s"] = res.wall_time_s
                rec[f"{algo}_bytes"] = res.peak_bytes

            rec["n_itemsets"] = results["hprepost"].total_count
            ref = results["prepost"].itemsets
            for algo in ALGOS:
                assert results[algo].itemsets == ref, (name, frac, algo)

            rows_out.append(rec)
            print(
                f"{name} sup={frac:.2f} n={rec['n_itemsets']}: "
                + " | ".join(f"{a} {rec[f'{a}_s']:.2f}s" for a in ALGOS)
            )
    if out_path:
        with open(out_path, "w") as f:
            json.dump(rows_out, f, indent=1)
    return rows_out
