"""Paper Figs 3-10: runtime + memory vs min-sup on the four datasets.

One run produces both tables (runtime figures 3-6, memory figures 7-10):
HPrepost (vectorized JAX, this paper) vs PrePost (host N-list baseline) vs
FP-growth (pointer baseline). Datasets are offline FIMI surrogates matched
on Table-3 characteristics (see repro/data/synth.py).
"""
from __future__ import annotations

import json
import time

import numpy as np

# dataset -> min-sup fractions (paper sweeps; bounded so CPU finishes)
SWEEPS = {
    "chess": [0.9, 0.8, 0.7, 0.6],
    "mushroom": [0.4, 0.3, 0.2, 0.15],
    "pumsb": [0.45, 0.35, 0.3],
    "kosarak": [0.05, 0.02, 0.01],
}
SCALES = {"chess": 1.0, "mushroom": 1.0, "pumsb": 0.1, "kosarak": 0.05}


def run(out_path: str | None = None, quick: bool = False) -> list[dict]:
    import jax
    from jax.sharding import AxisType

    from repro.core.fpgrowth import mine_fpgrowth
    from repro.core.hprepost import HPrepostConfig, HPrepostMiner
    from repro.core.prepost import mine_prepost
    from repro.data.synth import FIMI_SURROGATES, load

    mesh = jax.make_mesh((1, 1), ("data", "model"), axis_types=(AxisType.Auto,) * 2)
    rows_out = []
    sweeps = {k: v[:2] for k, v in SWEEPS.items()} if quick else SWEEPS
    for name, sweeps_v in sweeps.items():
        rows, n_items = load(name, scale=SCALES[name] * (0.3 if quick else 1.0))
        R = len(rows)
        for frac in sweeps_v:
            min_count = max(1, int(frac * R))
            rec = {"dataset": name, "min_sup": frac, "rows": R, "min_count": min_count}

            miner = HPrepostMiner(mesh, config=HPrepostConfig(max_k=5))
            t0 = time.perf_counter()
            res_h = miner.mine(rows, n_items, min_count)
            rec["hprepost_s"] = time.perf_counter() - t0
            rec["hprepost_bytes"] = res_h.peak_bytes
            rec["n_itemsets"] = res_h.total_count

            t0 = time.perf_counter()
            res_p = mine_prepost(rows, n_items, min_count, max_k=5)
            rec["prepost_s"] = time.perf_counter() - t0
            rec["prepost_bytes"] = res_p.peak_bytes
            assert res_p.itemsets == res_h.itemsets, (name, frac)

            t0 = time.perf_counter()
            res_f, stats = mine_fpgrowth(rows, n_items, min_count)
            rec["fpgrowth_s"] = time.perf_counter() - t0
            rec["fpgrowth_bytes"] = stats["peak_bytes"]
            # fp-growth has no max_k; compare on the overlap
            short = {k: v for k, v in res_f.items() if len(k) <= 5}
            assert short == res_p.itemsets, (name, frac)

            rows_out.append(rec)
            print(
                f"{name} sup={frac:.2f} n={rec['n_itemsets']}: "
                f"hprepost {rec['hprepost_s']:.2f}s | prepost {rec['prepost_s']:.2f}s | "
                f"fpgrowth {rec['fpgrowth_s']:.2f}s"
            )
    if out_path:
        with open(out_path, "w") as f:
            json.dump(rows_out, f, indent=1)
    return rows_out
