"""Paper Figs 3-10: runtime + memory vs min-sup on the four datasets.

One run produces both tables (runtime figures 3-6, memory figures 7-10):
HPrepost (vectorized JAX, this paper) vs PrePost (host N-list baseline) vs
FP-growth (pointer baseline), all through the unified ``repro.mining``
front-door on one ``MiningEngine``. Each dataset's threshold sweep — the
paper's x-axis — goes through ``engine.sweep``, so the HPrepost side takes
the planned shared-prep path (Job 1 / Job 2 / pack / F2 once at the
loosest threshold, every threshold served from the shared PreparedDB) with
jit-warm waves, exactly like repeated production traffic. Per-threshold
wall times for the shared-prep consumers exclude the prep they did not
re-run; the first threshold carries the prep cost (``prep_shared`` flags
the distinction, stage times attribute it honestly).
Datasets are offline FIMI surrogates matched on Table-3 characteristics
(see repro/data/synth.py).
"""
from __future__ import annotations

import json

# dataset -> min-sup fractions (paper sweeps; bounded so CPU finishes)
SWEEPS = {
    "chess": [0.9, 0.8, 0.7, 0.6],
    "mushroom": [0.4, 0.3, 0.2, 0.15],
    "pumsb": [0.45, 0.35, 0.3],
    "kosarak": [0.05, 0.02, 0.01],
}
SCALES = {"chess": 1.0, "mushroom": 1.0, "pumsb": 0.1, "kosarak": 0.05}
ALGOS = ("hprepost", "prepost", "fpgrowth")


def run(out_path: str | None = None, quick: bool = False) -> list[dict]:
    from repro.data.synth import load
    from repro.mining import MineSpec, MiningEngine

    engine = MiningEngine()
    rows_out = []
    sweeps = {k: v[:2] for k, v in SWEEPS.items()} if quick else SWEEPS
    for name, fracs in sweeps.items():
        rows, n_items = load(name, scale=SCALES[name] * (0.3 if quick else 1.0))
        spec = MineSpec(min_sup=min(fracs), max_k=5)

        # one planned sweep per algorithm over the whole x-axis
        results = {
            algo: engine.sweep(rows, n_items, spec.with_(algorithm=algo), fracs)
            for algo in ALGOS
        }

        for i, frac in enumerate(fracs):
            rec = {"dataset": name, "min_sup": frac, "rows": len(rows),
                   "min_count": spec.with_(min_sup=frac).resolve(len(rows))}
            for algo in ALGOS:
                res = results[algo][i]
                rec[f"{algo}_s"] = res.wall_time_s
                rec[f"{algo}_bytes"] = res.peak_bytes
                rec[f"{algo}_prep_shared"] = res.prep_shared

            rec["n_itemsets"] = results["hprepost"][i].total_count
            ref = results["prepost"][i].itemsets
            for algo in ALGOS:
                assert results[algo][i].itemsets == ref, (name, frac, algo)

            rows_out.append(rec)
            print(
                f"{name} sup={frac:.2f} n={rec['n_itemsets']}: "
                + " | ".join(f"{a} {rec[f'{a}_s']:.2f}s" for a in ALGOS)
            )
    if out_path:
        with open(out_path, "w") as f:
            json.dump(rows_out, f, indent=1)
    return rows_out
