"""Scalability (paper §4/§6: partition the DB, mine per block): per-shard
work and memory vs number of MapReduce workers.

Runs HPrepost on 1/2/4/8 fake devices (subprocess per world size) and
reports: wall time, per-shard tree nodes (the reducer's memory), and the
psum'd support correctness — the paper's "HPrepost memory << PrePost
memory" claim is the per-shard tree column.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

_WORKER = textwrap.dedent(
    """
    import os, sys, json, time
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
    import numpy as np
    from repro.compat import make_mesh
    from repro.core import encoding as enc
    from repro.core.ppc import build_ppc
    from repro.data.synth import load
    from repro.mining import MineSpec, MiningEngine

    D = int(sys.argv[1])
    rows, n_items = load("kosarak", scale=0.03)
    engine = MiningEngine(make_mesh((D, 1), ("data", "model")))
    spec = MineSpec(min_sup=0.008, max_k=4)
    min_count = spec.resolve(len(rows))
    engine.submit(rows, n_items, spec)                  # cold (compile)
    res = engine.submit(rows, n_items, spec)            # warm
    warm = res.wall_time_s

    # per-shard tree size (reducer memory model)
    fl = enc.build_flist(enc.item_support(rows, n_items), min_count)
    ranked = enc.rank_encode(rows, fl)
    shard_nodes = []
    per = (len(ranked) + D - 1) // D
    for d in range(D):
        block = ranked[d * per : (d + 1) * per]
        urows, w = enc.dedup_rows(block)
        shard_nodes.append(build_ppc(urows, w).n_nodes if len(urows) else 0)
    print(json.dumps({
        "workers": D, "warm_s": warm, "n_itemsets": res.total_count,
        "max_shard_nodes": max(shard_nodes), "total_nodes_single": build_ppc(
            *enc.dedup_rows(ranked)).n_nodes,
    }))
    """
)


def run(out_path: str | None = None, worlds=(1, 2, 4, 8)) -> list[dict]:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    recs = []
    for d in worlds:
        out = subprocess.run(
            [sys.executable, "-c", _WORKER, str(d)],
            env=env, capture_output=True, text=True, timeout=560,
        )
        assert out.returncode == 0, out.stderr[-2000:]
        rec = json.loads(out.stdout.strip().splitlines()[-1])
        recs.append(rec)
        print(
            f"workers={d}: warm {rec['warm_s']:.2f}s | per-shard tree {rec['max_shard_nodes']} "
            f"nodes (single-node: {rec['total_nodes_single']}) | n={rec['n_itemsets']}"
        )
    if out_path:
        json.dump(recs, open(out_path, "w"), indent=1)
    return recs
