"""Streaming ingestion bench: the PR 5 acceptance numbers.

Three measurements per segment count (4 / 16 / 64; quick drops 64), all
through warm engines (compile cost paid by a warmup pass, never timed):

  append throughput     total wall to ingest the database batch-by-batch
                        through ``engine.append`` (each batch preps only
                        its own segment) vs the FULL-REBUILD baseline: the
                        pre-streaming stack re-prepares the whole
                        concatenated database every time a batch lands
                        (fingerprint changes -> LRU miss -> full Job 1/
                        Job 2/pack/F2), so the baseline pays prep over
                        sum_i(i * batch) rows while streaming pays it over
                        sum_i(batch) — the gap widens linearly with S.

  query latency         one threshold served from the live SegmentedDB
                        (global F-lists + per-segment waves) vs the same
                        threshold from a monolithic warm PreparedDB
                        (waves only) — the price of segmentation on the
                        read path, which compaction then claws back.

  compaction            wall cost of folding the segments down (fanin 8
                        passes to ~S/8), and the query latency after — it
                        must land back near the monolithic figure.
"""
from __future__ import annotations

import time

import numpy as np


def _pc() -> float:
    return time.perf_counter()


def run(quick: bool = False) -> list[tuple[str, float, str]]:
    from repro.data.synth import random_db
    from repro.mining import MineSpec, MiningEngine
    from repro.mining.stream import StreamSpec

    n_items, max_len = 24, 8
    n_tx = 1024 if quick else 4096
    seg_counts = (4, 16) if quick else (4, 16, 64)
    spec = MineSpec(algorithm="hprepost", min_sup=0.08, max_k=4, candidate_unit=64)
    rows = random_db(np.random.default_rng(0), n_tx, n_items, max_len)
    out: list[tuple[str, float, str]] = []

    # monolithic reference: warm one-shot prep + a served query (waves only)
    mono = MiningEngine()
    mono.submit(rows, n_items, spec)  # warmup: compile + cache the prep
    t0 = _pc()
    mono_res = mono.submit(rows, n_items, spec)
    t_mono_query = _pc() - t0
    out.append((
        "stream_query_monolithic", t_mono_query * 1e6,
        f"warm PreparedDB, n={len(mono_res.itemsets)}",
    ))

    for S in seg_counts:
        batches = np.array_split(rows, S)
        pad = max(len(b) for b in batches)

        # --- streaming appends (one segment of prep per batch)
        eng = MiningEngine()
        ss = StreamSpec(row_pad=pad, max_segments=4 * S)  # no auto-compaction
        eng.append(batches[0], n_items, spec=spec, stream_spec=ss)  # warmup jits
        eng2 = MiningEngine()
        t0 = _pc()
        for b in batches:
            eng2.append(b, n_items, spec=spec, stream_spec=ss)
        t_stream = _pc() - t0
        out.append((
            f"stream_append_{S}seg", t_stream * 1e6,
            f"{n_tx} rows in {S} batches -> {n_tx / t_stream:.0f} rows/s",
        ))

        # --- full-rebuild baseline: every batch invalidates the whole prep.
        # Growing row counts are padded to the full size so every rebuild
        # hits one compiled shape — the timing is prep work, not recompiles
        # (the same discipline row_pad applies to the streaming side)
        from repro.core.encoding import PAD

        base = MiningEngine(prep_cache_bytes=0)
        fe = base.frontend("hprepost")
        whole = np.concatenate(batches)
        fe.prepare(whole, n_items, spec.resolve(n_tx), spec)  # warm the jits
        t0 = _pc()
        for i in range(1, S + 1):
            seen = np.concatenate(batches[:i])
            seen_p = np.full((n_tx, seen.shape[1]), PAD, np.int32)
            seen_p[: len(seen)] = seen
            fe.prepare(seen_p, n_items, spec.resolve(n_tx), spec)
        t_rebuild = _pc() - t0
        out.append((
            f"stream_rebuild_baseline_{S}seg", t_rebuild * 1e6,
            f"full prep per batch; stream saves {100 * (1 - t_stream / t_rebuild):.0f}%",
        ))

        # --- query latency from the live segmented DB
        eng2.submit_stream(spec)  # warmup the per-segment wave jits
        t0 = _pc()
        res = eng2.submit_stream(spec)
        t_query = _pc() - t0
        assert res.itemsets == mono_res.itemsets  # parity is the contract
        out.append((
            f"stream_query_{S}seg", t_query * 1e6,
            f"vs monolithic {t_mono_query * 1e6:.0f}us "
            f"({t_query / max(t_mono_query, 1e-9):.1f}x), n={len(res.itemsets)}",
        ))

        # --- compaction: fold down, re-measure the read path
        stream = eng2.stream()
        ss_c = StreamSpec(row_pad=pad, max_segments=4 * S, compact_fanin=8)
        stream.stream_spec = ss_c
        t0 = _pc()
        while len(stream.db.segments) > max(2, S // 8):
            stream.compact()
        t_compact = _pc() - t0
        eng2.submit_stream(spec)  # warmup the post-compaction shapes
        t0 = _pc()
        res_c = eng2.submit_stream(spec)
        t_query_c = _pc() - t0
        assert res_c.itemsets == mono_res.itemsets
        out.append((
            f"stream_compact_{S}seg", t_compact * 1e6,
            f"-> {len(stream.db.segments)} segments, query after "
            f"{t_query_c * 1e6:.0f}us",
        ))

    # --- continuous mining: sliding-window ingest and standing-query diffs
    S = 8 if quick else 16
    batches = np.array_split(rows, S)
    pad = max(len(b) for b in batches)
    ssw = StreamSpec(row_pad=pad, window_batches=S // 2, max_segments=4 * S)

    engw = MiningEngine()
    engw.append(batches[0], n_items, spec=spec, stream_spec=ssw)  # warmup jits
    engw2 = MiningEngine()
    t0 = _pc()
    for b in batches:
        engw2.append(b, n_items, spec=spec, stream_spec=ssw)
    t_win = _pc() - t0
    stw = engw2.stream().stats
    out.append((
        f"stream_window_append_{S}seg", t_win * 1e6,
        f"window={S // 2} batches; expired {stw['expired_segments']} segs "
        f"/{stw['expired_rows']} rows at append time",
    ))

    engq = MiningEngine()
    engq.append(batches[0], n_items, spec=spec, stream_spec=ssw)
    engq.register_standing(spec)  # every append now delivers a MineDiff
    t0 = _pc()
    for b in batches[1:]:
        engq.append(b, n_items, spec=spec, stream_spec=ssw)
    t_watch = _pc() - t0
    stq = engq.stream().stats
    per_diff = stq["diff_latency_s_total"] / max(stq["diffs_delivered"], 1)
    out.append((
        f"stream_standing_diff_{S}seg", per_diff * 1e6,
        f"{stq['diffs_delivered']} diffs in {t_watch * 1e6:.0f}us of appends; "
        f"seed-pruned {stq['seed_pruned_candidates']} candidates",
    ))
    return out


if __name__ == "__main__":
    for name, us, note in run(quick=True):
        print(f"{name},{us:.0f},{note}")
