"""Render the §Roofline tables from results/dryrun into EXPERIMENTS.md
(replaces the <!-- ROOFLINE_TABLE --> / <!-- FIM_TABLE --> markers)."""
from __future__ import annotations

import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.roofline import load, table  # noqa: E402

EXP = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")


def fim_table(recs) -> str:
    rows = [r for r in recs if r.get("arch", "").startswith("hprepost_")]
    rows.sort(key=lambda r: (r["mesh"], r["arch"]))
    out = [
        "| stage | mesh | t_compute (s) | t_memory (s) | t_collective (s) | bottleneck |",
        "|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r['arch'].replace('hprepost_', '')} | {r['mesh']} | {r['t_compute']:.2e} "
            f"| {r['t_memory']:.2e} | {r['t_collective']:.2e} | {r['bottleneck']} |"
        )
    return "\n".join(out)


def main():
    recs = load()
    model_recs = [r for r in recs if not r.get("arch", "").startswith("hprepost_")]
    roof = table(model_recs, mesh="pod16x16")
    fim = fim_table(recs)
    text = open(EXP).read()
    text = re.sub(
        r"<!-- ROOFLINE_TABLE -->(.|\n)*?(?=\n### FIM)",
        "<!-- ROOFLINE_TABLE -->\n" + roof + "\n",
        text,
        count=1,
    ) if "<!-- ROOFLINE_TABLE -->" in text else text
    text = re.sub(
        r"<!-- FIM_TABLE -->(.|\n)*?(?=\nThe wave rows)",
        "<!-- FIM_TABLE -->\n" + fim + "\n",
        text,
        count=1,
    ) if "<!-- FIM_TABLE -->" in text else text
    open(EXP, "w").write(text)
    print("EXPERIMENTS.md tables updated")


if __name__ == "__main__":
    main()
