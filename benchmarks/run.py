"""Benchmark runner — one section per paper table/figure.

  paper_runtime_memory : Figs 3-6 (runtime) + Figs 7-10 (memory)
  scaling              : §4 MapReduce block partitioning (workers sweep)
  kernels              : per-kernel micro-latency (CPU ref path)
  service              : cross-group overlap + snapshot warm-start (PR 4)
  roofline             : dry-run aggregation (EXPERIMENTS.md §Roofline)

Prints ``name,us_per_call,derived`` CSV lines per the harness contract.
Use ``--quick`` for a reduced sweep, ``--skip-scaling`` in constrained CI.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
BENCH_PR = 10  # this PR's trajectory tag: emit_json writes BENCH_PR<n>.json


def emit_json(path: str | None = None, records=None, pr: int = BENCH_PR) -> str:
    """Write the machine-readable perf trajectory: kernel micro-bench rows,
    the host wave-planning vec-vs-loop comparison, end-to-end miner timings
    through one warm ``MiningEngine``, the service rows (cross-group
    overlap + snapshot warm-start), the streaming rows (append
    throughput vs full rebuild, segmented query latency, compaction cost),
    the distributed rows (1/2/4-worker scale-out + recovery time), and the
    telemetry rows (instrumented vs bare warm submit + the per-observation
    histogram/snapshot primitives).
    Future PRs diff their own emit against this file instead of re-deriving
    a baseline (``make bench-gate`` automates the diff).

    The output name is parameterized by ``pr`` (default: this PR), so each
    PR's trajectory lands in its own ``BENCH_PR<n>.json`` instead of
    overwriting its predecessor's."""
    from benchmarks.bench_distributed import run as distributed_run
    from benchmarks.bench_kernels import run as kernels_run
    from benchmarks.bench_service import run as service_run
    from benchmarks.bench_stream import run as stream_run
    from benchmarks.bench_telemetry import run as telemetry_run

    if path is None:
        path = os.path.join(os.path.dirname(__file__), "..", f"BENCH_PR{pr}.json")
    if records is None:
        records = (kernels_run() + service_run(quick=True)
                   + stream_run(quick=True) + distributed_run(quick=True)
                   + telemetry_run(quick=True))
    payload = {
        "schema": "bench-trajectory-v1",
        "pr": pr,
        "records": [
            {"name": name, "us_per_call": round(us, 1), "note": note}
            for name, us, note in records
        ],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    return os.path.abspath(path)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-scaling", action="store_true")
    args, _ = ap.parse_known_args()
    os.makedirs(RESULTS, exist_ok=True)

    print("name,us_per_call,derived")

    # --- paper tables (runtime + memory vs min-sup, 4 datasets)
    from benchmarks.bench_paper import run as paper_run

    recs = paper_run(os.path.join(RESULTS, "paper_tables.json"), quick=args.quick)
    for r in recs:
        tag = f"{r['dataset']}_sup{r['min_sup']}"
        print(f"fig3-6_runtime_hprepost_{tag},{r['hprepost_s']*1e6:.0f},n={r['n_itemsets']}")
        print(f"fig3-6_runtime_prepost_{tag},{r['prepost_s']*1e6:.0f},")
        print(f"fig3-6_runtime_fpgrowth_{tag},{r['fpgrowth_s']*1e6:.0f},")
        print(f"fig7-10_memory_hprepost_{tag},0,{r['hprepost_bytes']}B")
        print(f"fig7-10_memory_prepost_{tag},0,{r['prepost_bytes']}B")
        print(f"fig7-10_memory_fpgrowth_{tag},0,{r['fpgrowth_bytes']}B")

    # --- kernels + service + streaming (one BENCH_PR<n>.json trajectory)
    from benchmarks.bench_kernels import run as kernels_run
    from benchmarks.bench_service import run as service_run
    from benchmarks.bench_stream import run as stream_run

    recs = kernels_run()
    for name, us, note in recs:
        print(f"kernel_{name},{us:.0f},{note}")
    srecs = service_run(quick=args.quick)
    for name, us, note in srecs:
        print(f"{name},{us:.0f},{note}")
    trecs = stream_run(quick=args.quick)
    for name, us, note in trecs:
        print(f"{name},{us:.0f},{note}")
    from benchmarks.bench_distributed import run as distributed_run

    drecs = distributed_run(quick=args.quick)
    for name, us, note in drecs:
        print(f"{name},{us:.0f},{note}")
    from benchmarks.bench_telemetry import run as telemetry_run

    orecs = telemetry_run(quick=args.quick)
    for name, us, note in orecs:
        print(f"{name},{us:.0f},{note}")
    emit_json(records=recs + srecs + trecs + drecs + orecs)

    # --- scaling (subprocesses with fake devices)
    if not args.skip_scaling:
        from benchmarks.bench_scaling import run as scaling_run

        recs = scaling_run(os.path.join(RESULTS, "scaling.json"),
                           worlds=(1, 2, 4) if args.quick else (1, 2, 4, 8))
        for r in recs:
            print(
                f"scaling_workers{r['workers']},{r['warm_s']*1e6:.0f},"
                f"shard_nodes={r['max_shard_nodes']}/single={r['total_nodes_single']}"
            )

    # --- roofline aggregation (requires results/dryrun from repro.launch.dryrun)
    from benchmarks.roofline import load, summary

    recs = load()
    if recs:
        s = summary(recs)
        print(f"roofline_cells,{s['cells']},errors={s['errors']} skips={s['skips']}")
        for r in recs:
            if "skipped" in r or "error" in r:
                continue
            dom = max(r["t_compute"], r["t_memory"], r["t_collective"])
            print(
                f"roofline_{r['arch']}_{r['shape']}_{r['mesh']},"
                f"{dom*1e6:.1f},bottleneck={r['bottleneck']}"
            )


if __name__ == "__main__":
    main()
