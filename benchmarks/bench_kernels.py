"""Kernel micro-benchmarks: jnp reference path wall-clock on CPU plus the
interpret-mode parity check, and the host-side wave-planning throughput
(vectorized vs. the per-candidate loop baseline it replaced). (Real Pallas
timings need a TPU; the TPU-side performance statement is the roofline of
the mask-matmul form — see EXPERIMENTS.md §Roofline FIM rows.)"""
from __future__ import annotations

import time

import numpy as np


def _time(f, *args, reps=5):
    """Mean wall time per call in µs. The warmup call is blocked before the
    timed reps start, so neither compile time nor leftover async dispatch
    leaks into the first rep; ``jax.block_until_ready`` drains whole result
    pytrees (the fused kernels return tuples) and is a no-op on host arrays."""
    import jax

    jax.block_until_ready(f(*args))  # compile + drain dispatch
    t0 = time.perf_counter()
    r = None
    for _ in range(reps):
        r = f(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run() -> list[tuple[str, float, str]]:
    import jax.numpy as jnp

    from repro.kernels.cooccur.ref import cooccur_ref
    from repro.kernels.histogram.ref import histogram_ref
    from repro.kernels.nlist_intersect.ref import nlist_intersect_fused_ref
    import jax

    rng = np.random.default_rng(0)
    out = []

    rows = jnp.asarray(rng.integers(-1, 512, size=(4096, 32)), jnp.int32)
    w = jnp.ones(4096, jnp.int32)
    f = jax.jit(lambda r, w: histogram_ref(r, w, n_bins=512))
    out.append(("histogram_4096x32_b512", _time(f, rows, w), "ref/jnp"))

    f = jax.jit(lambda r, w: cooccur_ref(r, w, n_items=256))
    rows2 = jnp.asarray(rng.integers(-1, 256, size=(2048, 24)), jnp.int32)
    out.append(("cooccur_2048x24_k256", _time(f, rows2, jnp.ones(2048, jnp.int32)), "ref/jnp"))

    B, La, Ly = 512, 256, 256
    a_pre = jnp.asarray(np.sort(rng.integers(0, 1 << 20, (B, La)), axis=1), jnp.int32)
    a_post = a_pre + 5
    y_pre = jnp.asarray(np.sort(rng.integers(0, 1 << 20, (B, Ly)), axis=1), jnp.int32)
    y_post = y_pre - 3
    y_cnt = jnp.ones((B, Ly), jnp.int32)
    f = jax.jit(nlist_intersect_fused_ref)  # (merged, supports) in one call
    out.append(
        (f"nlist_intersect_fused_B{B}_{La}x{Ly}",
         _time(f, a_pre, a_post, y_pre, y_post, y_cnt), "ref/jnp")
    )
    out.extend(run_host_planning())
    out.extend(run_miners())
    out.extend(run_early_stop())
    out.extend(run_tuned_blocks())
    return out


# --------------------------------------------------- host planning baseline
# The pre-PR-3 per-candidate Python loops, kept here (and only here) as the
# throughput baseline the vectorized planner is diffed against.
def _extensions_loop(entries, pair_ok):
    out = []
    for ranks, slot in entries:
        for q2 in range(ranks[0] - 1, -1, -1):
            if all(pair_ok[q2, p] for p in ranks):
                out.append(((q2,) + ranks, slot, q2))
    return out


def _pack_wave_loop(miner, cands, level, slots_per_shard):
    from repro.core.hprepost import _pow2

    cfg = miner.cfg
    unit = cfg.candidate_unit
    Mb = miner._Mb
    if level == 2 or not cfg.locality_dispatch:
        Cn = len(cands)
        Cs = unit * _pow2((Cn + unit * Mb - 1) // (unit * Mb))
        Cpad = Cs * Mb
        slot_of = list(range(Cn))
        parent_arr = np.zeros(Cpad, np.int32)
        base_idx = np.zeros(Cpad, np.int32)
        q_idx = np.zeros(Cpad, np.int32)
        for i, (ranks, par, q) in enumerate(cands):
            parent_arr[i] = par
            base_idx[i] = ranks[1]
            q_idx[i] = q
        return parent_arr, base_idx, q_idx, slot_of, Cpad
    buckets = [[] for _ in range(Mb)]
    for i, (_, pslot, _) in enumerate(cands):
        buckets[min(pslot // slots_per_shard, Mb - 1)].append(i)
    worst = max(len(b) for b in buckets)
    Cs = unit * _pow2((worst + unit - 1) // unit)
    Cpad = Cs * Mb
    parent_arr = np.zeros(Cpad, np.int32)
    base_idx = np.zeros(Cpad, np.int32)
    q_idx = np.zeros(Cpad, np.int32)
    slot_of = [0] * len(cands)
    for s, bucket in enumerate(buckets):
        for j, i in enumerate(bucket):
            ranks, pslot, q = cands[i]
            slot = s * Cs + j
            slot_of[i] = slot
            parent_arr[slot] = pslot % slots_per_shard
            base_idx[slot] = ranks[1]
            q_idx[slot] = q
    return parent_arr, base_idx, q_idx, slot_of, Cpad


def run_host_planning() -> list[tuple[str, float, str]]:
    """Wave-planning throughput on a >= 10^4-candidate wave: the vectorized
    ``_extensions`` + ``_pack_wave`` (packbits AND-reduce + argsort slotting)
    against the per-candidate loop baseline they replaced."""
    from repro.core.hprepost import HPrepostConfig, HPrepostMiner
    from repro.mining.miners import default_mesh

    rng = np.random.default_rng(3)
    K, min_count = 160, 2
    C = np.triu(rng.integers(0, 4, (K, K)), 1)  # ~half of all pairs frequent
    pair_ok = (C + C.T) >= min_count
    pair_packed = np.packbits(pair_ok, axis=1)
    prefix_packed = np.packbits(np.tri(K, K, -1, dtype=bool), axis=1)
    qs, ps = np.nonzero(C >= min_count)
    ranks2 = np.stack([qs, ps], axis=1).astype(np.int32)
    slots2 = np.arange(len(ranks2), dtype=np.int64)
    entries2 = [(tuple(r), int(s)) for r, s in zip(ranks2.tolist(), slots2.tolist())]

    miner = HPrepostMiner(default_mesh(), config=HPrepostConfig())
    sps = 1 << 20  # slots_per_shard for the locality bucketing path

    def plan_vec():
        r3, s3, q3 = HPrepostMiner._extensions(
            ranks2, slots2, pair_packed, prefix_packed, K)
        return miner._pack_wave(r3, s3, q3, 3, sps)

    def plan_loop():
        ext = _extensions_loop(entries2, pair_ok)
        return _pack_wave_loop(miner, ext, 3, sps)

    n3 = len(HPrepostMiner._extensions(ranks2, slots2, pair_packed, prefix_packed, K)[0])
    assert n3 >= 10_000, n3  # the acceptance bar: a >= 10^4-candidate wave
    return [
        (f"wave_plan_vec_C{n3}", _time(plan_vec, reps=10), "host/vectorized"),
        (f"wave_plan_loop_C{n3}", _time(plan_loop, reps=3), "host/baseline"),
    ]


def run_early_stop(reps: int = 5) -> list[tuple[str, float, str]]:
    """PR 7 headline: warm end-to-end mine with early stopping on vs off at
    the smallest benchmarked threshold (deep waves — where the Apriori-
    closure host prune has subsets to check and candidates to drop). Both
    variants share one PreparedDB cache entry (execution-only knobs are
    normalized out of the key), so the comparison is pure wave cost; the
    answers are bit-identical by the parity suite."""
    from repro.data.synth import load
    from repro.mining import MineSpec, MiningEngine

    rows, n_items = load("mushroom", scale=0.05)
    engine = MiningEngine()
    out = []
    for es in (True, False):
        spec = MineSpec(algorithm="hprepost", min_sup=0.15, max_k=6,
                        candidate_unit=32, early_stop=es)
        res = engine.submit(rows, n_items, spec)  # warm (compile + shared prep)
        walls, pruned = [], 0
        for _ in range(reps):
            res = engine.submit(rows, n_items, spec)
            walls.append(res.wall_time_s)
        st = res.stage_times_s
        pruned = int(st.get("host_pruned_parent", 0) + st.get("host_pruned_subset", 0))
        out.append((
            f"mine_hprepost_mushroom0.05_sup0.15_early_stop_{'on' if es else 'off'}",
            min(walls) * 1e6,
            f"pruned={pruned}/{int(st.get('planned_candidates', 0)) + pruned}, "
            f"best of {reps}",
        ))
    return out


def run_tuned_blocks(reps: int = 3) -> list[tuple[str, float, str]]:
    """Tuned vs default block config on the one backend whose blocks matter
    on CPU: the Pallas interpreter (grid iterations are Python loops, so
    block shape moves real wall time). The tuner searches in memory; the
    rows record the default-config launch against the winner."""
    from repro.kernels.nlist_intersect.ops import nlist_intersect
    from repro.mining.tune import KernelTuner, _synthetic_nlists

    B, W = 32, 128
    a_pre, a_post, a_cnt, y_pre, y_post, y_cnt = _synthetic_nlists(B, W)
    tuner = KernelTuner()  # in-memory: search cost is not part of the rows
    plan = tuner.plan_for(backend="pallas-interpret", B=B, W=W, early_stop=True)

    def launch(la, ly, bb):
        return nlist_intersect(
            a_pre, a_post, y_pre, y_post, y_cnt, a_cnt=a_cnt,
            backend="pallas-interpret", la_block=la, ly_block=ly,
            batch_block=bb, early_stop=True, min_count=2,
        )

    default_us = _time(lambda: launch(512, 512, 8), reps=reps)
    tuned_us = _time(
        lambda: launch(plan.la_block, plan.ly_block, plan.batch_block), reps=reps
    )
    cfg = f"la{plan.la_block}xly{plan.ly_block}xbb{plan.batch_block}"
    return [
        (f"nlist_intersect_interpret_B{B}_{W}x{W}_default", default_us, "512x512x8"),
        (f"nlist_intersect_interpret_B{B}_{W}x{W}_tuned", tuned_us, cfg),
    ]


def run_miners(reps: int = 5) -> list[tuple[str, float, str]]:
    """End-to-end miner micro-bench through the unified front-door: every
    registered algorithm on one small dense DB, jit-warm via one engine. For
    hprepost the second submit is a persistent-PreparedDB-cache hit, so the
    reported time is the pure k>2 wave cost production resubmits pay.

    Reported as the **best of ``reps`` warm submits** — the PR 5
    trajectory recorded a single submit's wall time, and a one-off
    scheduler hiccup at emission time showed up as a phantom 6x
    regression on ``mine_hprepost_mushroom``. For a latency floor the
    minimum is the robust statistic (what ``timeit`` reports): any
    interference from co-resident bench sections only ever inflates a
    sample, never deflates it."""
    from repro.data.synth import load
    from repro.mining import MineSpec, MiningEngine, list_miners

    rows, n_items = load("mushroom", scale=0.05)
    engine = MiningEngine()
    out = []
    for algo in list_miners():
        if algo == "bruteforce":  # oracle: exponential candidate BFS, not a benchmark
            continue
        spec = MineSpec(algorithm=algo, min_sup=0.35, max_k=4, candidate_unit=32)
        engine.submit(rows, n_items, spec)  # warm (compile + prep for hprepost)
        walls = [engine.submit(rows, n_items, spec).wall_time_s for _ in range(reps)]
        out.append((
            f"mine_{algo}_mushroom0.05_sup0.35",
            min(walls) * 1e6,
            f"mining-api, best of {reps}",
        ))
    return out
