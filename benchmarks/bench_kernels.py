"""Kernel micro-benchmarks: jnp reference path wall-clock on CPU plus the
interpret-mode parity check. (Real Pallas timings need a TPU; the TPU-side
performance statement is the roofline of the mask-matmul form — see
EXPERIMENTS.md §Roofline FIM rows.)"""
from __future__ import annotations

import time

import numpy as np


def _time(f, *args, reps=5):
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        r = f(*args)
    try:
        r.block_until_ready()
    except AttributeError:
        pass
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run() -> list[tuple[str, float, str]]:
    import jax.numpy as jnp

    from repro.kernels.cooccur.ref import cooccur_ref
    from repro.kernels.histogram.ref import histogram_ref
    from repro.kernels.nlist_intersect.ref import nlist_intersect_ref
    import jax

    rng = np.random.default_rng(0)
    out = []

    rows = jnp.asarray(rng.integers(-1, 512, size=(4096, 32)), jnp.int32)
    w = jnp.ones(4096, jnp.int32)
    f = jax.jit(lambda r, w: histogram_ref(r, w, n_bins=512))
    out.append(("histogram_4096x32_b512", _time(f, rows, w), "ref/jnp"))

    f = jax.jit(lambda r, w: cooccur_ref(r, w, n_items=256))
    rows2 = jnp.asarray(rng.integers(-1, 256, size=(2048, 24)), jnp.int32)
    out.append(("cooccur_2048x24_k256", _time(f, rows2, jnp.ones(2048, jnp.int32)), "ref/jnp"))

    B, La, Ly = 512, 256, 256
    a_pre = jnp.asarray(np.sort(rng.integers(0, 1 << 20, (B, La)), axis=1), jnp.int32)
    a_post = a_pre + 5
    y_pre = jnp.asarray(np.sort(rng.integers(0, 1 << 20, (B, Ly)), axis=1), jnp.int32)
    y_post = y_pre - 3
    y_cnt = jnp.ones((B, Ly), jnp.int32)
    f = jax.jit(nlist_intersect_ref)
    out.append(
        (f"nlist_intersect_B{B}_{La}x{Ly}", _time(f, a_pre, a_post, y_pre, y_post, y_cnt), "ref/jnp")
    )
    out.extend(run_miners())
    return out


def run_miners() -> list[tuple[str, float, str]]:
    """End-to-end miner micro-bench through the unified front-door: every
    registered algorithm on one small dense DB, jit-warm via one engine."""
    from repro.data.synth import load
    from repro.mining import MineSpec, MiningEngine, list_miners

    rows, n_items = load("mushroom", scale=0.05)
    engine = MiningEngine()
    out = []
    for algo in list_miners():
        if algo == "bruteforce":  # oracle: exponential candidate BFS, not a benchmark
            continue
        spec = MineSpec(algorithm=algo, min_sup=0.35, max_k=4, candidate_unit=32)
        engine.submit(rows, n_items, spec)  # warm (compile for hprepost)
        res = engine.submit(rows, n_items, spec)
        out.append((f"mine_{algo}_mushroom0.05_sup0.35", res.wall_time_s * 1e6, "mining-api"))
    return out
