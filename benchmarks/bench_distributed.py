"""Distributed mining bench: the PR 6 acceptance numbers.

The scale-out curve the paper's cluster experiments draw (query latency
and append/ingest throughput at 1 / 2 / 4 workers) against the
single-process ``StreamingMiner`` on the same rows, plus a recovery-time
row: hard-kill a worker under a 2-worker database and time the next
query, which must detect the death, re-place the dead worker's segments
(snapshot-restored), replay, and still answer bit-identically.

On one host the workers compete for the same cores, so the curve
measures *overhead* (RPC framing + per-worker wave launch) rather than
speedup — the number that must stay flat for multi-host scale-out to
pay. Every distributed result is parity-checked against the
single-process answer before its row is emitted.
"""
from __future__ import annotations

import shutil
import statistics
import tempfile
import time

import numpy as np


def _pc() -> float:
    return time.perf_counter()


def run(quick: bool = False) -> list[tuple[str, float, str]]:
    from repro.data.synth import random_db
    from repro.mining import MineSpec, MiningEngine
    from repro.mining.stream import StreamSpec

    n_items, max_len = 24, 8
    n_tx = 1024 if quick else 2048
    n_batches = 8
    reps = 3 if quick else 5
    # min_sup low enough that k=2/3 waves actually run — at 0.05 on this
    # synth DB only singletons survive and the rows would time an empty
    # RPC round-trip instead of the broadcast wave path
    spec = MineSpec(algorithm="hprepost", min_sup=0.02, max_k=4, candidate_unit=64)
    rows = random_db(np.random.default_rng(3), n_tx, n_items, max_len)
    batches = np.array_split(rows, n_batches)
    pad = max(len(b) for b in batches)
    ss = StreamSpec(row_pad=pad, max_segments=4 * n_batches)
    out: list[tuple[str, float, str]] = []

    # single-process streaming reference
    eng = MiningEngine()
    t0 = _pc()
    for b in batches:
        eng.append(b, n_items, spec=spec, stream_spec=ss)
    t_append_1p = _pc() - t0
    eng.submit_stream(spec)  # warm the wave jits
    walls = []
    for _ in range(reps):
        t0 = _pc()
        ref = eng.submit_stream(spec)
        walls.append(_pc() - t0)
    t_query_1p = statistics.median(walls)
    out.append((
        "dist_query_single_process", t_query_1p * 1e6,
        f"StreamingMiner baseline, {n_batches} segments, n={len(ref.itemsets)}",
    ))
    out.append((
        "dist_append_single_process", t_append_1p * 1e6,
        f"{n_tx} rows in {n_batches} batches -> {n_tx / t_append_1p:.0f} rows/s",
    ))

    for W in (1, 2, 4):
        deng = MiningEngine()
        dm = deng.distribute(
            n_items=n_items, workers=W, spec=spec, stream_spec=ss,
            name=f"bench-w{W}",
        )
        try:
            t0 = _pc()
            for b in batches:
                dm.append(b)
            t_append = _pc() - t0
            dm.mine(spec)  # warm every worker's wave jits
            walls = []
            for _ in range(reps):
                t0 = _pc()
                res = dm.mine(spec)
                walls.append(_pc() - t0)
            assert res.itemsets == ref.itemsets  # parity is the contract
            t_query = statistics.median(walls)
            out.append((
                f"dist_query_{W}w", t_query * 1e6,
                f"vs single-process {t_query_1p * 1e6:.0f}us "
                f"({t_query / max(t_query_1p, 1e-9):.1f}x), n={len(res.itemsets)}",
            ))
            out.append((
                f"dist_append_{W}w", t_append * 1e6,
                f"{n_tx} rows in {n_batches} batches -> "
                f"{n_tx / t_append:.0f} rows/s (incl. worker jit warmup)",
            ))
        finally:
            dm.close()

    # recovery time: 2 workers with a shared snapshot store, kill one
    # mid-topology, time the next query end-to-end (death detection +
    # snapshot re-placement + full replay)
    snap_dir = tempfile.mkdtemp(prefix="bench-dist-snap-")
    try:
        deng = MiningEngine(snapshot_dir=snap_dir)
        dm = deng.distribute(
            n_items=n_items, workers=2, spec=spec, stream_spec=ss,
            name="bench-recovery",
        )
        try:
            for b in batches:
                dm.append(b)
            r1 = dm.mine(spec)  # warm both workers
            assert r1.itemsets == ref.itemsets
            victim = min(w.wid for w in dm._live())
            dm.kill_worker(victim)
            t0 = _pc()
            r2 = dm.mine(spec)
            t_recover = _pc() - t0
            assert r2.itemsets == ref.itemsets
            st = dm.stats
            out.append((
                "dist_recovery_2w", t_recover * 1e6,
                f"kill->answer: {st['reassigned_segments']} segments re-placed, "
                f"{st['reassign_snapshot_restores']} from snapshots, "
                f"{st['reassign_rebuilds']} rebuilt",
            ))
        finally:
            dm.close()
    finally:
        shutil.rmtree(snap_dir, ignore_errors=True)
    return out


if __name__ == "__main__":
    for name, us, note in run(quick=True):
        print(f"{name},{us:.0f},{note}")
