"""Perf regression gate over the BENCH_PR*.json trajectory files.

Compares the current PR's trajectory against the previous PR's, row by
row over the names both contain, and exits nonzero when a tracked row
slowed past tolerance — the class of silent one-row regressions the PR 5
trajectory carried (``mine_hprepost_mushroom`` recorded at 6x its real
latency) becomes unshippable instead of a note for the next session.

The check is deliberately loose: these benches run on shared noisy CI
hosts, so a row fails only when ``cur > prev * tolerance + slack_us``.
The default 3x tolerance catches order-of-magnitude breakage without
tripping on scheduler jitter; rows measured in microseconds get the
absolute slack so a 40us -> 130us wobble on a trivial row doesn't gate a
merge.

    python -m benchmarks.bench_gate                 # newest PR vs its predecessor
    python -m benchmarks.bench_gate --pr 6 --prev 5 --tolerance 2.5
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _trajectories() -> dict[int, str]:
    out = {}
    for path in glob.glob(os.path.join(ROOT, "BENCH_PR*.json")):
        m = re.fullmatch(r"BENCH_PR(\d+)\.json", os.path.basename(path))
        if m:
            out[int(m.group(1))] = path
    return out


def _rows(path: str) -> dict[str, float]:
    with open(path) as f:
        payload = json.load(f)
    return {r["name"]: float(r["us_per_call"]) for r in payload["records"]}


def gate(cur_path: str, prev_path: str, *, tolerance: float = 3.0,
         slack_us: float = 500.0, out=sys.stdout) -> int:
    """Compare two trajectory files; returns the number of failing rows.
    A row fails when ``cur > prev * tolerance + slack_us``; rows present
    in only one file are reported but never fail (new subsystems appear,
    old rows retire)."""
    cur, prev = _rows(cur_path), _rows(prev_path)
    shared = sorted(set(cur) & set(prev))
    failures = []
    print(
        f"bench-gate: {os.path.basename(cur_path)} vs "
        f"{os.path.basename(prev_path)} ({len(shared)} shared rows, "
        f"tolerance {tolerance:g}x + {slack_us:g}us)", file=out,
    )
    for name in shared:
        c, p = cur[name], prev[name]
        limit = p * tolerance + slack_us
        ratio = c / p if p > 0 else float("inf")
        verdict = "FAIL" if c > limit else "ok"
        if c > limit:
            failures.append(name)
        if c > limit or ratio > 1.5 or ratio < 0.5:
            print(f"  [{verdict}] {name}: {p:.0f}us -> {c:.0f}us ({ratio:.2f}x)",
                  file=out)
    only_cur = sorted(set(cur) - set(prev))
    only_prev = sorted(set(prev) - set(cur))
    if only_cur:
        print(f"  new rows (not gated): {len(only_cur)}", file=out)
    if only_prev:
        print(f"  retired rows: {', '.join(only_prev)}", file=out)
    if failures:
        print(f"bench-gate: {len(failures)} row(s) regressed past tolerance: "
              f"{', '.join(failures)}", file=out)
    else:
        print("bench-gate: green", file=out)
    return len(failures)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pr", type=int, default=None,
                    help="PR trajectory to check (default: newest on disk)")
    ap.add_argument("--prev", type=int, default=None,
                    help="baseline PR (default: newest below --pr)")
    ap.add_argument("--tolerance", type=float, default=3.0)
    ap.add_argument("--slack-us", type=float, default=500.0)
    args = ap.parse_args(argv)

    traj = _trajectories()
    if len(traj) < 2:
        print("bench-gate: fewer than two BENCH_PR*.json trajectories on disk; "
              "nothing to compare")
        return 0
    pr = args.pr if args.pr is not None else max(traj)
    older = [n for n in traj if n < pr]
    if pr not in traj or (args.prev is None and not older):
        print(f"bench-gate: no trajectory pair for PR {pr}")
        return 2
    prev = args.prev if args.prev is not None else max(older)
    if prev not in traj:
        print(f"bench-gate: BENCH_PR{prev}.json not found")
        return 2
    return 1 if gate(traj[pr], traj[prev], tolerance=args.tolerance,
                     slack_us=args.slack_us) else 0


if __name__ == "__main__":
    raise SystemExit(main())
