"""Aggregate results/dryrun/*.json into the EXPERIMENTS.md roofline table."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load(results_dir: str = RESULTS) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def table(recs: list[dict], mesh: str = "pod16x16") -> str:
    rows = [r for r in recs if r.get("mesh") == mesh and "skipped" not in r and "error" not in r]
    rows.sort(key=lambda r: (r["arch"], r.get("shape", "")))
    out = [
        "| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) | bottleneck | "
        "MODEL/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        dom = max(r["t_compute"], r["t_memory"], r["t_collective"])
        frac = r["t_compute"] / dom if dom else 0.0
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.3e} | {r['t_memory']:.3e} "
            f"| {r['t_collective']:.3e} | {r['bottleneck']} "
            f"| {r.get('useful_flops_ratio', 0):.2f} | {frac:.2f} |"
        )
    return "\n".join(out)


def summary(recs: list[dict]) -> dict:
    done = [r for r in recs if "skipped" not in r and "error" not in r]
    return {
        "cells": len(done),
        "errors": sum(1 for r in recs if "error" in r),
        "skips": sum(1 for r in recs if "skipped" in r),
        "bottlenecks": {
            b: sum(1 for r in done if r["bottleneck"] == b)
            for b in ("compute", "memory", "collective")
        },
    }


def main():
    recs = load()
    print(json.dumps(summary(recs), indent=1))
    print(table(recs))


if __name__ == "__main__":
    main()
