"""Service throughput/latency bench: the PR 4 acceptance numbers.

Two measurements, both through one warm engine (compile cost is paid by a
warmup pass and never timed):

  cross-group overlap   four distinct databases, a threshold sweep each
                        -> four planned groups. Sequential baseline: each
                        group served alone (sum of walls). Service path:
                        one batch through ``GroupScheduler`` — group g+1's
                        prepare (host shuffle + device Jobs 1/2/pack/F2)
                        runs while group g's wave loop drains, so the
                        batch wall must undercut the sequential sum. The
                        LRU is disabled for this phase so every group
                        really pays prep — with caching on there is
                        nothing left to overlap. Both paths are timed
                        best-of-N after a shared warmup: the workload is
                        deliberately dispatch-bound (small DBs, several
                        groups) because that is where a prep thread buys
                        wall-clock on a 2-core CI box — at XLA-saturating
                        sizes the cores are already busy and overlap is
                        contention, not speedup (expect single-digit
                        percent here; the headroom grows with cores).

  snapshot warm-start   cold prep+mine vs ``clear_prep_cache()`` + mine
                        through the on-disk PreparedDB store: the
                        warm-start serves with zero prep stages, so its
                        latency is load + waves only.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np


def _pc() -> float:
    return time.perf_counter()


def run(quick: bool = False) -> list[tuple[str, float, str]]:
    from repro.data.synth import random_db
    from repro.mining import MineRequest, MineSpec, MiningEngine
    from repro.mining.service import GroupScheduler

    n_tx, n_items, max_len = 800, 24, 8
    sweeps = [0.1, 0.07, 0.05]
    # paired reps: each rep times the sequential path and the batch path
    # back-to-back, and the headline statistic is the MEDIAN of per-rep
    # savings — the per-batch margin (a few hidden prepares of a few ms)
    # sits near OS-scheduler noise on a 2-core box, and pairing cancels
    # the machine-wide drift that poisons unpaired minima
    reps = 11 if quick else 15
    spec = MineSpec(algorithm="hprepost", max_k=5, candidate_unit=64, min_sup=0.5)
    dbs = [random_db(np.random.default_rng(seed), n_tx, n_items, max_len)
           for seed in range(4)]
    groups = [
        [MineRequest(rows, n_items, spec.with_(min_sup=s)) for s in sweeps]
        for rows in dbs
    ]
    all_reqs = [r for g in groups for r in g]
    out: list[tuple[str, float, str]] = []

    # --- cross-group overlap (prep of group g+1 hidden under mine of g)
    engine = MiningEngine(prep_cache_bytes=0)  # every group pays real prep
    with GroupScheduler(engine, overlap=False) as seq, GroupScheduler(engine) as ovl:
        seq.run(all_reqs)  # warmup: compile every jit both phases will hit
        ovl.run(all_reqs)  # ... and the overlapped path's thread handoffs
        pairs = []
        group_walls = [float("inf")] * len(groups)
        for _ in range(reps):
            walls = []
            for g in groups:
                t0 = _pc()
                seq.run(g)
                walls.append(_pc() - t0)
            group_walls = [min(a, b) for a, b in zip(group_walls, walls)]
            t0 = _pc()
            ovl.run(all_reqs)
            pairs.append((sum(walls), _pc() - t0))
        n_itemsets = sum(len(r.itemsets) for r in seq.run(all_reqs))
    savings = sorted(1 - b / s for s, b in pairs)
    saved = savings[len(savings) // 2]  # median of paired per-rep savings
    pos = sum(1 for x in savings if x > 0)
    t_seq, t_batch = min(p[0] for p in pairs), min(p[1] for p in pairs)
    for i, w in enumerate(group_walls):
        out.append((f"service_group{i}_sequential", w * 1e6, f"db{i} sweep x{len(sweeps)}"))
    out.append((
        f"service_batch_{len(dbs)}db_overlap",
        t_batch * 1e6,
        f"sequential_sum={t_seq * 1e6:.0f}us median_saved={100 * saved:.0f}% "
        f"positive_reps={pos}/{reps} "
        f"overlapped_prepares={ovl.stats['overlapped_prepares']} n={n_itemsets}",
    ))

    # --- snapshot warm-start (cold prep vs zero-prep load from the store)
    with tempfile.TemporaryDirectory() as d:
        eng = MiningEngine(snapshot_dir=d)
        req = groups[0][0]
        eng.submit(req.rows, req.n_items, req.spec)  # warmup: compile + spill
        eng.clear_prep_cache()
        import shutil, os

        for entry in eng.snapshot_store.entries():  # force a true cold build
            shutil.rmtree(entry, ignore_errors=True)
        t0 = _pc()
        eng.submit(req.rows, req.n_items, req.spec)
        t_cold = _pc() - t0
        eng.clear_prep_cache()  # "process restart": LRU gone, store populated
        t0 = _pc()
        res = eng.submit(req.rows, req.n_items, req.spec)
        t_warm = _pc() - t0
        assert res.service_stats.get("prep_source") == "snapshot", res.service_stats
    out.append(("service_warmstart_cold_prep", t_cold * 1e6, "prep rebuilt from rows"))
    out.append((
        "service_warmstart_snapshot",
        t_warm * 1e6,
        f"prepares=0 cold/warm={t_cold / max(t_warm, 1e-9):.2f}x",
    ))
    return out


if __name__ == "__main__":
    for name, us, note in run(quick=True):
        print(f"{name},{us:.0f},{note}")
