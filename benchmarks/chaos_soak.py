"""Chaos soak + overload smoke for the hardened MiningService
(``make chaos-smoke``).

Two checks, both fixed-seed and self-verifying:

  ``soak``     — install a seeded ``ChaosInjector`` over every service
                 failure point (enqueue, prep, serve, wave launch,
                 snapshot read) and flood the service with mixed-QoS
                 requests. PASS iff every accepted Future resolves —
                 with a result or a typed error — every successful
                 result is bit-identical to a clean single-engine run,
                 and the admission accounting drains back to zero.
  ``overload`` — bound the queue tightly and flood it. PASS iff the
                 overflow is rejected *immediately* with typed
                 ``Overloaded`` (never buffered, never hung), everything
                 else serves exactly, and the counters add up.

Usage:
    PYTHONPATH=src python -m benchmarks.chaos_soak            # both
    PYTHONPATH=src python -m benchmarks.chaos_soak soak
    PYTHONPATH=src python -m benchmarks.chaos_soak overload
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.data.synth import random_db
from repro.fault.failures import ChaosInjector, SimulatedFailure, installed
from repro.mining import MineSpec, MiningEngine
from repro.mining.service import MiningService
from repro.mining.service.admission import Overloaded, ServiceError

SPEC = MineSpec(algorithm="hprepost", max_k=4, candidate_unit=8, min_sup=0.25,
                nlist_width=16)
SOAK_SEED = 20260808  # the whole failure schedule is derived from this
N_SOAK = 36


def _dbs():
    return [(random_db(np.random.default_rng(s), 70 + 10 * s, 12, 6), 12)
            for s in range(3)]


def _clean_baselines(dbs):
    eng = MiningEngine()
    return [eng.submit(rows, n, SPEC).itemsets for rows, n in dbs]


def soak() -> None:
    dbs = _dbs()
    clean = _clean_baselines(dbs)
    inj = ChaosInjector(seed=SOAK_SEED)
    inj.arm("service.serve", times=0, prob=0.12)
    inj.arm("service.prep", times=0, prob=0.12)
    inj.arm("service.enqueue", times=0, prob=0.08)
    inj.arm("mine.wave", times=0, prob=0.04)
    inj.arm("snapshot.read", times=0, prob=0.25)

    t0 = time.perf_counter()
    with MiningService(batch_window_s=0.01, max_queue_depth=12) as svc:
        with installed(inj):
            futs = []
            for k in range(N_SOAK):
                rows, n = dbs[k % len(dbs)]
                spec = SPEC.with_(
                    priority=k % 3,
                    deadline_s=120.0 if k % 5 == 0 else None,
                )
                futs.append((k, svc.submit(rows, n, spec)))
                if k % 9 == 8:
                    time.sleep(0.03)  # let a few batches cycle mid-flood
        ok = fail = 0
        for k, f in futs:
            exc = f.exception(timeout=600)  # a hang here is the failure
            if exc is not None:
                if not isinstance(exc, (ServiceError, SimulatedFailure)):
                    raise SystemExit(
                        f"request {k} resolved with an untyped error: {exc!r}"
                    )
                fail += 1
            else:
                got = f.result().itemsets
                if got != clean[k % len(dbs)]:
                    raise SystemExit(
                        f"request {k} diverged from the clean run under chaos"
                    )
                ok += 1
        snap = svc.stats()
    if ok + fail != N_SOAK:
        raise SystemExit(f"lost futures: {ok}+{fail} != {N_SOAK}")
    adm = snap["admission"]
    if adm["depth"] != 0 or adm["bytes_in_flight"] != 0:
        raise SystemExit(f"admission accounting did not drain: {adm}")
    fired = sum(inj.fired.values())
    if fired == 0:
        raise SystemExit("the chaos schedule never fired; soak proved nothing")
    print(
        f"chaos soak: {N_SOAK} requests in {time.perf_counter() - t0:.1f}s -> "
        f"{ok} exact results, {fail} typed failures, 0 orphans"
    )
    print(f"  injected: {dict(inj.fired)}")
    print(
        f"  counters: {snap['counters']} "
        f"worker_restarts={snap['service']['worker_restarts']}"
    )
    print("chaos soak PASS: every accepted Future resolved, results bit-identical")


def overload() -> None:
    dbs = _dbs()
    clean = _clean_baselines(dbs)
    t0 = time.perf_counter()
    with MiningService(batch_window_s=0.25, max_queue_depth=2) as svc:
        futs = []
        for k in range(12):
            rows, n = dbs[k % len(dbs)]
            futs.append((k, svc.submit(rows, n, SPEC)))
        served = rejected = 0
        for k, f in futs:
            exc = f.exception(timeout=600)
            if isinstance(exc, Overloaded):
                rejected += 1
            elif exc is None:
                if f.result().itemsets != clean[k % len(dbs)]:
                    raise SystemExit(f"request {k} served a wrong answer under load")
                served += 1
            else:
                raise SystemExit(f"request {k}: unexpected error {exc!r}")
        snap = svc.stats()
    if served + rejected != 12 or rejected == 0 or served == 0:
        raise SystemExit(
            f"overload shape wrong: served={served} rejected={rejected}"
        )
    if snap["counters"]["rejected"] != rejected:
        raise SystemExit(f"rejected counter disagrees: {snap['counters']}")
    print(
        f"overload smoke: 12 submits vs depth-2 queue in "
        f"{time.perf_counter() - t0:.1f}s -> {served} exact, {rejected} Overloaded"
    )
    print("overload smoke PASS: backpressure is immediate and typed")


def main(argv=None) -> None:
    modes = (argv if argv is not None else sys.argv[1:]) or ["soak", "overload"]
    for m in modes:
        {"soak": soak, "overload": overload}[m]()


if __name__ == "__main__":
    main()
