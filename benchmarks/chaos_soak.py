"""Chaos soak + overload smoke for the hardened MiningService
(``make chaos-smoke``).

Three checks, all fixed-seed and self-verifying:

  ``soak``       — install a seeded ``ChaosInjector`` over every service
                   failure point (enqueue, prep, serve, wave launch,
                   snapshot read, telemetry emit) and flood the service
                   with mixed-QoS requests while a periodic ``StatsEmitter``
                   snapshots the service. PASS iff every accepted Future
                   resolves — with a result or a typed error — every
                   successful result is bit-identical to a clean
                   single-engine run, the admission accounting drains back
                   to zero, and chaos-dropped emits were swallowed by the
                   emitter (counted in ``dropped``) without blocking or
                   failing a single request Future, with later snapshots
                   still landing as parseable JSON lines.
  ``overload``   — bound the queue tightly and flood it. PASS iff the
                   overflow is rejected *immediately* with typed
                   ``Overloaded`` (never buffered, never hung), everything
                   else serves exactly, and the counters add up.
  ``continuous`` — a sliding-window stream with a standing query, driven
                   through the service Future lane while chaos hits the
                   continuous points (``stream.expire``, ``stream.diff``)
                   plus enqueue. PASS iff every accepted Future resolves,
                   every delivered diff chain replays from empty to the
                   exact delivered answer (diffs consistent with a clean
                   replay), interleaved windowed queries answer
                   bit-identically to the brute-force oracle over exactly
                   the retained rows, and after chaos is disarmed one
                   clean append restores the window invariant (expiry
                   self-heals).

Usage:
    PYTHONPATH=src python -m benchmarks.chaos_soak            # all three
    PYTHONPATH=src python -m benchmarks.chaos_soak soak
    PYTHONPATH=src python -m benchmarks.chaos_soak overload
    PYTHONPATH=src python -m benchmarks.chaos_soak continuous
"""
from __future__ import annotations

import io
import json
import sys
import time

import numpy as np

from repro.data.synth import random_db
from repro.fault.failures import ChaosInjector, SimulatedFailure, installed
from repro.mining import MineSpec, MiningEngine
from repro.mining.service import MiningService
from repro.mining.service.admission import Overloaded, ServiceError

SPEC = MineSpec(algorithm="hprepost", max_k=4, candidate_unit=8, min_sup=0.25,
                nlist_width=16)
SOAK_SEED = 20260808  # the whole failure schedule is derived from this
N_SOAK = 36


def _dbs():
    return [(random_db(np.random.default_rng(s), 70 + 10 * s, 12, 6), 12)
            for s in range(3)]


def _clean_baselines(dbs):
    eng = MiningEngine()
    return [eng.submit(rows, n, SPEC).itemsets for rows, n in dbs]


def soak() -> None:
    from repro.mining.telemetry import StatsEmitter

    dbs = _dbs()
    clean = _clean_baselines(dbs)
    inj = ChaosInjector(seed=SOAK_SEED)
    inj.arm("service.serve", times=0, prob=0.12)
    inj.arm("service.prep", times=0, prob=0.12)
    inj.arm("service.enqueue", times=0, prob=0.08)
    inj.arm("mine.wave", times=0, prob=0.04)
    inj.arm("snapshot.read", times=0, prob=0.25)
    inj.arm("telemetry.emit", times=0, prob=0.6)

    t0 = time.perf_counter()
    sink = io.StringIO()
    with MiningService(batch_window_s=0.01, max_queue_depth=12) as svc, \
            StatsEmitter(svc.stats, sink, interval_s=0.01) as emitter:
        with installed(inj):
            futs = []
            for k in range(N_SOAK):
                rows, n = dbs[k % len(dbs)]
                spec = SPEC.with_(
                    priority=k % 3,
                    deadline_s=120.0 if k % 5 == 0 else None,
                )
                futs.append((k, svc.submit(rows, n, spec)))
                if k % 9 == 8:
                    time.sleep(0.03)  # let a few batches cycle mid-flood
        ok = fail = 0
        for k, f in futs:
            exc = f.exception(timeout=600)  # a hang here is the failure
            if exc is not None:
                if not isinstance(exc, (ServiceError, SimulatedFailure)):
                    raise SystemExit(
                        f"request {k} resolved with an untyped error: {exc!r}"
                    )
                fail += 1
            else:
                got = f.result().itemsets
                if got != clean[k % len(dbs)]:
                    raise SystemExit(
                        f"request {k} diverged from the clean run under chaos"
                    )
                ok += 1
        snap = svc.stats()
    if ok + fail != N_SOAK:
        raise SystemExit(f"lost futures: {ok}+{fail} != {N_SOAK}")
    adm = snap["admission"]
    if adm["depth"] != 0 or adm["bytes_in_flight"] != 0:
        raise SystemExit(f"admission accounting did not drain: {adm}")
    fired = sum(inj.fired.values())
    if fired == 0:
        raise SystemExit("the chaos schedule never fired; soak proved nothing")
    # telemetry containment: chaos drops hit the emitter, never a request.
    # The Future checks above already proved no request was harmed; here we
    # prove the drops actually happened, were swallowed (not raised), and
    # that later snapshots still landed as parseable JSON lines.
    est = emitter.stats
    if est["dropped"] < 1:
        raise SystemExit(f"chaos never dropped an emit; telemetry containment "
                         f"unproven: {est}")
    if est["periodic"] < 1:
        raise SystemExit(f"the emitter never landed a periodic snapshot "
                         f"between drops: {est}")
    if est["errors"] != 0:
        raise SystemExit(f"emitter hit non-chaos errors: {est}")
    for line in sink.getvalue().splitlines():
        json.loads(line)  # every landed line must be a parseable snapshot
    print(
        f"chaos soak: {N_SOAK} requests in {time.perf_counter() - t0:.1f}s -> "
        f"{ok} exact results, {fail} typed failures, 0 orphans"
    )
    print(f"  injected: {dict(inj.fired)}")
    print(f"  emitter: {est['periodic']} periodic landed, {est['dropped']} "
          f"chaos-dropped, 0 request futures harmed")
    print(
        f"  counters: {snap['counters']} "
        f"worker_restarts={snap['service']['worker_restarts']}"
    )
    print("chaos soak PASS: every accepted Future resolved, results bit-identical")


def overload() -> None:
    dbs = _dbs()
    clean = _clean_baselines(dbs)
    t0 = time.perf_counter()
    with MiningService(batch_window_s=0.25, max_queue_depth=2) as svc:
        futs = []
        for k in range(12):
            rows, n = dbs[k % len(dbs)]
            futs.append((k, svc.submit(rows, n, SPEC)))
        served = rejected = 0
        for k, f in futs:
            exc = f.exception(timeout=600)
            if isinstance(exc, Overloaded):
                rejected += 1
            elif exc is None:
                if f.result().itemsets != clean[k % len(dbs)]:
                    raise SystemExit(f"request {k} served a wrong answer under load")
                served += 1
            else:
                raise SystemExit(f"request {k}: unexpected error {exc!r}")
        snap = svc.stats()
    if served + rejected != 12 or rejected == 0 or served == 0:
        raise SystemExit(
            f"overload shape wrong: served={served} rejected={rejected}"
        )
    if snap["counters"]["rejected"] != rejected:
        raise SystemExit(f"rejected counter disagrees: {snap['counters']}")
    print(
        f"overload smoke: 12 submits vs depth-2 queue in "
        f"{time.perf_counter() - t0:.1f}s -> {served} exact, {rejected} Overloaded"
    )
    print("overload smoke PASS: backpressure is immediate and typed")


def continuous() -> None:
    from repro.core.oracle import mine_bruteforce
    from repro.mining.continuous import replay_diffs
    from repro.mining.stream import StreamSpec

    rng = np.random.default_rng(SOAK_SEED)
    n_items = 12
    sspec = StreamSpec(row_pad=16, window_rows=120)
    spec = SPEC.with_(min_sup=0.3)
    n_appends = 14

    inj = ChaosInjector(seed=SOAK_SEED)
    inj.arm("stream.expire", times=0, prob=0.3)
    inj.arm("stream.diff", times=0, prob=0.25)
    inj.arm("service.enqueue", times=0, prob=0.05)

    t0 = time.perf_counter()
    with MiningService(batch_window_s=0.01) as svc:
        svc.engine.stream("cont", n_items=n_items, spec=spec, stream_spec=sspec)
        qf = svc.register_standing(spec, stream="cont")
        afuts, qfuts = [], []
        with installed(inj):
            for k in range(n_appends):
                rows = random_db(rng, 20 + int(rng.integers(0, 25)), n_items, 6)
                afuts.append(svc.append(rows, n_items, stream="cont",
                                        spec=spec, stream_spec=sspec))
                if k == 2:
                    qfuts.append(svc.register_standing(spec, stream="cont"))
                if k % 4 == 3:
                    qfuts.append(svc.submit_stream(spec, stream="cont"))
            # drain the appends INSIDE the chaos window — they execute on
            # the service worker thread, and the expiry/diff points must be
            # armed when it reaches them
            for f in afuts:
                f.exception(timeout=600)
            for point in ("stream.expire", "stream.diff", "service.enqueue"):
                inj.disarm(point)
            # one clean append after disarm: expiry self-heals whatever
            # chaos skipped
            heal = svc.append(random_db(rng, 24, n_items, 6), n_items,
                              stream="cont")
        resolved = typed = 0
        queries = []
        for f in afuts + qfuts + [qf, heal]:
            exc = f.exception(timeout=600)  # a hang here is the failure
            if exc is None:
                resolved += 1
                queries.append(f.result())
            elif isinstance(exc, (ServiceError, SimulatedFailure)):
                typed += 1
            else:
                raise SystemExit(f"untyped error out of the stream lane: {exc!r}")
        sm = svc.engine.stream("cont")
    # every delivered diff chain replays from empty to the delivered answer
    standing = [r for r in queries if hasattr(r, "diffs")]
    for q in standing:
        if replay_diffs(q.diffs) != q.latest:
            raise SystemExit("a diff chain does not replay to its answer")
    # window invariant after the clean append, and exact windowed answers
    # segment rows carry PAD tails (row_pad); the real rows lead
    retained = np.concatenate([s.rows[:s.n_rows] for s in sm.db.segments])
    if len(retained) != sm.db.n_rows:
        raise SystemExit("segment rows disagree with db.n_rows")
    # minimal suffix: dropping the oldest retained segment must land below
    # the window (otherwise a clean expiry pass would have dropped it)
    if len(sm.db.segments) > 1 \
            and sm.db.n_rows - sm.db.segments[0].n_rows >= sspec.window_rows:
        raise SystemExit(
            f"window did not self-heal: {sm.db.n_rows} rows retained"
        )
    final = sm.mine(spec)
    oracle = mine_bruteforce(retained, n_items, final.min_count, max_k=spec.max_k)
    if final.itemsets != oracle:
        raise SystemExit("windowed mine diverged from the oracle under chaos")
    for q in standing:
        if q.latest != replay_diffs(q.diffs):
            raise SystemExit("standing answer inconsistent with replay")
    for r in queries:
        if hasattr(r, "itemsets") and r.n_rows == final.n_rows \
                and r.itemsets != final.itemsets:
            raise SystemExit("an interleaved query diverged at equal coverage")
    if inj.fired["stream.expire"] + inj.fired["stream.diff"] == 0:
        raise SystemExit("no continuous point ever fired; soak proved nothing")
    st = sm.stats
    print(
        f"continuous soak: {n_appends + 1} appends in {time.perf_counter() - t0:.1f}s"
        f" -> {resolved} futures resolved, {typed} typed failures, 0 orphans"
    )
    print(f"  injected: {dict(inj.fired)}  "
          f"expires={st['expires']} expire_errors={st['expire_errors']} "
          f"diffs={st['diffs_delivered']} diff_errors={st['diff_errors']}")
    print("continuous soak PASS: diffs replay exactly, window self-healed, "
          "answers bit-identical to the oracle")


def main(argv=None) -> None:
    modes = (argv if argv is not None else sys.argv[1:]) or [
        "soak", "overload", "continuous"]
    for m in modes:
        {"soak": soak, "overload": overload, "continuous": continuous}[m]()


if __name__ == "__main__":
    main()
