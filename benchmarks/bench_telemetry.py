"""Telemetry overhead bench: what observability costs the hot path.

The instrumentation contract (PR 10) is execution-orthogonal: histograms
and spans never touch plan/prep/snapshot keys, and with no trace recorder
attached a ``trace.span`` is one module-global read. This bench prices
that claim:

  telemetry_submit_bare          warm cached submit, registry counters on
                                 (they always are) but no trace recorder
                                 attached and no emitter running — the
                                 default serving configuration.
  telemetry_submit_instrumented  the same warm submit with a live
                                 ``TraceRecorder`` attached and a periodic
                                 ``StatsEmitter`` snapshotting the registry
                                 every 50ms — the fully-observed
                                 configuration. The note carries the
                                 relative overhead vs the bare row.
  telemetry_hist_record          per-call cost of ``LatencyHistogram
                                 .record`` (lock + bisect + bucket add),
                                 the primitive every instrumented layer
                                 pays per observation.
  telemetry_stats_snapshot       one full registry snapshot (what the
                                 emitter and ``stats()`` pay per tick).
"""
from __future__ import annotations

import io
import time

import numpy as np


def _pc() -> float:
    return time.perf_counter()


def _best(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = _pc()
        fn()
        best = min(best, _pc() - t0)
    return best


def run(quick: bool = False) -> list[tuple[str, float, str]]:
    from repro.data.synth import random_db
    from repro.mining import MineSpec, MiningEngine
    from repro.mining.telemetry import (
        LatencyHistogram, StatsEmitter, TraceRecorder, trace,
    )

    # dense enough that k>=2 waves really dispatch each submit (so the
    # instrumented row pays per-wave span cost, not just the null check)
    n_items = 16
    rows = random_db(np.random.default_rng(3), 600, n_items, 10)
    spec = MineSpec(algorithm="hprepost", max_k=4, candidate_unit=32, min_sup=0.1)
    reps = 30 if quick else 60
    out: list[tuple[str, float, str]] = []

    engine = MiningEngine()
    engine.submit(rows, n_items, spec)  # warmup: compile + prep cached

    # --- bare: the default configuration (no recorder, no emitter)
    t_bare = _best(lambda: engine.submit(rows, n_items, spec), reps)

    # --- instrumented: recorder attached + emitter ticking over the run
    rec = TraceRecorder()
    sink = io.StringIO()
    with StatsEmitter(engine.telemetry.snapshot, sink, interval_s=0.05), \
            trace.attached(rec):
        t_inst = _best(lambda: engine.submit(rows, n_items, spec), reps)
    over = t_inst / max(t_bare, 1e-9) - 1
    out.append((
        "telemetry_submit_bare", t_bare * 1e6,
        "warm cached submit, no recorder/emitter attached",
    ))
    out.append((
        "telemetry_submit_instrumented", t_inst * 1e6,
        f"tracer+50ms emitter attached overhead={100 * over:+.0f}% "
        f"spans={len(rec)}",
    ))

    # --- the per-observation primitive
    h = LatencyHistogram()
    n_rec = 50_000
    t0 = _pc()
    for _ in range(n_rec):
        h.record(1.3e-4)
    t_rec = (_pc() - t0) / n_rec
    out.append((
        "telemetry_hist_record", t_rec * 1e6,
        f"LatencyHistogram.record best-effort mean over {n_rec} calls",
    ))

    # --- one full registry snapshot (the per-tick emitter cost)
    t_snap = _best(engine.telemetry.snapshot, 200)
    n_hists = len(engine.telemetry.histograms())
    out.append((
        "telemetry_stats_snapshot", t_snap * 1e6,
        f"registry snapshot over {n_hists} histogram(s)",
    ))
    return out


if __name__ == "__main__":
    for name, us, note in run(quick=True):
        print(f"{name},{us:.0f},{note}")
