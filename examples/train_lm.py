"""End-to-end training driver: a small LM for a few hundred steps on CPU,
with checkpointing and an injected failure + automatic restart.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="tinyllama_1_1b")
    args = ap.parse_args()
    hist = train_main([
        "--arch", args.arch, "--reduced",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "128",
        "--ckpt-dir", "/tmp/repro_train_example",
        "--ckpt-every", "50",
        "--inject-failure-at", str(args.steps // 2),  # survives a mid-run failure
    ])
    assert hist[-1]["loss"] < hist[0]["loss"], "loss must improve"
    print("OK: loss improved and the run survived an injected failure.")
