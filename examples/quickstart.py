"""Quickstart: the paper's pipeline end-to-end in ~40 lines.

  PYTHONPATH=src python examples/quickstart.py

1. Builds the paper's Table-1 database.
2. Shows the PPC-tree/N-lists from the paper's Fig. 2.
3. Mines it through the unified ``repro.mining`` front-door: one MineSpec,
   every algorithm (the distributed HPrepost contribution and the host
   baselines), one enriched MineResult each — all cross-checked.
4. Runs the paper's experimental surface — a threshold sweep — through the
   engine's planned path: prepare() once at the loosest threshold,
   mine_prepared() per threshold.
5. Shows the persistent PreparedDB cache: ad-hoc submits after the sweep
   re-run zero prep stages (engine.cache_info() tells the story).
"""
from repro.core import encoding as enc
from repro.core.ppc import build_ppc
from repro.mining import MineSpec, MiningEngine, mine

# Paper Table 1 (a=0 b=1 c=2 d=3 e=4 f=5 g=6)
TX = [[0, 1, 6], [1, 2, 3, 5, 6], [0, 1, 4], [0, 3], [1, 2, 4], [0, 3, 4, 5], [1, 2]]
NAMES = "abcdefg"

rows = enc.pad_transactions(TX)
spec = MineSpec(algorithm="hprepost", min_count=3, candidate_unit=4)
# paper Example 1: threshold 3 of 7 transactions; a fraction spec resolves
# to the same count through MineSpec.resolve (the one conversion site).
assert spec.resolve(len(rows)) == MineSpec(min_sup=3 / 7).resolve(len(rows)) == 3

# --- the PPC-tree + N-lists of Fig. 1/2 --------------------------------
fl = enc.build_flist(enc.item_support(rows, 7), spec.resolve(len(rows)))
print("F-list:", [(NAMES[i], int(s)) for i, s in zip(fl.items, fl.supports)])
urows, w = enc.dedup_rows(enc.rank_encode(rows, fl))
tree = build_ppc(urows, w)
for rank, nl in enumerate(tree.nlists(fl.k)):
    item = NAMES[fl.items[rank]]
    codes = " ".join(f"({p},{q}):{c}" for p, q, c in nl)
    print(f"  N-list({item}) = {codes}")

# --- one front-door, every miner ---------------------------------------
res = mine(rows, 7, spec)  # the paper's distributed HPrepost
ref = mine(rows, 7, spec.with_(algorithm="prepost"))  # host baseline
assert res.itemsets == ref.itemsets
print(f"\n{res.summary()}")
print(f"stage times: " + ", ".join(f"{k} {v * 1e3:.1f}ms" for k, v in res.stage_times_s.items()))
print("frequent itemsets (HPrepost == PrePost):")
for items, sup in sorted(res.itemsets.items()):
    print(f"  {{{','.join(NAMES[i] for i in items)}}}: {sup}")

# --- derived pattern families (closed/maximal/top-rank-k post-passes) ---
closed = mine(rows, 7, spec.with_(algorithm="prepost", patterns="closed"))
print(f"closed itemsets: {len(closed.itemsets)} of {closed.total_count} frequent")

# --- the paper's x-axis: a planned threshold sweep -----------------------
# engine.sweep groups the thresholds over one database: Job 1 (histogram),
# Job 2 (PPC-tree), the N-list pack, and the F2 scan run ONCE at the
# loosest threshold; every min_sup is then served from the shared
# PreparedDB by the k>2 wave loop alone. min_sup resolves with ceiling
# semantics: an itemset is frequent iff support/n_rows >= min_sup.
engine = MiningEngine()
fracs = [4 / 7, 3 / 7, 2 / 7]
swept = engine.sweep(rows, 7, spec, fracs)
counters = engine.frontend("hprepost").miner_for(spec).stage_counters
assert counters["job1"] == counters["job2"] == counters["f2"] == 1
print(f"\nplanned sweep over min_sup={[f'{f:.2f}' for f in fracs]} "
      f"(prep ran once, {engine.stats['prepared_mines']} prepared mines):")
for frac, res in zip(fracs, swept):
    assert res.itemsets == mine(rows, 7, spec.with_(min_sup=frac)).itemsets
    tag = " [shared prep]" if res.prep_shared else ""
    print(f"  min_sup={frac:.2f} (min_count={res.min_count}): "
          f"{res.total_count} itemsets{tag}")

# --- persistent PreparedDB cache ----------------------------------------
# the sweep's PreparedDB stays resident (LRU under prep_cache_bytes), so an
# ad-hoc submit at any tighter-or-equal threshold re-runs ZERO prep stages:
adhoc = engine.submit(rows, 7, spec)
assert adhoc.prep_shared and counters["job1"] == 1  # no prep re-run
info = engine.cache_info()
print(f"\ncache after ad-hoc resubmit: {info['hits']} hit(s), "
      f"{info['misses']} miss(es), {info['entries']} entr(ies), "
      f"{info['bytes_in_use']}B of {info['byte_budget']}B budget")
