"""Quickstart: the paper's pipeline end-to-end in ~40 lines.

  PYTHONPATH=src python examples/quickstart.py

1. Builds the paper's Table-1 database.
2. Runs the full HPrepost pipeline (Job-1 count -> F-list -> Job-2 PPC-tree
   -> N-lists -> mining waves) on a JAX mesh.
3. Cross-checks against the single-shard PrePost miner and shows the
   PP-codes from the paper's Fig. 2.
"""
import jax
from jax.sharding import AxisType

from repro.core import encoding as enc
from repro.core.hprepost import HPrepostConfig, HPrepostMiner
from repro.core.ppc import build_ppc
from repro.core.prepost import mine_prepost

# Paper Table 1 (a=0 b=1 c=2 d=3 e=4 f=5 g=6)
TX = [[0, 1, 6], [1, 2, 3, 5, 6], [0, 1, 4], [0, 3], [1, 2, 4], [0, 3, 4, 5], [1, 2]]
NAMES = "abcdefg"

rows = enc.pad_transactions(TX)
min_count = 3  # min-sup = 0.3 over 7 transactions, paper Example 1

# --- the PPC-tree + N-lists of Fig. 1/2 --------------------------------
fl = enc.build_flist(enc.item_support(rows, 7), min_count)
print("F-list:", [(NAMES[i], int(s)) for i, s in zip(fl.items, fl.supports)])
urows, w = enc.dedup_rows(enc.rank_encode(rows, fl))
tree = build_ppc(urows, w)
for rank, nl in enumerate(tree.nlists(fl.k)):
    item = NAMES[fl.items[rank]]
    codes = " ".join(f"({p},{q}):{c}" for p, q, c in nl)
    print(f"  N-list({item}) = {codes}")

# --- distributed HPrepost on a mesh -------------------------------------
mesh = jax.make_mesh((1, 1), ("data", "model"), axis_types=(AxisType.Auto,) * 2)
miner = HPrepostMiner(mesh, config=HPrepostConfig(candidate_unit=4))
res = miner.mine(rows, 7, min_count)
ref = mine_prepost(rows, 7, min_count)
assert res.itemsets == ref.itemsets
print("\nfrequent itemsets (HPrepost == PrePost):")
for items, sup in sorted(res.itemsets.items()):
    print(f"  {{{','.join(NAMES[i] for i in items)}}}: {sup}")
