"""The paper's technique as a data-pipeline feature: mine frequent token
n-gram itemsets from the LM training corpus with distributed HPrepost.

  PYTHONPATH=src python examples/mine_corpus.py

The synthetic corpus injects known 4-token phrases; the miner must surface
them as high-support 4-itemsets — the corpus-statistics workflow (vocabulary
analysis / data curation) this framework runs between training epochs. Runs
through a ``MiningEngine`` session, the shape production traffic uses.
"""
from repro.data import corpus
from repro.mining import MineSpec, MiningEngine

VOCAB = 512
toks = corpus.token_stream(120_000, VOCAB, seed=3, n_phrases=6, phrase_len=4, phrase_rate=0.2)
rows = corpus.ngram_transactions(toks, window=8, stride=4)
print(f"corpus: {len(toks)} tokens -> {len(rows)} window transactions")

engine = MiningEngine()  # default 1x1 (data, model) mesh; pass a real mesh to scale
res = engine.submit(rows, VOCAB, MineSpec(algorithm="hprepost", min_sup=0.02, max_k=4))

four = res.by_size(4)
print(f"{res.summary()}; {len(four)} of size 4 — the injected phrases:")
for items, sup in sorted(four.items(), key=lambda kv: -kv[1])[:8]:
    print(f"  {items}: support {sup}")
assert len(four) >= 4, "expected the injected phrases to be recovered"
