"""The paper's technique as a data-pipeline feature: mine frequent token
n-gram itemsets from the LM training corpus with distributed HPrepost.

  PYTHONPATH=src python examples/mine_corpus.py

The synthetic corpus injects known 4-token phrases; the miner must surface
them as high-support 4-itemsets — the corpus-statistics workflow (vocabulary
analysis / data curation) this framework runs between training epochs.
"""
import numpy as np
import jax
from jax.sharding import AxisType

from repro.core.hprepost import HPrepostConfig, HPrepostMiner
from repro.data import corpus

VOCAB = 512
toks = corpus.token_stream(120_000, VOCAB, seed=3, n_phrases=6, phrase_len=4, phrase_rate=0.2)
rows = corpus.ngram_transactions(toks, window=8, stride=4)
print(f"corpus: {len(toks)} tokens -> {len(rows)} window transactions")

mesh = jax.make_mesh((1, 1), ("data", "model"), axis_types=(AxisType.Auto,) * 2)
miner = HPrepostMiner(mesh, config=HPrepostConfig(max_k=4))
min_count = int(0.02 * len(rows))
res = miner.mine(rows, VOCAB, min_count)

four = {k: v for k, v in res.itemsets.items() if len(k) == 4}
print(f"{res.total_count} frequent itemsets (min_count={min_count}); "
      f"{len(four)} of size 4 — the injected phrases:")
for items, sup in sorted(four.items(), key=lambda kv: -kv[1])[:8]:
    print(f"  {items}: support {sup}")
assert len(four) >= 4, "expected the injected phrases to be recovered"
