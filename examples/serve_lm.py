"""Batched serving example: prefill + greedy decode over a static KV cache.

  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    out = serve_main(["--arch", "qwen1_5_0_5b", "--reduced", "--batch", "4",
                      "--max-seq", "96", "--max-new", "12", "--requests", "6"])
    assert all(len(r.out) == 12 for r in out)
    print("OK: 6 requests served in 2 static-batch waves.")
