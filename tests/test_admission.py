"""Admission control + QoS: bounded queues, typed errors, deadlines,
priorities, and the stats() operator surface.

Anchors: a full queue rejects with ``Overloaded`` immediately (never
buffers), byte budgets count in-flight work, shedding is strictly
oldest-deadline-first and only in favor of later deadlines, expired
requests resolve with ``DeadlineExceeded`` before device work, priority
reorders group service, and every counter the ISSUE names is visible
through ``MiningService.stats()``.
"""
import time

import numpy as np
import pytest

from repro.data.synth import random_db
from repro.mining import MineSpec, MiningEngine
from repro.mining.service import MiningService
from repro.mining.service.admission import (
    AdmissionQueue, DeadlineExceeded, Overloaded, ServiceClosed,
)

SPEC = MineSpec(algorithm="hprepost", max_k=4, candidate_unit=8, min_sup=0.3,
                nlist_width=16)


def _db(seed=0, n_tx=60, n_items=10):
    return random_db(np.random.default_rng(seed), n_tx, n_items, 6), n_items


class _Item:
    def __init__(self, nbytes=0, deadline_at=None):
        self.nbytes = nbytes
        self.deadline_at = deadline_at


# --------------------------------------------------------- AdmissionQueue
def test_depth_bound_rejects_without_shedding_no_deadlines():
    q = AdmissionQueue(max_depth=2)
    assert q.offer(_Item())[0] and q.offer(_Item())[0]
    admitted, shed = q.offer(_Item())
    assert not admitted and shed == []
    assert q.counters == {"admitted": 2, "rejected": 1, "shed": 0}
    assert q.depth == 2


def test_byte_budget_counts_in_flight_until_release():
    q = AdmissionQueue(max_bytes=100)
    a = _Item(nbytes=60)
    assert q.offer(a)[0]
    assert q.get(0.1) is a  # popped off the queue, still in flight
    assert not q.offer(_Item(nbytes=60))[0]  # 60 in flight + 60 > 100
    q.release(a.nbytes)
    assert q.offer(_Item(nbytes=60))[0]


def test_shed_oldest_deadline_first_in_favor_of_later():
    now = time.monotonic()
    q = AdmissionQueue(max_depth=2)
    early = _Item(deadline_at=now + 1.0)
    late = _Item(deadline_at=now + 5.0)
    assert q.offer(early)[0] and q.offer(late)[0]
    # incoming deadline later than the earliest queued -> evict `early`
    incoming = _Item(deadline_at=now + 9.0)
    admitted, shed = q.offer(incoming)
    assert admitted and shed == [early]
    # incoming with the EARLIEST deadline cannot shed anyone -> rejected
    admitted, shed = q.offer(_Item(deadline_at=now + 0.5))
    assert not admitted and shed == []
    # no-deadline incoming never sheds no-deadline queue, but queued
    # deadlines are "older" than infinity -> they are sheddable
    admitted, shed = q.offer(_Item())
    assert admitted and shed == [late]
    assert q.counters["shed"] == 2


def test_byte_shedding_reclaims_victim_bytes():
    now = time.monotonic()
    q = AdmissionQueue(max_bytes=100)
    victim = _Item(nbytes=80, deadline_at=now + 1.0)
    assert q.offer(victim)[0]
    admitted, shed = q.offer(_Item(nbytes=90, deadline_at=now + 9.0))
    assert admitted and shed == [victim]
    assert q.bytes_in_flight == 90


def test_queue_validates_budgets():
    with pytest.raises(ValueError):
        AdmissionQueue(max_depth=0)
    with pytest.raises(ValueError):
        AdmissionQueue(max_bytes=0)


# -------------------------------------------------------------- MineSpec
def test_spec_validates_deadline():
    with pytest.raises(ValueError):
        MineSpec(min_sup=0.3, deadline_s=0.0)
    s = MineSpec(min_sup=0.3, deadline_s=2.5, priority=3)
    assert s.deadline_s == 2.5 and s.priority == 3


def test_qos_fields_do_not_perturb_prep_keys():
    eng = MiningEngine()
    fe = eng.frontend("hprepost")
    assert fe._prep_config(SPEC) == fe._prep_config(
        SPEC.with_(priority=9, deadline_s=60.0)
    )


# --------------------------------------------------------------- service
def test_service_overload_resolves_future_with_typed_error():
    rows, n_items = _db(0)
    # depth 1 + a long batch window: the first submit occupies the queue
    # until the worker collects it; meanwhile flood past the bound
    with MiningService(batch_window_s=0.5, max_queue_depth=1) as svc:
        futs = [svc.submit(rows, n_items, SPEC) for _ in range(6)]
        done = [f.result() if not f.exception() else f.exception() for f in futs]
    overloads = [r for r in done if isinstance(r, Overloaded)]
    served = [r for r in done if not isinstance(r, BaseException)]
    assert len(served) >= 1 and len(overloads) >= 1
    assert len(served) + len(overloads) == 6
    info = svc.stats()["admission"]
    assert info["rejected"] == len(overloads)
    assert svc.stats["requests"] == len(served)  # accepted only


def test_service_byte_budget_rejects_big_requests():
    rows, n_items = _db(0)
    tiny = int(np.asarray(rows).nbytes) - 1
    with MiningService(max_queue_bytes=tiny) as svc:
        fut = svc.submit(rows, n_items, SPEC)
        with pytest.raises(Overloaded) as ei:
            fut.result(timeout=5)
        assert ei.value.shed is False
    assert svc.stats()["counters"]["rejected"] == 1


def test_service_deadline_exceeded_before_work():
    rows, n_items = _db(0)
    with MiningService(batch_window_s=0.0) as svc:
        # warm the prep so timing is stable, then submit an already-tight
        # deadline: it expires during the batch window / queue wait
        svc.submit(rows, n_items, SPEC).result(timeout=120)
        fut = svc.submit(rows, n_items, SPEC.with_(deadline_s=1e-6))
        time.sleep(0.01)
        with pytest.raises(DeadlineExceeded):
            fut.result(timeout=30)
    assert svc.stats()["counters"]["deadline_dropped"] == 1


def test_service_priority_orders_groups():
    rows_a, n_items = _db(0)
    rows_b, _ = _db(1)
    with MiningService(batch_window_s=0.25) as svc:
        futs = [
            svc.submit(rows_a, n_items, SPEC),  # priority 0
            svc.submit(rows_b, n_items, SPEC.with_(priority=5)),
        ]
        for f in futs:
            f.result(timeout=300)
    assert svc.scheduler.stats["priority_reordered"] >= 1


def test_priority_order_is_stable_for_equal_priorities():
    rows_a, n_items = _db(0)
    rows_b, _ = _db(1)
    with MiningService(batch_window_s=0.25) as svc:
        futs = [svc.submit(rows_a, n_items, SPEC), svc.submit(rows_b, n_items, SPEC)]
        for f in futs:
            f.result(timeout=300)
    assert svc.scheduler.stats["priority_reordered"] == 0


def test_stats_is_dict_and_callable_with_issue_counters():
    with MiningService() as svc:
        assert svc.stats["requests"] == 0  # historical dict surface intact
        snap = svc.stats()
    for key in ("admitted", "rejected", "shed", "deadline_dropped",
                "retries", "respawns"):
        assert key in snap["counters"], key
    for section in ("service", "admission", "scheduler", "engine", "streams"):
        assert section in snap, section


def test_submit_after_close_raises_typed_error():
    svc = MiningService()
    svc.close()
    with pytest.raises(ServiceClosed):
        svc.submit(*_db(0)[0:1], 10, SPEC)
