"""hlo_cost rollup validated against analytically-known workloads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import rollup
from repro.launch.hlo_analysis import collective_bytes


def test_scan_matmul_flops_exact():
    def scanned(x, ws):
        def body(c, w):
            return c @ w, None
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((12, 256, 256), jnp.float32)
    pc = rollup(jax.jit(scanned).lower(x, ws).compile().as_text())
    want = 12 * 2 * 256**3
    assert abs(pc.flops / want - 1.0) < 0.02, (pc.flops, want)


def test_nested_scan_multiplies():
    def nested(x, ws):
        def outer(c, wg):
            def inner(c2, w):
                return c2 @ w, None
            return jax.lax.scan(inner, c, wg)[0], None
        return jax.lax.scan(outer, x, ws)[0]

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 4, 128, 128), jnp.float32)
    pc = rollup(jax.jit(nested).lower(x, ws).compile().as_text())
    want = 12 * 2 * 128**3
    assert abs(pc.flops / want - 1.0) < 0.05, (pc.flops, want)


def test_collectives_inside_scan_multiplied():
    from jax.sharding import PartitionSpec as P

    from repro.compat import make_mesh, shard_map

    mesh = make_mesh((1,), ("data",))

    def f(x, ws):
        def inner(x, ws):
            def body(c, w):
                return jax.lax.psum(c @ w, "data"), None
            return jax.lax.scan(body, x, ws)[0]
        return shard_map(inner, mesh=mesh, in_specs=(P(), P()), out_specs=P())(x, ws)

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((6, 128, 128), jnp.float32)
    pc = rollup(jax.jit(f).lower(x, ws).compile().as_text())
    want_payload = 6 * 128 * 128 * 4
    got = sum(pc.collectives.values())
    assert abs(got / want_payload - 1.0) < 0.02, (got, want_payload)
    assert pc.wire_bytes == pytest.approx(2 * want_payload, rel=0.02)  # ring all-reduce


def test_bytes_slice_fusion_not_whole_operand():
    """Reading a (L, n, n) stacked array via per-step dynamic-slice must cost
    ~L·n², not L·(L·n²)."""
    def scanned(x, ws):
        def body(c, w):
            return c * 0.5 + w, None
        return jax.lax.scan(body, x, ws)[0]

    n, L = 512, 16
    x = jax.ShapeDtypeStruct((n, n), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, n, n), jnp.float32)
    pc = rollup(jax.jit(scanned).lower(x, ws).compile().as_text())
    slice_traffic = L * n * n * 4
    assert pc.hbm_bytes < 8 * slice_traffic, (pc.hbm_bytes, slice_traffic)
    assert pc.hbm_bytes > slice_traffic  # but not under-counted either


def test_collective_bytes_text_parser_agrees():
    """The simple text parser (used for reference) sees the same op types."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import make_mesh, shard_map

    mesh = make_mesh((1,), ("data",))

    def f(x):
        return shard_map(
            lambda x: jax.lax.psum(x, "data"),
            mesh=mesh, in_specs=P("data", None), out_specs=P(),
        )(x)

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    txt = jax.jit(f).lower(x).compile().as_text()
    cb = collective_bytes(txt)
    assert cb["all-reduce"] > 0 or cb["all-gather"] > 0
