"""Shared-work sweep planning + threshold-semantics regressions.

The engine's ``sweep``/``submit_many`` group hprepost requests by
(database fingerprint, device config), run Job 1 / Job 2 / pack / F2 once
at the group's loosest threshold, and serve every threshold from the
shared ``PreparedDB``. The correctness anchor: planned results are
itemset-identical to independent ``submit`` calls per threshold.
"""
import numpy as np
import pytest

from repro.core.encoding import pad_transactions
from repro.data.synth import random_db
from repro.mining import MineRequest, MineSpec, MiningEngine, list_miners, mine

SPEC = MineSpec(algorithm="hprepost", max_k=5, candidate_unit=8, min_sup=0.5)


def _db(seed=0, n_tx=60, n_items=10):
    return random_db(np.random.default_rng(seed), n_tx, n_items, 6), n_items


# ------------------------------------------------------- planned sweeps
def test_sweep_runs_prep_once_and_matches_independent_mines():
    rows, n_items = _db()
    eng = MiningEngine()
    fracs = [0.4, 0.25, 0.1]
    sweep = eng.sweep(rows, n_items, SPEC, fracs)

    # the acceptance criterion: one 3-threshold sweep, each prep stage once
    counters = eng.frontend("hprepost").miner_for(SPEC).stage_counters
    assert counters["job1"] == 1
    assert counters["job2"] == 1
    assert counters["pack"] == 1
    assert counters["f2"] == 1
    assert eng.stats["prepares"] == 1 and eng.stats["prepared_mines"] == 3
    assert eng.miners_built == 1  # one resident device miner served the sweep

    # parity anchor: the planned path == independent mine() per threshold
    fresh = MiningEngine()
    for res, frac in zip(sweep, fracs):
        ind = fresh.submit(rows, n_items, SPEC.with_(min_sup=frac))
        assert res.itemsets == ind.itemsets
        assert res.min_count == ind.min_count
        assert res.total_count == ind.total_count


def test_sweep_attributes_shared_prep_honestly():
    rows, n_items = _db(1)
    eng = MiningEngine()
    sweep = eng.sweep(rows, n_items, SPEC, [0.3, 0.2, 0.1])
    prep_keys = ("job1_flist", "job2_ppc_pack", "f2_scan")
    payer, shared = sweep[0], sweep[1:]
    assert not payer.prep_shared
    assert sum(payer.stage_times_s[k] for k in prep_keys) > 0
    for res in shared:
        assert res.prep_shared
        for k in prep_keys:  # stable keys, zero cost: prep was not re-run
            assert res.stage_times_s[k] == 0.0
        assert "mining_waves" in res.stage_times_s


def test_submit_many_groups_by_database_content_and_config():
    rows_a, n_items = _db(0)
    rows_b, _ = _db(1)
    eng = MiningEngine()
    reqs = [
        MineRequest(rows_a, n_items, SPEC.with_(min_sup=0.3)),
        MineRequest(rows_b, n_items, SPEC.with_(min_sup=0.3)),  # other db: no group
        MineRequest(rows_a, n_items, MineSpec(algorithm="prepost", min_sup=0.3)),
        MineRequest(rows_a, n_items, SPEC.with_(min_sup=0.15)),
        # same content, different array object: fingerprint still groups it
        MineRequest(rows_a.copy(), n_items, SPEC.with_(min_sup=0.5)),
    ]
    out = eng.submit_many(reqs)
    assert eng.stats["prepares"] == 1  # only the 3-request rows_a group
    assert eng.stats["prepared_mines"] == 3
    assert eng.stats["submits"] == len(reqs)

    fresh = MiningEngine()
    for i in (0, 1, 3, 4):
        r = reqs[i]
        assert out[i].itemsets == fresh.submit(r.rows, r.n_items, r.spec).itemsets
    assert out[2].itemsets == out[0].itemsets  # prepost agrees with hprepost
    assert [r.algorithm for r in out] == ["hprepost"] * 2 + ["prepost"] + ["hprepost"] * 2


def test_group_of_max_k_one_requests_skips_tree_build():
    rows, n_items = _db(2)
    eng = MiningEngine()
    spec1 = SPEC.with_(max_k=1)
    out = eng.submit_many([
        MineRequest(rows, n_items, spec1.with_(min_sup=0.3)),
        MineRequest(rows, n_items, spec1.with_(min_sup=0.2)),
    ])
    counters = eng.frontend("hprepost").miner_for(spec1).stage_counters
    assert counters["job1"] == 1 and counters["job2"] == 0 and counters["f2"] == 0
    for res in out:
        assert res.itemsets and all(len(s) == 1 for s in res.itemsets)
        assert res.peak_bytes > 0  # real sharded-rows/F-list footprint


def test_mine_prepared_rejects_looser_threshold_than_floor():
    from repro.mining.miners import default_mesh
    from repro.core.hprepost import HPrepostConfig, HPrepostMiner

    rows, n_items = _db(3)
    miner = HPrepostMiner(default_mesh(), config=HPrepostConfig(candidate_unit=8))
    prepared = miner.prepare(rows, n_items, 10)
    with pytest.raises(ValueError, match="floor"):
        miner.mine_prepared(prepared, 5)


def test_pipelined_waves_match_sequential_loop():
    from repro.mining.miners import default_mesh
    from repro.core.hprepost import HPrepostConfig, HPrepostMiner

    mesh = default_mesh()
    pipelined = HPrepostMiner(mesh, config=HPrepostConfig(candidate_unit=8))
    sequential = HPrepostMiner(
        mesh, config=HPrepostConfig(candidate_unit=8, pipeline_waves=False)
    )
    for seed in (0, 4):
        rows, n_items = _db(seed, n_tx=80, n_items=12)
        a = pipelined.mine(rows, n_items, 2)
        b = sequential.mine(rows, n_items, 2)
        assert a.itemsets == b.itemsets


# ------------------------------------------- threshold-semantics bugfixes
def test_resolve_uses_ceiling_semantics():
    assert MineSpec(min_sup=0.25).resolve(10) == 3  # flooring admitted 0.2 < 0.25
    assert MineSpec(min_sup=0.3).resolve(1000) == 300  # exact fractions stay exact
    assert MineSpec(min_sup=3 / 7).resolve(7) == 3  # float noise just above an int
    assert MineSpec(min_sup=0.5).resolve(7) == 4
    assert MineSpec(min_sup=1.0).resolve(9) == 9
    assert MineSpec(min_sup=1e-9).resolve(10) == 1  # still floors at 1


@pytest.mark.parametrize("algo", list_miners())
def test_min_sup_boundary_excluded_across_miners(algo):
    # 10 rows: item 0 in 2 (fraction 0.2), item 1 in 3 (0.3), item 2 in 7
    tx = [[0, 1], [0, 1], [1]] + [[2]] * 7
    rows = pad_transactions(tx)
    res = mine(rows, 3, MineSpec(algorithm=algo, min_sup=0.25, candidate_unit=8))
    assert res.min_count == 3  # ceil(0.25 * 10), not int(...) == 2
    assert (1,) in res.itemsets and (0,) not in res.itemsets
    assert all(sup / 10 >= 0.25 for sup in res.itemsets.values())


def test_with_cannot_silently_clear_the_threshold():
    spec = MineSpec(min_sup=0.3)
    with pytest.raises(ValueError, match="threshold"):
        spec.with_(min_sup=None)
    with pytest.raises(ValueError, match="threshold"):
        MineSpec(min_count=3).with_(min_count=None)
    # switching kinds still works, including the explicit two-key form
    assert spec.with_(min_count=3).min_sup is None
    assert spec.with_(min_sup=None, min_count=3).resolve(10) == 3
    assert MineSpec(min_count=3).with_(min_sup=0.5).resolve(10) == 5
    # a spec that never had a threshold may keep not having one
    assert MineSpec().with_(backend="jnp").min_sup is None


# --------------------------------------- per-threshold result attribution
def test_sweep_results_stay_threshold_dependent():
    rows, n_items = _db()
    eng = MiningEngine()
    loose, tight = eng.sweep(rows, n_items, SPEC, [0.15, 0.45])
    # memory figures must not flatten at the sweep floor: the tight
    # threshold's footprint is the F-list/N-list prefix it actually uses
    assert 0 < tight.peak_bytes < loose.peak_bytes
    # flist_items is the request's own F1, not the shared floor F-list
    ind = MiningEngine().submit(rows, n_items, SPEC.with_(min_sup=0.45))
    assert list(tight.flist_items) == list(ind.flist_items)
    assert len(tight.flist_items) == sum(1 for s in tight.itemsets if len(s) == 1)


def test_group_floor_tripping_max_f1_degrades_to_per_request():
    # items 0-5 in 8/10 rows, items 6-9 in 2/10: the loose threshold's
    # F-list (K=10) trips max_f1=6, the tight one (K=6) is fine
    tx = [[0, 1, 2, 3, 4, 5]] * 8 + [[6, 7, 8, 9]] * 2
    rows = pad_transactions(tx)
    eng = MiningEngine()
    spec = SPEC.with_(max_f1=6)
    ok = eng.submit(rows, 10, spec.with_(min_sup=0.5))
    assert ok.itemsets
    # planned prep at the floor would fail the whole group; the engine must
    # fall back to per-request mining so the error stays per-request
    with pytest.raises(ValueError, match="max_f1"):
        eng.sweep(rows, 10, spec, [0.5, 0.2])
    assert eng.stats["prepares"] == 0  # no shared prep was recorded
    # a feasible group afterwards is served without re-running prep: the
    # ad-hoc submit above already paid for this floor, and its PreparedDB
    # sits in the engine's persistent cache
    j1 = eng.frontend("hprepost").miner_for(spec).stage_counters["job1"]
    swept = eng.sweep(rows, 10, spec, [0.5, 0.6])
    assert eng.frontend("hprepost").miner_for(spec).stage_counters["job1"] == j1
    assert eng.cache_info()["hits"] >= 1
    assert swept[0].itemsets == ok.itemsets


def test_f2_counter_only_counts_dispatched_scans():
    from repro.mining.miners import default_mesh
    from repro.core.hprepost import HPrepostConfig, HPrepostMiner

    tx = [[0]] * 9 + [[1]]  # exactly one item survives the floor threshold
    rows = pad_transactions(tx)
    miner = HPrepostMiner(default_mesh(), config=HPrepostConfig(candidate_unit=8))
    miner.prepare(rows, 2, 5)
    assert miner.stage_counters["job1"] == 1
    assert miner.stage_counters["job2"] == 1
    assert miner.stage_counters["f2"] == 0  # K == 1: no F2 scan dispatched


# ------------------------------------------------- early-return telemetry
def test_high_threshold_early_return_reports_real_footprint():
    rows, n_items = _db(5)
    stage_keys = ("job1_flist", "job2_ppc_pack", "f2_scan", "mining_waves")

    # threshold above every support: |F1| == 0, but memory must not read 0
    res = mine(rows, n_items, MineSpec(
        algorithm="hprepost", min_count=len(rows) + 1, candidate_unit=8))
    assert res.itemsets == {} and res.total_count == 0
    assert res.peak_bytes > 0
    for k in stage_keys:
        assert k in res.stage_times_s

    # max_k == 1 early return: F-list only, same stable keys + real footprint
    res1 = mine(rows, n_items, MineSpec(
        algorithm="hprepost", min_sup=0.2, max_k=1, candidate_unit=8))
    assert res1.itemsets and all(len(s) == 1 for s in res1.itemsets)
    assert res1.peak_bytes > 0
    for k in stage_keys:
        assert k in res1.stage_times_s
