"""Backend registry + kernel-plan autotuner (repro.mining.tune):
resolution rules, MineSpec validation at the resolve() choke point, plan
persistence (cold search -> kernel_plans.json -> warm zero-trial load),
and shape bucketing."""
import json
import os

import pytest

from repro.mining import MineSpec
from repro.mining.tune import (
    PLANS_FILENAME,
    PLANS_SCHEMA,
    KernelPlan,
    KernelTuner,
    _bucket,
    registered_backends,
    resolve_backend,
    static_plan,
)


# ------------------------------------------------------------- the registry
def test_registry_resolution_on_cpu():
    # conftest pins JAX_PLATFORMS=cpu, so "auto" must take the jnp path and
    # "pallas" must fall back to the interpreter
    assert resolve_backend("auto") == "jnp"
    assert resolve_backend("jnp") == "jnp"
    assert resolve_backend("pallas") == "pallas-interpret"
    assert resolve_backend("pallas-interpret") == "pallas-interpret"


def test_registry_resolution_per_platform():
    assert resolve_backend("auto", "tpu") == "pallas-tpu"
    assert resolve_backend("auto", "gpu") == "pallas-gpu"
    assert resolve_backend("pallas", "tpu") == "pallas-tpu"
    assert resolve_backend("pallas-tpu", "tpu") == "pallas-tpu"
    assert resolve_backend("jnp", "tpu") == "jnp"


def test_platform_locked_backends_raise_elsewhere():
    with pytest.raises(ValueError, match="not available on platform"):
        resolve_backend("pallas-tpu", "cpu")
    with pytest.raises(ValueError, match="not available on platform"):
        resolve_backend("pallas-gpu", "tpu")


def test_unknown_backend_raises_with_registered_list():
    with pytest.raises(ValueError) as e:
        resolve_backend("cuda")
    for name in registered_backends():
        assert name in str(e.value)


def test_minespec_validates_backend_at_resolve():
    """S2: the resolve() choke point rejects unknown names before any
    device work, naming every registered backend."""
    spec = MineSpec(algorithm="hprepost", min_sup=0.5, backend="no-such-backend")
    with pytest.raises(ValueError, match="registered backends"):
        spec.resolve(10)
    # every registered name passes the same gate
    for name in registered_backends():
        assert MineSpec(min_sup=0.5, backend=name).resolve(10) == 5


# ------------------------------------------------------------------ buckets
def test_bucket_next_pow2_clamped():
    assert _bucket(1, 8, 512) == 8
    assert _bucket(8, 8, 512) == 8
    assert _bucket(9, 8, 512) == 16
    assert _bucket(500, 8, 512) == 512
    assert _bucket(5000, 8, 512) == 512
    assert _bucket(0, 8, 1024) == 8


# -------------------------------------------------------------------- plans
def test_static_plan_resolves_backend():
    plan = static_plan("auto", 128, 256, 4, True, platform="cpu")
    assert plan == KernelPlan("jnp", 128, 256, 4, True, "config")
    assert static_plan("pallas", 64, 64, 2, False, platform="cpu").backend == (
        "pallas-interpret"
    )


def test_tuner_cold_search_then_warm_zero_trials(tmp_path):
    """The tune-smoke contract as a unit test: a cold tuner times a search
    and persists the winner; a fresh tuner on the same dir serves the plan
    with zero trials; an in-memory re-ask is a plan hit either way."""
    d = str(tmp_path)
    t1 = KernelTuner(plan_dir=d)
    p1 = t1.plan_for(backend="jnp", B=8, W=16, early_stop=True)
    assert p1.source == "tuned" and p1.backend == "jnp"
    assert t1.stats["trials"] > 0 and t1.stats["tuned"] == 1
    path = os.path.join(d, PLANS_FILENAME)
    assert os.path.exists(path)
    with open(path) as f:
        doc = json.load(f)
    assert doc["schema"] == PLANS_SCHEMA and len(doc["plans"]) == 1

    # same bucketed shape from memory: a hit, no new search
    p1b = t1.plan_for(backend="jnp", B=8, W=16, early_stop=True)
    assert p1b.source == "cached" and t1.stats["tuned"] == 1

    t2 = KernelTuner(plan_dir=d)
    assert t2.stats["loaded_plans"] == 1
    p2 = t2.plan_for(backend="jnp", B=8, W=16, early_stop=True)
    assert t2.stats["trials"] == 0 and t2.stats["plan_hits"] == 1
    assert (p2.la_block, p2.ly_block, p2.batch_block) == (
        p1.la_block, p1.ly_block, p1.batch_block)
    assert p2.source == "cached"


def test_tuner_tune_false_returns_config_defaults(tmp_path):
    t = KernelTuner(plan_dir=str(tmp_path))
    p = t.plan_for(backend="pallas-interpret", B=4, W=16, early_stop=False,
                   defaults=(64, 32, 2), tune=False)
    assert p == KernelPlan("pallas-interpret", 64, 32, 2, False, "config")
    assert t.stats["trials"] == 0 and not t._plans


def test_tuner_ignores_foreign_schema(tmp_path):
    path = os.path.join(str(tmp_path), PLANS_FILENAME)
    with open(path, "w") as f:
        json.dump({"schema": PLANS_SCHEMA + 1, "plans": {"x": {}}}, f)
    t = KernelTuner(plan_dir=str(tmp_path))
    assert t.stats["loaded_plans"] == 0


def test_tuner_keys_split_by_backend_shape_and_early_stop():
    t = KernelTuner()
    k = t._key("jnp", B=100, W=300, early_stop=True)
    assert k == f"jnp|{t._platform}|es1|W512|B128"
    assert t._key("jnp", 100, 300, False) != k
    assert t._key("pallas-interpret", 100, 300, True) != k
    # same bucket -> same key (the memoization grain)
    assert t._key("jnp", 65, 257, True) == k
