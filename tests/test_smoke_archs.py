"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs. Full configs are exercised only by
the dry-run (launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.models.common import init_params
from repro.models.registry import applicable, build_model, cache_specs_for, materialize_batch

SMOKE_SEQ = 32
SMOKE_BATCH = 2


def _setup(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg, model, params = _setup(arch)
    batch = materialize_batch(cfg, "train_4k", SMOKE_SEQ, SMOKE_BATCH, None)
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss)), (arch, float(loss))
    gnorms = [float(jnp.linalg.norm(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)]
    assert all(np.isfinite(g) for g in gnorms), arch
    assert any(g > 0 for g in gnorms), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_smoke(arch):
    cfg, model, params = _setup(arch)
    ok, why = applicable(cfg, "decode_32k")
    if not ok:
        pytest.skip(why)
    # prefill SMOKE_SEQ-1 tokens into a cache of capacity SMOKE_SEQ
    cache_specs = cache_specs_for(cfg, "decode_32k", seq=SMOKE_SEQ, batch=SMOKE_BATCH)
    cache = init_params(cache_specs, jax.random.PRNGKey(1))
    pre_batch = materialize_batch(cfg, "prefill_32k", SMOKE_SEQ - 16, SMOKE_BATCH, None)
    logits, cache = jax.jit(model.prefill)(params, pre_batch, cache)
    assert logits.shape[0] == SMOKE_BATCH and logits.shape[1] == 1
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch

    dec_batch = {
        "token": jnp.ones((SMOKE_BATCH, 1), jnp.int32),
        "pos": jnp.asarray(SMOKE_SEQ - 16, jnp.int32),
    }
    logits2, cache2 = jax.jit(model.decode)(params, dec_batch, cache)
    assert logits2.shape == (SMOKE_BATCH, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits2.astype(jnp.float32)).all()), arch


@pytest.mark.parametrize("arch", ["xlstm_125m", "zamba2_2_7b", "tinyllama_1_1b", "seamless_m4t_v2"])
def test_decode_consistency_with_full_forward(arch):
    """Prefill+decode logits must match the full-sequence forward."""
    cfg, model, params = _setup(arch)
    S = 24
    batch = materialize_batch(cfg, "train_4k", S, SMOKE_BATCH, None)
    tokens = batch["tokens"]  # (B, S+1)

    # full forward on S tokens -> logits at last position
    cache_specs = cache_specs_for(cfg, "decode_32k", seq=S + 8, batch=SMOKE_BATCH)
    cache = init_params(cache_specs, jax.random.PRNGKey(1))
    pre = dict(batch)
    pre["tokens"] = tokens[:, :S]
    full_logits, cache = jax.jit(model.prefill)(params, pre, cache)

    # same via prefill of S-1 then one decode step
    cache2 = init_params(cache_specs, jax.random.PRNGKey(1))
    pre2 = dict(batch)
    pre2["tokens"] = tokens[:, : S - 1]
    _, cache2 = jax.jit(model.prefill)(params, pre2, cache2)
    dec = {"token": tokens[:, S - 1 : S], "pos": jnp.asarray(S - 1, jnp.int32)}
    step_logits, _ = jax.jit(model.decode)(params, dec, cache2)

    np.testing.assert_allclose(
        np.asarray(full_logits[:, 0].astype(jnp.float32)),
        np.asarray(step_logits[:, 0].astype(jnp.float32)),
        rtol=2e-3,
        atol=2e-3,
    )


def test_param_counts_full_configs():
    """Full-config parameter counts are in the right ballpark (sanity that
    the configs encode the intended architectures)."""
    from repro.models.common import n_params

    expect = {  # total params, ±35% (vocab padding, simplifications)
        "phi3_5_moe": 42e9,
        "granite_moe": 1.3e9,
        "qwen1_5_0_5b": 0.62e9,
        "minitron_8b": 8e9,
        "internlm2_20b": 20e9,
        "tinyllama_1_1b": 1.1e9,
        "xlstm_125m": 0.125e9,
        "zamba2_2_7b": 2.7e9,
        "internvl2_26b": 20e9,  # LM backbone only (vision stubbed)
        "seamless_m4t_v2": 1.4e9,
    }
    for arch, want in expect.items():
        cfg = get_config(arch)
        n = n_params(build_model(cfg).param_specs())
        assert 0.6 * want < n < 1.6 * want, (arch, n, want)
