"""Property tests for the streaming subsystem's load-bearing invariant:
per-segment support additivity over disjoint partitions.

The reduce step is only exact because, for ANY partition of the
transactions into segments, every itemset's whole-database support equals
the sum of its per-segment supports. The oracle-level property is checked
directly for all itemsets up to k=3, and end-to-end through
``StreamingMiner`` (random batch splits must answer exactly like the
whole database).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.encoding import pad_transactions
from repro.core.oracle import mine_bruteforce
from repro.mining import MineSpec, MiningEngine

N_ITEMS = 6


@st.composite
def db_and_partition(draw):
    """A small transaction DB plus a partition of its rows into 1-4
    disjoint segments (possibly empty — empty map partitions are legal)."""
    n_rows = draw(st.integers(1, 16))
    tx = [
        draw(st.lists(st.integers(0, N_ITEMS - 1), min_size=0, max_size=4))
        for _ in range(n_rows)
    ]
    n_parts = draw(st.integers(1, 4))
    assign = [draw(st.integers(0, n_parts - 1)) for _ in range(n_rows)]
    return tx, assign, n_parts


def _pad(tx):
    return pad_transactions(tx, max_len=4) if tx else np.empty((0, 4), np.int32)


@settings(max_examples=25, deadline=None)
@given(db_and_partition())
def test_per_segment_supports_are_additive(case):
    tx, assign, n_parts = case
    rows = _pad(tx)
    full = mine_bruteforce(rows, N_ITEMS, 1, max_k=3)
    parts = [
        mine_bruteforce(_pad([t for t, a in zip(tx, assign) if a == p]),
                        N_ITEMS, 1, max_k=3)
        for p in range(n_parts)
    ]
    # every itemset in the full DB: support == sum of segment supports
    # (absent from a segment == zero there); and no segment can carry an
    # itemset the full DB lacks
    for itemset, support in full.items():
        assert support == sum(p.get(itemset, 0) for p in parts)
    for p in parts:
        for itemset in p:
            assert itemset in full


@settings(max_examples=15, deadline=None)
@given(db_and_partition())
def test_streaming_miner_matches_whole_db(case):
    tx, assign, n_parts = case
    rows = _pad(tx)
    spec = MineSpec(algorithm="hprepost", min_count=2, max_k=3, candidate_unit=8)
    eng = MiningEngine()
    eng.stream(n_items=N_ITEMS, spec=spec)  # exists even if all batches are empty
    for p in range(n_parts):
        eng.append(_pad([t for t, a in zip(tx, assign) if a == p]))
    res = eng.submit_stream(spec)
    assert res.n_rows == len(rows)
    assert res.itemsets == mine_bruteforce(rows, N_ITEMS, 2, max_k=3)


# The deterministic (hypothesis-free) additivity anchor lives in
# tests/test_stream.py::test_additivity_exhaustive_paper_db so it runs
# even where hypothesis is absent.
