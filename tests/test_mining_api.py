"""The unified repro.mining front-door: spec resolution, cross-miner
parity against the oracle, pattern post-passes, and MiningEngine sessions
reusing warm jit caches."""
import numpy as np
import pytest

from repro.data.synth import random_db
from repro.mining import (
    MineRequest,
    MineResult,
    MineSpec,
    MiningEngine,
    get_miner,
    list_miners,
    mine,
)

SMALL = MineSpec(min_count=2, candidate_unit=8)  # fast hprepost buffers


def _db(seed=0, n_tx=60, n_items=10):
    return random_db(np.random.default_rng(seed), n_tx, n_items, 6), n_items


# ------------------------------------------------------------------ MineSpec
def test_spec_resolve_is_the_one_conversion():
    assert MineSpec(min_sup=0.01).resolve(50) == 1  # floors at 1
    assert MineSpec(min_sup=0.3).resolve(1000) == 300
    assert MineSpec(min_count=7).resolve(1000) == 7
    with pytest.raises(ValueError):
        MineSpec(min_sup=0.3, min_count=3)
    with pytest.raises(ValueError):
        MineSpec(min_sup=1.5)
    with pytest.raises(ValueError):
        MineSpec(patterns="nope")
    with pytest.raises(ValueError):
        MineSpec().resolve(10)  # no threshold given
    # with_ switches threshold kinds without tripping the both-set check
    assert MineSpec(min_count=3).with_(min_sup=0.5).resolve(10) == 5


def test_registry_covers_the_paper_family():
    names = list_miners()
    for expected in ("hprepost", "prepost", "prepost+", "fpgrowth", "apriori", "bruteforce"):
        assert expected in names
    with pytest.raises(KeyError):
        get_miner("eclat")


# ------------------------------------------------------- cross-miner parity
@pytest.mark.parametrize("algo", list_miners())
@pytest.mark.parametrize("seed", [0, 1])
def test_every_miner_matches_oracle(algo, seed):
    rows, n_items = _db(seed)
    oracle = mine(rows, n_items, SMALL.with_(algorithm="bruteforce"))
    res = mine(rows, n_items, SMALL.with_(algorithm=algo))
    assert isinstance(res, MineResult)
    assert res.algorithm == algo
    assert res.min_count == 2 and res.n_rows == len(rows)
    assert res.total_count == oracle.total_count  # exact count, always
    assert res.wall_time_s > 0 and res.stage_times_s
    if get_miner(algo).exhaustive:
        assert res.itemsets == oracle.itemsets
    else:  # CPE-pruned: explicit subset, but every support exact
        assert set(res.itemsets) <= set(oracle.itemsets)
        for s, sup in res.itemsets.items():
            assert oracle.itemsets[s] == sup


@pytest.mark.parametrize("algo", list_miners())
def test_every_miner_honors_max_k(algo):
    rows, n_items = _db(3)
    res = mine(rows, n_items, SMALL.with_(algorithm=algo, max_k=2))
    assert res.itemsets and all(len(s) <= 2 for s in res.itemsets)
    oracle = mine(rows, n_items, SMALL.with_(algorithm="bruteforce", max_k=2))
    assert res.total_count == oracle.total_count


def test_pattern_postpasses_through_front_door(paper_db):
    rows, n_items = paper_db
    spec = SMALL.with_(algorithm="prepost", min_count=3)
    full = mine(rows, n_items, spec)
    closed = mine(rows, n_items, spec.with_(patterns="closed"))
    maximal = mine(rows, n_items, spec.with_(patterns="maximal"))
    top = mine(rows, n_items, spec.with_(patterns="top_rank_k", rank_k=1))
    assert set(maximal.itemsets) <= set(closed.itemsets) <= set(full.itemsets)
    assert closed.total_count == full.total_count  # count describes the full family
    best = max(full.itemsets.values())
    assert all(v == best for v in top.itemsets.values())
    assert "patterns" in closed.stage_times_s
    with pytest.raises(ValueError):  # CPE subset cannot feed a post-pass
        mine(rows, n_items, spec.with_(algorithm="prepost+", patterns="closed"))


# ------------------------------------------------------------ MiningEngine
def test_engine_submits_reuse_jit_caches(paper_db):
    rows, n_items = paper_db
    eng = MiningEngine()
    spec = MineSpec(algorithm="hprepost", min_count=3, candidate_unit=4)
    r1 = eng.submit(rows, n_items, spec)
    fe = eng.frontend("hprepost")
    miner = fe.miner_for(spec)  # resident instance, not a rebuild
    jits = [miner._job1, miner._job2, miner._pack, miner._jobf2, miner._wave, miner._wave_local]
    sizes_warm = [f._cache_size() for f in jits if hasattr(f, "_cache_size")]
    assert sizes_warm and sum(sizes_warm) > 0  # first submit compiled something

    # same-shape resubmit: same miner, zero new compilation cache entries
    r2 = eng.submit(rows, n_items, spec)
    assert [f._cache_size() for f in jits if hasattr(f, "_cache_size")] == sizes_warm
    assert r1.itemsets == r2.itemsets

    # a threshold change may add entries for new static shapes, but still
    # rides the same resident miner (no rebuild of the sharded programs)
    r3 = eng.submit(rows, n_items, spec.with_(min_count=2))
    assert fe.miner_for(spec.with_(min_count=2)) is miner
    assert eng.miners_built == 1 and eng.stats["submits"] == 3
    assert set(r1.itemsets) <= set(r3.itemsets)


def test_engine_mixed_batch_and_sweep(paper_db):
    rows, n_items = paper_db
    eng = MiningEngine()
    reqs = [
        MineRequest(rows, n_items, MineSpec(algorithm="prepost", min_count=3)),
        MineRequest(rows, n_items, MineSpec(algorithm="fpgrowth", min_count=3)),
    ]
    out = eng.submit_many(reqs)
    assert [r.algorithm for r in out] == ["prepost", "fpgrowth"]
    assert out[0].itemsets == out[1].itemsets

    # ceiling threshold semantics: 0.7*7 -> 5, 0.4*7 -> 3 (never below the fraction)
    sweep = eng.sweep(rows, n_items, MineSpec(algorithm="prepost", min_count=3), [0.7, 0.4])
    assert sweep[0].min_count == 5 and sweep[1].min_count == 3
    assert len(sweep[0].itemsets) <= len(sweep[1].itemsets)


def test_core_reexports_the_mining_surface():
    import repro.core as core
    import repro.mining as mining

    assert core.MineSpec is mining.MineSpec
    assert core.MineResult is mining.MineResult
    assert core.mine is mining.mine
