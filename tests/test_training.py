"""Training loop, checkpoint/restart, fault injection, compression, serving."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.data import corpus
from repro.models.common import init_params
from repro.models.registry import build_model
from repro.training.compress import (
    compress_with_feedback,
    init_residuals,
    int8_compress,
    int8_decompress,
    topk_compress,
)
from repro.training.optim import OptConfig
from repro.training.step import TrainConfig, make_train_state, make_train_step
from repro.training.trainer import LoopConfig, Trainer
from repro.fault.failures import FailureInjector, SimulatedFailure, StragglerMonitor


def _tiny_model():
    cfg = get_config("tinyllama_1_1b").reduced()
    return cfg, build_model(cfg)


def _batches(cfg, seq=32, batch=2, seed=0):
    toks = corpus.token_stream(20_000, cfg.vocab_size, seed=seed)

    def gen():
        return corpus.batches(toks, batch, seq, seed=seed)

    return gen


def test_loss_decreases():
    cfg, model = _tiny_model()
    tc = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=5, total_steps=60))
    state = make_train_state(model, jax.random.PRNGKey(0), tc)
    step = jax.jit(make_train_step(model, tc))
    gen = _batches(cfg)()
    losses = []
    for i in range(60):
        state, metrics = step(state, next(gen))
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.3, (losses[:5], losses[-5:])


def test_checkpoint_restart_bitexact(tmp_path):
    """Failure mid-run + restart from checkpoint == uninterrupted run."""
    cfg, model = _tiny_model()
    tc = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=30))

    def run(ckpt_dir, injector):
        lc = LoopConfig(total_steps=24, ckpt_every=8, ckpt_dir=str(ckpt_dir), log_every=1)
        tr = Trainer(model, tc, lc, _batches(cfg), failure_injector=injector)
        final = tr.train()
        assert final == 24
        state, _ = tr.ckpt.restore()
        return state

    s_fail = run(tmp_path / "a", FailureInjector(fail_at_steps=(13,)))
    s_ok = run(tmp_path / "b", None)
    for a, b in zip(jax.tree.leaves(s_fail["params"]), jax.tree.leaves(s_ok["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_failure_exhausts_retries(tmp_path):
    cfg, model = _tiny_model()
    tc = TrainConfig()
    lc = LoopConfig(total_steps=10, ckpt_every=100, ckpt_dir=str(tmp_path / "c"), max_restarts=2)
    inj = FailureInjector(fail_prob=1.0)
    tr = Trainer(model, tc, lc, _batches(cfg), failure_injector=inj)
    with pytest.raises(SimulatedFailure):
        tr.train()


def test_straggler_monitor():
    m = StragglerMonitor(threshold=2.0)
    assert not m.record(0, 1.0)
    assert not m.record(1, 1.1)
    assert m.record(2, 5.0)  # straggler
    assert m.flagged == [2]
    assert m.mean < 1.2  # straggler did not contaminate the baseline


def test_int8_compression_unbiased_and_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(512,)), jnp.float32)
    deqs = []
    for i in range(50):
        q, s = int8_compress(g, jax.random.PRNGKey(i))
        deqs.append(np.asarray(int8_decompress(q, s)))
    err = np.mean(deqs, axis=0) - np.asarray(g)
    assert np.abs(err).max() < 0.01  # stochastic rounding is unbiased
    assert np.abs(deqs[0] - np.asarray(g)).max() <= float(s) * 1.01  # 1-ulp bound


def test_topk_keeps_largest():
    g = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05])
    out = np.asarray(topk_compress(g, 0.4))
    assert set(np.nonzero(out)[0]) == {1, 3}


def test_error_feedback_accumulates():
    """With feedback, the *sum* of delivered grads tracks the sum of true
    grads (compression error does not accumulate)."""
    rng = np.random.default_rng(1)
    true = [jnp.asarray(rng.normal(size=(64,)), jnp.float32) for _ in range(30)]
    res = init_residuals({"g": true[0]})
    delivered = []
    for i, g in enumerate(true):
        out, res = compress_with_feedback({"g": g}, res, jax.random.PRNGKey(i), "topk", 0.1)
        delivered.append(np.asarray(out["g"]))
    total_err = np.sum(delivered, axis=0) - np.sum([np.asarray(g) for g in true], axis=0)
    # residual bound: |err_total| == |final residual| << sum of grads
    np.testing.assert_allclose(total_err, -np.asarray(res["g"]), rtol=1e-4, atol=1e-4)


def test_compressed_training_converges():
    cfg, model = _tiny_model()
    tc = TrainConfig(opt=OptConfig(lr=2e-3, warmup_steps=5, total_steps=100), compression="int8")
    state = make_train_state(model, jax.random.PRNGKey(0), tc)
    step = jax.jit(make_train_step(model, tc))
    gen = _batches(cfg)()
    losses = []
    for i in range(100):
        state, metrics = step(state, next(gen))
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.25, (losses[:5], losses[-5:])


def test_serving_engine_greedy():
    from repro.serving.engine import Engine, Request

    cfg, model = _tiny_model()
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    eng = Engine(cfg, params, batch_size=2, max_seq=64)
    reqs = [Request(np.arange(1, 9, dtype=np.int32), max_new=4) for _ in range(2)]
    out = eng.generate(reqs)
    assert all(len(r.out) == 4 for r in out)
    assert all(0 <= t < cfg.padded_vocab for r in out for t in r.out)


def test_checkpoint_elastic_reshard(tmp_path):
    """Save on one 'mesh', restore with different shardings (elasticity)."""
    from repro.checkpoint.ckpt import CheckpointManager
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.compat import make_mesh

    cm = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    cm.save(0, state, extra={"note": "t"})
    mesh = make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, extra = cm.restore(shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
    assert extra["note"] == "t"
