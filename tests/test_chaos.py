"""Chaos harness: break every instrumented point and prove the service
invariant — every accepted Future resolves, with a result or a typed
error, and nothing the chaos touched corrupts later answers.

Deterministic sections arm one point at a time (enqueue, prep, serve,
wave launch, snapshot read) and pin down exactly how the failure
surfaces. The mini-soak arms several points probabilistically with a
fixed seed, floods the service, and checks (a) total resolution and
(b) that every successful result is bit-identical to a clean run —
the long-running version lives in ``benchmarks/chaos_soak.py``
(``make chaos-smoke``).
"""
import numpy as np
import pytest

from repro.data.synth import random_db
from repro.fault.failures import ChaosInjector, SimulatedFailure, installed
from repro.mining import MineSpec, MiningEngine
from repro.mining.service import MiningService, SnapshotStore
from repro.mining.service.admission import (
    DeadlineExceeded, Overloaded, ServiceClosed, ServiceError,
)

SPEC = MineSpec(algorithm="hprepost", max_k=4, candidate_unit=8, min_sup=0.3,
                nlist_width=16)


def _db(seed=0, n_tx=60, n_items=10):
    return random_db(np.random.default_rng(seed), n_tx, n_items, 6), n_items


def _mine_clean(rows, n_items, spec=SPEC):
    return MiningEngine().submit(rows, n_items, spec).itemsets


# ------------------------------------------------------ one point at a time
def test_chaos_enqueue_resolves_future_and_service_survives():
    rows, n_items = _db(0)
    with MiningService() as svc:
        with installed(ChaosInjector().arm("service.enqueue")):
            fut = svc.submit(rows, n_items, SPEC)
            with pytest.raises(SimulatedFailure):
                fut.result(timeout=5)
            # the poisoned request was never admitted; the next one works
            ok = svc.submit(rows, n_items, SPEC)
            assert ok.result(timeout=300).itemsets == _mine_clean(rows, n_items)
    assert svc.stats["requests"] == 1  # only the served one was accepted


def test_chaos_serve_crash_restarts_worker_and_fails_only_that_batch():
    rows, n_items = _db(0)
    with MiningService(batch_window_s=0.0) as svc:
        with installed(ChaosInjector().arm("service.serve")):
            fut = svc.submit(rows, n_items, SPEC)
            with pytest.raises(SimulatedFailure):
                fut.result(timeout=30)
            assert svc.stats["worker_restarts"] == 1
            res = svc.submit(rows, n_items, SPEC).result(timeout=300)
    assert res.itemsets == _mine_clean(rows, n_items)


def test_chaos_prep_failure_pins_to_its_group_only():
    rows, n_items = _db(0)
    with MiningService(batch_window_s=0.0) as svc:
        with installed(ChaosInjector().arm("service.prep")):
            fut = svc.submit(rows, n_items, SPEC)
            with pytest.raises(SimulatedFailure):
                fut.result(timeout=300)
            # worker loop did NOT die: the failure belonged to the group
            assert svc.stats["worker_restarts"] == 0
            res = svc.submit(rows, n_items, SPEC).result(timeout=300)
    assert res.itemsets == _mine_clean(rows, n_items)


def test_chaos_wave_launch_failure_resolves_future():
    rows, n_items = _db(0)
    # min_sup low enough that mining actually reaches a k>2 wave launch
    spec = SPEC.with_(min_sup=0.15, max_k=5)
    with MiningService(batch_window_s=0.0) as svc:
        svc.submit(rows, n_items, spec).result(timeout=300)  # warm: prep cached
        with installed(ChaosInjector().arm("mine.wave")):
            fut = svc.submit(rows, n_items, spec)
            with pytest.raises(SimulatedFailure):
                fut.result(timeout=300)
        res = svc.submit(rows, n_items, spec).result(timeout=300)
    assert res.itemsets == _mine_clean(rows, n_items, spec)


def test_chaos_snapshot_read_degrades_to_rebuild(tmp_path):
    rows, n_items = _db(0)
    sd = str(tmp_path / "snaps")
    with MiningService(snapshot_dir=sd) as svc:
        svc.submit(rows, n_items, SPEC).result(timeout=300)  # build + spill
    inj = ChaosInjector().arm("snapshot.read", times=10**9)
    with MiningService(snapshot_dir=sd) as svc:
        with installed(inj):
            res = svc.submit(rows, n_items, SPEC).result(timeout=300)
    # an I/O failure mid-read is a miss, never an error: correct answer,
    # just not warm-started from the store
    assert res.itemsets == _mine_clean(rows, n_items)
    assert inj.fired["snapshot.read"] >= 1
    assert res.service_stats["prep_source"] == "built"


def test_chaos_snapshot_store_get_raises_at_store_level(tmp_path):
    store = SnapshotStore(str(tmp_path / "s"))
    with installed(ChaosInjector().arm("snapshot.read")):
        with pytest.raises(SimulatedFailure):
            store.get("any-key")


def test_typed_errors_share_a_catchable_base():
    for exc in (Overloaded("x"), DeadlineExceeded("x"), ServiceClosed("x")):
        assert isinstance(exc, ServiceError)


# ------------------------------------------------------------- mini-soak
def test_chaos_mini_soak_every_accepted_future_resolves():
    dbs = [_db(0), _db(1)]
    clean = [_mine_clean(rows, n) for rows, n in dbs]

    inj = ChaosInjector(seed=1234)
    inj.arm("service.serve", times=0, prob=0.15)
    inj.arm("service.prep", times=0, prob=0.15)
    inj.arm("service.enqueue", times=0, prob=0.10)
    inj.arm("mine.wave", times=0, prob=0.05)
    with MiningService(batch_window_s=0.01, max_queue_depth=8) as svc:
        with installed(inj):
            futs = []
            for k in range(14):
                rows, n = dbs[k % len(dbs)]
                spec = SPEC.with_(priority=k % 3,
                                  deadline_s=60.0 if k % 4 == 0 else None)
                futs.append((k, svc.submit(rows, n, spec)))
        # chaos uninstalled; everything already accepted must still resolve
        outcomes = []
        for k, f in futs:
            exc = f.exception(timeout=600)  # resolution itself is the test
            outcomes.append((k, exc if exc is not None else f.result()))

    ok = fail = 0
    for k, out in outcomes:
        if isinstance(out, BaseException):
            assert isinstance(out, (ServiceError, SimulatedFailure)), out
            fail += 1
        else:
            assert out.itemsets == clean[k % len(dbs)]  # bit-identical
            ok += 1
    assert ok + fail == 14
    assert ok >= 1  # the seed gives a mixed run, not a total outage
    assert sum(inj.fired.values()) >= 1
    # the accounting drained fully: nothing is left in flight
    snap = svc.stats()
    assert snap["admission"]["depth"] == 0
    assert snap["admission"]["bytes_in_flight"] == 0
