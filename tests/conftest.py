import os

# Keep the default device count at 1 for smoke tests / benches; distributed
# tests that need fake devices spawn subprocesses with their own XLA_FLAGS.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# Paper Table 1 database: a=0 b=1 c=2 d=3 e=4 f=5 g=6
PAPER_TX = [[0, 1, 6], [1, 2, 3, 5, 6], [0, 1, 4], [0, 3], [1, 2, 4], [0, 3, 4, 5], [1, 2]]


@pytest.fixture
def paper_db():
    from repro.core.encoding import pad_transactions

    return pad_transactions(PAPER_TX), 7
