"""Correctness of the §Perf optimization paths: flash attention VJP,
sharded MoE dispatch, chunked sLSTM."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.layers import BIG_POS, _flash, _pick_kv_block


def _exact(q, k, v, q_pos, kv_pos, causal=True):
    hd = q.shape[-1]
    s = jnp.einsum("bqhd,bshd->bqhs", q.astype(jnp.float32), k.astype(jnp.float32)) * hd**-0.5
    mask = kv_pos[:, None, :] <= q_pos[:, :, None] if causal else kv_pos[:, None, :] < BIG_POS
    s = jnp.where(mask[:, :, None, :], s, -1e30)
    return jnp.einsum("bqhs,bshd->bqhd", jax.nn.softmax(s, -1), v.astype(jnp.float32)).astype(q.dtype)


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 2),
    s=st.sampled_from([16, 48, 64, 96]),
    h=st.integers(1, 3),
    hd=st.sampled_from([8, 16]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_matches_exact_fwd_bwd(b, s, h, hd, causal, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s)).astype(jnp.int32)
    kb = _pick_kv_block(s)
    o1 = _flash(q, k, v, pos, pos, causal, kb)
    o2 = _exact(q, k, v, pos, pos, causal)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=3e-5, atol=3e-5)
    f = lambda *a: _flash(*a, pos, pos, causal, kb).sum()
    e = lambda *a: _exact(*a, pos, pos, causal).sum()
    g1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(e, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-3, atol=1e-3)


def test_flash_masks_unfilled_cache_slots():
    """kv_pos = BIG_POS (unfilled cache) must contribute nothing."""
    rng = np.random.default_rng(0)
    B, S, H, hd = 1, 32, 2, 8
    q = jnp.asarray(rng.normal(size=(B, 8, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    q_pos = jnp.broadcast_to(jnp.arange(8)[None], (B, 8)).astype(jnp.int32)
    kv_pos_full = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    kv_pos_half = jnp.where(kv_pos_full < 8, kv_pos_full, BIG_POS)
    o_half = _flash(q, k, v, q_pos, kv_pos_half, True, 8)
    o_trunc = _flash(q, k[:, :8], v[:, :8], q_pos, kv_pos_full[:, :8], True, 8)
    np.testing.assert_allclose(np.asarray(o_half), np.asarray(o_trunc), rtol=1e-5, atol=1e-5)


def test_moe_sharded_equals_dense():
    from repro.configs.base import get_config
    from repro.models.common import init_params
    from repro.models.moe import _moe_dense, moe_ffn, moe_specs
    from repro.compat import make_mesh, set_mesh

    cfg = dataclasses.replace(get_config("granite_moe").reduced(), capacity_factor=4.0)
    p = init_params(moe_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model), jnp.float32)
    out_d, aux_d = jax.jit(lambda p, x: _moe_dense(p, x, cfg))(p, x)
    mesh = make_mesh((1, 1), ("data", "model"))
    with set_mesh(mesh):
        out_s, aux_s = jax.jit(lambda p, x: moe_ffn(p, x, cfg))(p, x)
    np.testing.assert_allclose(np.asarray(out_d), np.asarray(out_s), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(float(aux_d), float(aux_s), rtol=1e-5)


@pytest.mark.parametrize("S", [1, 16, 64, 96, 128])
def test_slstm_chunking_matches_flat(S):
    """Chunked/unrolled sLSTM must equal a flat per-step recurrence."""
    from repro.configs.base import get_config
    from repro.models.common import init_params
    from repro.models.ssm import slstm, slstm_specs

    cfg = get_config("xlstm_125m").reduced()
    p = init_params(slstm_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, S, cfg.d_model), jnp.float32)
    y, st1 = jax.jit(lambda p, x: slstm(p, x, cfg))(p, x)
    # flat reference: feed one token at a time through the single-step path
    state = None
    outs = []
    for t in range(S):
        yt, state = slstm(p, x[:, t : t + 1], cfg, state=state, single_step=True)
        outs.append(yt)
    y2 = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y2, np.float32), rtol=2e-4, atol=2e-4
    )


def test_corpus_phrases_recovered():
    """Injected n-gram phrases come back as high-support itemsets."""
    from repro.core.prepost import mine_prepost
    from repro.data import corpus

    toks = corpus.token_stream(30_000, 256, seed=3, n_phrases=4, phrase_len=3, phrase_rate=0.25)
    rows = corpus.ngram_transactions(toks, window=6, stride=3)
    res = mine_prepost(rows, 256, int(0.03 * len(rows)), max_k=3)
    three = [k for k in res.itemsets if len(k) == 3]
    assert len(three) >= 3  # the injected phrases (as sets) are frequent


def test_prefetcher_overlap_and_skip():
    import itertools
    from repro.data.pipeline import Prefetcher

    gen = ({"i": np.asarray(i)} for i in itertools.count())
    pf = Prefetcher(gen, depth=4)
    first = pf.next()["i"]
    pf.skip_slow(2)
    later = pf.next()["i"]
    assert later > first
    assert pf.skipped == 2
    pf.close()
