"""Distributed mining (repro.mining.distributed): coordinator/worker
placement, the RPC layer, and snapshot-based failover.

Anchors, per the PR acceptance criteria:
  - parity: a >= 2-worker distributed mine answers bit-identically to the
    single-process ``StreamingMiner`` on the same appended batches (and
    to the brute-force oracle), across min_sup thresholds, and through
    the ``MiningService`` Future path;
  - chaos: a worker hard-killed between waves, mid-wave (no reply), or
    during an append still yields the exact answer, with the dead
    worker's segments re-placed from the shared snapshot store with ZERO
    prep recompute on the survivors (snapshot-only recovery);
  - heartbeats: with a monitor enabled, a dead worker is detected and
    failed over without any query traffic.

Worker processes are real (multiprocessing spawn + loopback TCP), so the
parity tests share one module-scoped cluster; chaos tests get fresh ones.
"""
import time

import numpy as np
import pytest

from repro.core.oracle import mine_bruteforce
from repro.data.synth import random_db
from repro.mining import MineSpec, MiningEngine
from repro.mining.distributed import NoLiveWorkers, choose_worker, replan
from repro.mining.service import MiningService
from repro.mining.stream import StreamSpec

SPEC = MineSpec(algorithm="hprepost", max_k=4, candidate_unit=8, min_sup=0.3)
SSPEC = StreamSpec(row_pad=16)


def _batches(seed=0, sizes=(30, 14, 22), n_items=10, max_len=6):
    rng = np.random.default_rng(seed)
    return [random_db(rng, n, n_items, max_len) for n in sizes], n_items


def _single_process(batches, n_items, spec):
    eng = MiningEngine()
    for b in batches:
        eng.append(b, n_items, spec=SPEC, stream_spec=SSPEC)
    return eng.submit_stream(spec)


# ------------------------------------------------------------- placement
def test_choose_worker_picks_least_loaded_deterministically():
    assert choose_worker({0: 100, 1: 40, 2: 70}) == 1
    # ties break on worker id, never dict order
    assert choose_worker({2: 50, 0: 50, 1: 80}) == 0
    assert choose_worker({3: 0}) == 3


def test_replan_best_fit_decreasing_balances_bytes():
    loads = {1: 100, 2: 300}
    plan = replan([(10, 500), (11, 200), (12, 50)], loads)
    # biggest orphan lands on the lightest survivor, then re-balance
    assert plan == {10: 1, 11: 2, 12: 2}
    # loads mutated in place to reflect the plan
    assert loads == {1: 600, 2: 550}
    assert replan([], {5: 0}) == {}


# -------------------------------------------------------------- protocol
def test_protocol_roundtrip_with_arrays():
    import socket

    from repro.mining.distributed.protocol import (
        ConnectionClosed, recv_msg, send_msg)

    a, b = socket.socketpair()
    try:
        msg = {
            "op": "wave", "seq": 7,
            "parent_arr": np.arange(1000, dtype=np.int32),
            "sups": np.array([1, 2, 3], np.int64),
        }
        send_msg(a, msg)
        got = recv_msg(b)
        assert got["op"] == "wave" and got["seq"] == 7
        np.testing.assert_array_equal(got["parent_arr"], msg["parent_arr"])
        np.testing.assert_array_equal(got["sups"], msg["sups"])
        assert got["sups"].dtype == np.int64
        a.close()
        with pytest.raises(ConnectionClosed):
            recv_msg(b)  # clean EOF is a typed error, not a short read
    finally:
        a.close()
        b.close()


# ---------------------------------------------------------------- parity
@pytest.fixture(scope="module")
def cluster(tmp_path_factory):
    batches, n_items = _batches(1, sizes=(25, 18, 31, 12))
    snap = tmp_path_factory.mktemp("dist-snap")
    eng = MiningEngine(snapshot_dir=str(snap))
    dm = eng.distribute(
        name="t", n_items=n_items, workers=2, spec=SPEC, stream_spec=SSPEC
    )
    for b in batches:
        dm.append(b)
    yield eng, dm, batches, n_items
    dm.close()


@pytest.mark.parametrize("min_sup", [0.5, 0.3, 0.15])
def test_distributed_matches_single_process_and_oracle(cluster, min_sup):
    _, dm, batches, n_items = cluster
    spec = SPEC.with_(min_sup=min_sup)
    res = dm.mine(spec)
    ref = _single_process(batches, n_items, spec)
    allrows = np.concatenate(batches)
    assert res.n_rows == len(allrows)
    assert res.itemsets == ref.itemsets
    assert res.itemsets == mine_bruteforce(allrows, n_items, res.min_count,
                                           max_k=SPEC.max_k)
    assert res.service_stats["prep_source"] == "distributed"
    assert res.service_stats["workers"] == 2


def test_segments_spread_over_both_workers(cluster):
    _, dm, _, _ = cluster
    owners = {m.worker for m in dm._segments.values()}
    assert owners == {0, 1}  # byte-balanced placement used the whole pool


def test_distributed_through_service_future_path(cluster):
    eng, dm, batches, n_items = cluster
    svc = MiningService(engine=eng)
    try:
        spec = SPEC.with_(min_sup=0.25)
        fut_res = svc.submit_stream(spec, stream="t")
        fut_append = svc.append(
            random_db(np.random.default_rng(7), 9, n_items, 6), stream="t"
        )
        assert fut_res.result(120).itemsets == _single_process(
            batches, n_items, spec).itemsets
        assert fut_append.result(120)["total_rows"] == dm.db.n_rows
        # the appended batch is part of the database for later queries
        res2 = svc.submit_stream(spec, stream="t").result(120)
        assert res2.n_rows == dm.db.n_rows
    finally:
        svc.close()


def test_mixed_device_config_query_rejected(cluster):
    _, dm, _, _ = cluster
    with pytest.raises(ValueError, match="device config"):
        dm.mine(SPEC.with_(candidate_unit=16))
    with pytest.raises(ValueError, match="hprepost"):
        dm.mine(SPEC.with_(algorithm="apriori"))


# ----------------------------------------------------------------- chaos
def _survivor_prepares(stats_by_wid, wids):
    return sum(stats_by_wid[w]["stats"]["seg_prepares"] for w in wids)


@pytest.mark.parametrize(
    "fault_op,after,when",
    [
        ("wave", 0, "after_reply"),  # dies between waves, reply flushed
        ("wave", 0, "before"),       # dies mid-wave, reply never sent
        ("prep", 0, "before"),       # dies during an append's map step
    ],
    ids=["between-waves", "mid-wave", "during-append"],
)
def test_chaos_worker_death_recovers_from_snapshots(tmp_path, fault_op, after, when):
    """Kill a worker at each dangerous point; the answer must stay exact
    and every re-placed segment must warm-restore from the shared
    snapshot store — failover recomputes nothing."""
    batches, n_items = _batches(3, sizes=(30, 14, 22))
    spec = SPEC.with_(min_sup=0.08)  # dense enough for 3-itemsets (2 waves)
    eng = MiningEngine(snapshot_dir=str(tmp_path))
    dm = eng.distribute(
        name="chaos", n_items=n_items, workers=2, spec=SPEC, stream_spec=SSPEC
    )
    try:
        for b in batches:
            dm.append(b)
        ref = _single_process(batches, n_items, spec)
        assert any(len(s) >= 3 for s in ref.itemsets)  # multi-wave query
        assert dm.mine(spec).itemsets == ref.itemsets

        if fault_op == "prep":
            # the next append's map step must land on the faulted worker:
            # placement is deterministic (least loaded bytes, then wid)
            victim = choose_worker(dm._loads())
        else:
            victim = min(m.worker for m in dm._segments.values())
        pre = dm.worker_stats()
        dm.inject_fault(victim, fault_op, after=after, when=when)
        if fault_op == "prep":
            extra = random_db(np.random.default_rng(9), 18, n_items, 6)
            dm.append(extra)
            batches = batches + [extra]
            ref = _single_process(batches, n_items, spec)
        res = dm.mine(spec)
        assert res.itemsets == ref.itemsets  # bit-identical after failover

        survivors = {w.wid for w in dm._live()}
        assert victim not in survivors and len(survivors) == 1
        assert dm.stats["workers_lost"] == 1
        assert dm.stats["failovers"] >= 1
        # snapshot-only recovery: re-placed segments restored, not rebuilt
        assert dm.stats["reassigned_segments"] >= 1
        assert dm.stats["reassign_rebuilds"] == 0
        post = dm.worker_stats()
        # the survivors ran prep (full N-list build) only for a batch the
        # store had never seen: the in-flight append of the 'prep' case
        expected_new_preps = 1 if fault_op == "prep" else 0
        assert (_survivor_prepares(post, survivors)
                - _survivor_prepares(pre, survivors)) == expected_new_preps

        # the database stays serviceable: append + re-query on survivors
        extra2 = random_db(np.random.default_rng(11), 7, n_items, 6)
        dm.append(extra2)
        ref2 = _single_process(batches + [extra2], n_items, spec)
        assert dm.mine(spec).itemsets == ref2.itemsets
    finally:
        dm.close()


def test_all_workers_dead_raises_no_live_workers(tmp_path):
    batches, n_items = _batches(5, sizes=(20,))
    eng = MiningEngine(snapshot_dir=str(tmp_path))
    dm = eng.distribute(
        name="dead", n_items=n_items, workers=1, spec=SPEC, stream_spec=SSPEC
    )
    try:
        dm.append(batches[0])
        dm.kill_worker(0)
        with pytest.raises(NoLiveWorkers):
            dm.mine(SPEC)
        with pytest.raises(NoLiveWorkers):
            dm.append(batches[0])
    finally:
        dm.close()


def test_heartbeat_detects_death_without_query_traffic(tmp_path):
    """With the monitor on, a hard-killed worker is retired and its
    segments re-placed by the heartbeat alone — the next query pays no
    mid-flight retry."""
    batches, n_items = _batches(6, sizes=(24, 17))
    eng = MiningEngine(snapshot_dir=str(tmp_path))
    dm = eng.distribute(
        name="hb", n_items=n_items, workers=2, spec=SPEC, stream_spec=SSPEC,
        heartbeat_s=0.2,
    )
    try:
        for b in batches:
            dm.append(b)
        victim = min(w.wid for w in dm._live())
        dm.kill_worker(victim)

        # the failovers counter bumps at the *start* of the re-place loop
        # (the monitor holds _op_lock throughout), so wait for the whole
        # postcondition — detected AND every segment off the victim —
        # not just the counter, or a slow box observes mid-failover state
        def settled():
            with dm._op_lock:
                return dm.stats["failovers"] >= 1 and all(
                    m.worker != victim for m in dm._segments.values()
                )

        deadline = time.monotonic() + 30
        while not settled() and time.monotonic() < deadline:
            time.sleep(0.05)
        assert settled()  # detected + re-placed with zero queries issued
        assert dm.stats["workers_lost"] == 1
        assert dm.stats["reassign_rebuilds"] == 0

        spec = SPEC.with_(min_sup=0.2)
        res = dm.mine(spec)
        assert dm.stats["query_retries"] == 0  # failover happened off-path
        assert res.itemsets == _single_process(batches, n_items, spec).itemsets
    finally:
        dm.close()


# ----------------------------------------------- transport hardening (PR 8)
def test_channel_sockets_are_hardened():
    import socket

    from repro.mining.distributed.transport import Listener, dial

    lst = Listener()
    try:
        peer = dial(lst.address)
        chan = lst.accept(5)
        for c in (peer, chan):
            s = c.sock
            assert s.getsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY) != 0
            assert s.getsockopt(socket.SOL_SOCKET, socket.SO_KEEPALIVE) != 0
        peer.close()
        chan.close()
    finally:
        lst.close()


def test_channel_half_open_peer_surfaces_as_typed_error():
    """A peer that stops responding trips the bounded recv timeout; a
    peer that dies hard (RST, no clean FIN) surfaces as ConnectionClosed
    — either way the coordinator gets a typed error, never a hang."""
    import socket
    import struct

    from repro.mining.distributed.protocol import ConnectionClosed
    from repro.mining.distributed.transport import Listener, dial

    lst = Listener()
    try:
        peer = dial(lst.address)
        chan = lst.accept(5)
        # half-open: the peer exists but never writes
        with pytest.raises(TimeoutError):
            chan.recv(0.2)
        # hard death: RST instead of FIN (SO_LINGER 0 + close), the
        # kill -9 shape — recv must type it, not crash on raw OSError
        peer.sock.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                             struct.pack("ii", 1, 0))
        peer.sock.close()
        with pytest.raises((ConnectionClosed, TimeoutError)):
            chan.recv(5)
        chan.close()
    finally:
        lst.close()


# ------------------------------------------------- rpc retry / respawn / ckpt
def test_rpc_timeout_retries_and_skips_stale_reply(tmp_path):
    """A reply that times out once is retried under a fresh seq; the
    late duplicate reply of the timed-out send is skipped as a stale
    frame, so the retry returns the right payload."""
    from repro.fault.failures import ChaosInjector, installed

    batches, n_items = _batches(21, sizes=(20,))
    eng = MiningEngine(snapshot_dir=str(tmp_path))
    dm = eng.distribute(
        name="retry", n_items=n_items, workers=1, spec=SPEC, stream_spec=SSPEC,
        rpc_attempts=3, rpc_backoff_s=0.01,
    )
    try:
        dm.append(batches[0])
        # one injected timeout on the coordinator's next reply recv: the
        # worker HAS replied (the chaos fires before the socket read), so
        # the retry must discard that now-stale frame and match its own
        with installed(ChaosInjector().arm("rpc.recv", exc=TimeoutError)):
            stats = dm.worker_stats()
        assert stats[0]["stats"]["preps"] == 1  # correct payload after retry
        assert dm.stats["rpc_timeouts"] == 1
        assert dm.stats["rpc_retries"] == 1
        assert len(dm._live()) == 1  # one timeout never retires the worker
    finally:
        dm.close()


def test_rpc_retry_exhaustion_fails_over(tmp_path):
    """Every send timing out exhausts rpc_attempts and surfaces as a
    WorkerDied -> failover; with no survivors and no budget, typed
    NoLiveWorkers."""
    from repro.fault.failures import ChaosInjector, installed

    batches, n_items = _batches(22, sizes=(18, 12))
    eng = MiningEngine(snapshot_dir=str(tmp_path))
    dm = eng.distribute(
        name="exhaust", n_items=n_items, workers=1, spec=SPEC, stream_spec=SSPEC,
        rpc_attempts=2, rpc_backoff_s=0.01,
    )
    try:
        dm.append(batches[0])
        inj = ChaosInjector().arm("rpc.recv", times=10**9, exc=TimeoutError)
        with installed(inj):
            with pytest.raises(NoLiveWorkers):
                dm.append(batches[1])
        assert dm.stats["rpc_timeouts"] >= 2  # both attempts timed out
        assert dm.stats["rpc_retries"] >= 1
        assert dm.stats["workers_lost"] == 1  # exhaustion ran the failover
    finally:
        dm.close()


def test_respawn_restores_pool_and_answers_exactly(tmp_path):
    """With a restart budget, a killed worker is replaced: the pool
    recovers to full size, displaced segments migrate onto the fresh
    worker snapshot-first, and answers stay bit-identical."""
    batches, n_items = _batches(23, sizes=(26, 15, 19))
    spec = SPEC.with_(min_sup=0.15)
    eng = MiningEngine(snapshot_dir=str(tmp_path))
    dm = eng.distribute(
        name="respawn", n_items=n_items, workers=2, spec=SPEC, stream_spec=SSPEC,
        restart_budget=2,
    )
    try:
        for b in batches:
            dm.append(b)
        ref = _single_process(batches, n_items, spec)
        assert dm.mine(spec).itemsets == ref.itemsets

        victim = min(m.worker for m in dm._segments.values())
        dm.kill_worker(victim)
        res = dm.mine(spec)  # death detected mid-query -> failover+respawn
        assert res.itemsets == ref.itemsets
        assert dm.stats["respawns"] == 1
        assert dm.stats["reassign_rebuilds"] == 0  # snapshot-only recovery
        assert len(dm._live()) == 2  # pool is whole again
        live_ids = {w.wid for w in dm._live()}
        assert victim not in live_ids
        # every segment is owned by a live worker, and the fresh worker
        # actually carries load (migration happened, not just spawn)
        owners = {m.worker for m in dm._segments.values()}
        assert owners <= live_ids and max(live_ids) in owners

        # still fully serviceable, including new appends onto the new pool
        extra = random_db(np.random.default_rng(31), 12, n_items, 6)
        dm.append(extra)
        ref2 = _single_process(batches + [extra], n_items, spec)
        assert dm.mine(spec).itemsets == ref2.itemsets
    finally:
        dm.close()


def test_respawn_budget_spent_pool_shrinks(tmp_path):
    batches, n_items = _batches(24, sizes=(20, 14))
    eng = MiningEngine(snapshot_dir=str(tmp_path))
    dm = eng.distribute(
        name="budget", n_items=n_items, workers=2, spec=SPEC, stream_spec=SSPEC,
        restart_budget=1,
    )
    try:
        for b in batches:
            dm.append(b)
        spec = SPEC.with_(min_sup=0.2)
        ref = _single_process(batches, n_items, spec)
        for kill in range(2):
            victim = min(w.wid for w in dm._live())
            dm.kill_worker(victim)
            assert dm.mine(spec).itemsets == ref.itemsets
        assert dm.stats["respawns"] == 1  # second death: budget exhausted
        assert len(dm._live()) == 1  # now the pool has shrunk for good
    finally:
        dm.close()


def test_coordinator_checkpoint_replays_identical_database(tmp_path):
    """Restarting the coordinator from its append-log checkpoint yields
    the same SegmentedDB — same rank space, row totals, digest, and
    bit-identical answers — with segments restored from snapshots, and
    the recorded placement honored."""
    batches, n_items = _batches(25, sizes=(24, 16, 20))
    spec = SPEC.with_(min_sup=0.15)
    snap, ck = str(tmp_path / "snap"), str(tmp_path / "ck")

    eng1 = MiningEngine(snapshot_dir=snap)
    dm1 = eng1.distribute(
        name="ck", n_items=n_items, workers=2, spec=SPEC, stream_spec=SSPEC,
        checkpoint_dir=ck,
    )
    empty = np.full((5, 6), -1, np.int32)  # pad-only batch: rows, no segment
    try:
        for b in batches:
            dm1.append(b)
        dm1.append(empty)
        ref = dm1.mine(spec)
        placement1 = {s: m.worker for s, m in dm1._segments.items()}
        digest1 = dm1._db_digest()
        n_rows1 = dm1.db.n_rows
    finally:
        dm1.close()

    eng2 = MiningEngine(snapshot_dir=snap)
    dm2 = eng2.distribute(
        name="ck2", n_items=n_items, workers=2, spec=SPEC, stream_spec=SSPEC,
        checkpoint_dir=ck,
    )
    try:
        assert dm2.stats["restored_appends"] == len(batches) + 1
        assert dm2.db.n_rows == n_rows1
        assert dm2._db_digest() == digest1
        assert {s: m.worker for s, m in dm2._segments.items()} == placement1
        res = dm2.mine(spec)
        assert res.itemsets == ref.itemsets
        # replay was a restore, not a recompute: every segment came from
        # the shared snapshot store
        ws = dm2.worker_stats()
        assert sum(s["stats"]["seg_snapshot_hits"] for s in ws.values()) == len(batches)
        assert sum(s["stats"]["seg_prepares"] for s in ws.values()) == 0

        # the restored database keeps checkpointing: append, restart again
        extra = random_db(np.random.default_rng(41), 11, n_items, 6)
        dm2.append(extra)
        ref3 = dm2.mine(spec)
    finally:
        dm2.close()

    eng3 = MiningEngine(snapshot_dir=snap)
    dm3 = eng3.distribute(
        name="ck3", n_items=n_items, workers=1, spec=SPEC, stream_spec=SSPEC,
        checkpoint_dir=ck,
    )
    try:
        assert dm3.mine(spec).itemsets == ref3.itemsets
    finally:
        dm3.close()


def test_checkpoint_rejects_mismatched_n_items(tmp_path):
    batches, n_items = _batches(26, sizes=(15,))
    ck = str(tmp_path / "ck")
    eng = MiningEngine()
    dm = eng.distribute(
        name="ckbad", n_items=n_items, workers=1, spec=SPEC, stream_spec=SSPEC,
        checkpoint_dir=ck,
    )
    try:
        dm.append(batches[0])
    finally:
        dm.close()
    eng2 = MiningEngine()
    with pytest.raises(ValueError, match="n_items"):
        eng2.distribute(
            name="ckbad2", n_items=n_items + 1, workers=1, spec=SPEC,
            stream_spec=SSPEC, checkpoint_dir=ck,
        )
