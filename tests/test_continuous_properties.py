"""Property tests for the continuous-mining invariants.

Random append/expire/compact interleavings over a sliding window must
keep two properties at every step:

  1. windowed parity — the live windowed mine is bit-identical to the
     brute-force oracle over exactly the retained (window) rows;
  2. diff reconstruction — a standing query's cumulative diff stream,
     replayed from empty, equals its delivered answer, and the final
     delivered answer equals the final frequent set.

Expiry is driven implicitly (window_rows at append time) and compaction
both implicitly (max_segments) and explicitly (forced passes drawn into
the interleaving). The deterministic (hypothesis-free) anchor lives in
tests/test_continuous.py::
test_deterministic_interleaving_parity_and_diff_reconstruction so the
invariant is exercised even where hypothesis is absent.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.encoding import pad_transactions
from repro.core.oracle import mine_bruteforce
from repro.mining import MineSpec, MiningEngine
from repro.mining.continuous import replay_diffs
from repro.mining.stream import StreamSpec

N_ITEMS = 6
SPEC = MineSpec(algorithm="hprepost", min_count=2, max_k=3, candidate_unit=8)


@st.composite
def interleaving(draw):
    """2-6 ops: each an append of 1-8 random short transactions, possibly
    followed by a forced compaction pass."""
    n_ops = draw(st.integers(2, 6))
    ops = []
    for _ in range(n_ops):
        n_rows = draw(st.integers(1, 8))
        tx = [
            draw(st.lists(st.integers(0, N_ITEMS - 1), min_size=0, max_size=4))
            for _ in range(n_rows)
        ]
        ops.append((tx, draw(st.booleans())))
    window = draw(st.integers(4, 20))
    return ops, window


def _pad(tx):
    return pad_transactions(tx, max_len=4) if tx else np.empty((0, 4), np.int32)


def _retained(eng):
    db = eng.stream().db
    if not db.segments:
        return np.empty((0, 4), np.int32)
    return np.concatenate([s.rows[:s.n_rows] for s in db.segments])


@settings(max_examples=20, deadline=None)
@given(interleaving())
def test_windowed_interleavings_keep_parity_and_replay(case):
    ops, window = case
    ss = StreamSpec(window_rows=window, max_segments=3, compact_fanin=2,
                    compact_async=False)
    eng = MiningEngine()
    eng.stream(n_items=N_ITEMS, spec=SPEC, stream_spec=ss)
    q = eng.register_standing(SPEC)
    for tx, force_compact in ops:
        eng.append(_pad(tx), N_ITEMS)
        if force_compact and len(eng.stream().db.segments) > 1:
            eng.stream().compact()
        retained = _retained(eng)
        res = eng.submit_stream(SPEC)
        # n_rows covers the retained segments plus any still-windowed
        # all-PAD appends (segment-less rows; support-neutral)
        empty_rows = sum(n for _, n in eng.stream()._empty_trail)
        assert res.n_rows == len(retained) + empty_rows
        assert res.itemsets == mine_bruteforce(retained, N_ITEMS, 2, max_k=3)
        # the diff chain replays to the delivered answer at every step
        assert replay_diffs(q.diffs) == q.latest
    # the cumulative diff stream reconstructs the final frequent set
    final = eng.submit_stream(SPEC)
    assert replay_diffs(q.diffs) == q.latest == final.itemsets
