"""repro.fault.failures: the injector/monitor/supervisor primitives.

Previously untested satellite coverage: StragglerMonitor's EWMA flagging
(and baseline hygiene), run_with_restarts' checkpoint-resume + bounded
retry exhaustion, FailureInjector's deterministic fail_at_steps firing
exactly once, and the PR 8 ChaosInjector (deterministic countdowns,
seeded probabilistic firing, global install/fire plumbing).
"""
import pytest

from repro.fault.failures import (
    ChaosInjector, FailureInjector, SimulatedFailure, StragglerMonitor,
    fire, installed, run_with_restarts,
)


# ------------------------------------------------------ FailureInjector
def test_fail_at_steps_fires_exactly_once_per_step():
    inj = FailureInjector(fail_at_steps=(3, 5))
    fired = []
    for step in range(8):
        try:
            inj.maybe_fail(step)
        except SimulatedFailure:
            fired.append(step)
    assert fired == [3, 5]
    # a restarted loop revisiting the same steps does not re-fire them
    for step in range(8):
        inj.maybe_fail(step)


def test_fail_prob_is_seed_deterministic():
    def schedule():
        inj = FailureInjector(fail_prob=0.2, seed=7)
        return [s for s in range(200) if _fails(inj, s)]

    a, b = schedule(), schedule()
    assert a == b
    assert 10 < len(a) < 90  # ~20% of 200, loose bounds


def _fails(inj, step):
    try:
        inj.maybe_fail(step)
        return False
    except SimulatedFailure:
        return True


# ----------------------------------------------------- StragglerMonitor
def test_straggler_flagging_and_ewma_baseline():
    mon = StragglerMonitor(threshold=3.0, ewma=0.5)
    assert mon.record(0, 1.0) is False  # first sample seeds the mean
    assert mon.record(1, 1.0) is False
    assert mon.record(2, 10.0) is True  # 10 > 3 * ~1.0
    # the straggler must NOT have contaminated the baseline: another
    # normal step is still unflagged and the mean stayed near 1.0
    assert mon.flagged == [2]
    assert mon.record(3, 1.2) is False
    assert mon.mean == pytest.approx(1.0, abs=0.3)


def test_straggler_ewma_tracks_drift():
    mon = StragglerMonitor(threshold=3.0, ewma=0.5)
    for step, dt in enumerate([1.0, 2.0, 2.5, 2.8, 2.9]):
        mon.record(step, dt)  # gradual slowdown: never flagged
    assert mon.flagged == []
    assert mon.mean > 2.0  # baseline followed the drift


# ----------------------------------------------------- run_with_restarts
def test_run_with_restarts_resumes_from_latest_checkpoint():
    state = {"ckpt": None, "starts": []}

    def run(start):
        state["starts"].append(start)
        for step in range(start, 10):
            if step == 4 and len(state["starts"]) == 1:
                raise SimulatedFailure("die once at step 4")
            state["ckpt"] = step
        return 9

    assert run_with_restarts(run, lambda: state["ckpt"], max_restarts=2) == 9
    assert state["starts"] == [0, 4]  # resumed after the last checkpoint


def test_run_with_restarts_exhausts_budget():
    calls = {"n": 0}

    def run(start):
        calls["n"] += 1
        raise SimulatedFailure("always")

    with pytest.raises(SimulatedFailure):
        run_with_restarts(run, lambda: None, max_restarts=3)
    assert calls["n"] == 4  # the initial attempt + 3 restarts


# -------------------------------------------------------- ChaosInjector
def test_chaos_deterministic_after_and_times():
    inj = ChaosInjector().arm("p", after=2, times=2)
    hits = []
    for i in range(6):
        try:
            inj.fire("p")
            hits.append(False)
        except SimulatedFailure:
            hits.append(True)
    assert hits == [False, False, True, True, False, False]
    assert inj.seen["p"] == 6 and inj.fired["p"] == 2


def test_chaos_custom_exception_type():
    inj = ChaosInjector().arm("rpc.recv", exc=TimeoutError)
    with pytest.raises(TimeoutError):
        inj.fire("rpc.recv")


def test_chaos_global_fire_is_noop_unless_installed():
    fire("not.installed.anywhere")  # must not raise
    inj = ChaosInjector().arm("x")
    with installed(inj):
        with pytest.raises(SimulatedFailure):
            fire("x")
    fire("x")  # uninstalled again on exit
    assert inj.fired["x"] == 1


def test_chaos_unarmed_points_pass_through():
    inj = ChaosInjector().arm("only.this")
    inj.fire("something.else")
    assert inj.seen["something.else"] == 1
    assert inj.fired["something.else"] == 0


def test_chaos_prob_is_seed_deterministic():
    def schedule():
        inj = ChaosInjector(seed=11).arm("p", times=0, prob=0.3)
        out = []
        for _ in range(100):
            try:
                inj.fire("p")
                out.append(0)
            except SimulatedFailure:
                out.append(1)
        return out

    a, b = schedule(), schedule()
    assert a == b
    assert 10 < sum(a) < 60
