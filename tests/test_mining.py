"""Miner correctness: PrePost / PrePost+ / FP-growth / Apriori vs brute force."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.apriori import mine_apriori
from repro.core.fpgrowth import mine_fpgrowth
from repro.core.oracle import mine_bruteforce
from repro.core.prepost import mine_prepost
from repro.data.synth import random_db


def test_paper_example_mining(paper_db):
    rows, n_items = paper_db
    res = mine_prepost(rows, n_items, 3)
    bf = mine_bruteforce(rows, n_items, 3)
    assert res.itemsets == bf
    # paper Example 2: N-list of (be) has support 2 -> not frequent at 3
    assert (1, 4) not in res.itemsets


@settings(max_examples=40, deadline=None)
@given(
    n_tx=st.integers(1, 50),
    n_items=st.integers(1, 10),
    min_count=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_prepost_equals_bruteforce(n_tx, n_items, min_count, seed):
    rng = np.random.default_rng(seed)
    rows = random_db(rng, n_tx, n_items, min(6, n_items))
    bf = mine_bruteforce(rows, n_items, min_count)
    res = mine_prepost(rows, n_items, min_count)
    assert res.itemsets == bf
    assert res.total_count == len(bf)


@settings(max_examples=30, deadline=None)
@given(
    n_tx=st.integers(1, 50),
    n_items=st.integers(1, 10),
    min_count=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_cpe_count_exact(n_tx, n_items, min_count, seed):
    """PrePost+ CPE pruning must preserve the exact itemset count/supports."""
    rng = np.random.default_rng(seed)
    rows = random_db(rng, n_tx, n_items, min(6, n_items))
    bf = mine_bruteforce(rows, n_items, min_count)
    res = mine_prepost(rows, n_items, min_count, cpe=True)
    assert res.total_count == len(bf)
    for k, v in res.itemsets.items():
        assert bf[k] == v  # every explicit itemset has the right support


@settings(max_examples=25, deadline=None)
@given(
    n_tx=st.integers(1, 40),
    n_items=st.integers(1, 9),
    min_count=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_fpgrowth_and_apriori_agree(n_tx, n_items, min_count, seed):
    rng = np.random.default_rng(seed)
    rows = random_db(rng, n_tx, n_items, min(6, n_items))
    bf = mine_bruteforce(rows, n_items, min_count)
    fp, _ = mine_fpgrowth(rows, n_items, min_count)
    ap, _ = mine_apriori(rows, n_items, min_count)
    assert fp == bf
    assert ap == bf


def test_max_k_truncation(paper_db):
    rows, n_items = paper_db
    res = mine_prepost(rows, n_items, 2, max_k=1)
    assert all(len(k) == 1 for k in res.itemsets)
    res2 = mine_prepost(rows, n_items, 2, max_k=2)
    assert all(len(k) <= 2 for k in res2.itemsets)


def test_dense_surrogate_consistency():
    """All four miners agree on a chess-like dense block."""
    from repro.data.synth import FIMI_SURROGATES, generate_dense

    rng = np.random.default_rng(7)
    spec = FIMI_SURROGATES["chess"]
    rows = generate_dense(spec, rng, 120)
    min_count = 84  # 70%
    res = mine_prepost(rows, spec.n_items, min_count)
    fp, _ = mine_fpgrowth(rows, spec.n_items, min_count)
    assert res.itemsets == fp
    res_cpe = mine_prepost(rows, spec.n_items, min_count, cpe=True)
    assert res_cpe.total_count == len(fp)
