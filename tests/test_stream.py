"""Streaming ingestion: segmented N-list databases (repro.mining.stream).

Anchors, per the PR acceptance criteria:
  - parity: N appended batches answer identically to a one-shot ``mine()``
    over the concatenated rows and to the brute-force oracle, across
    min_sup boundaries, PAD-heavy batches, and F-list growth (a batch
    introducing never-seen items);
  - incrementality: appending to an S-segment database runs prep stages
    on exactly one segment (stage counters — no full rebuild);
  - compaction reduces the segment count while preserving query answers
    bit-for-bit (sync and async);
  - per-segment snapshots warm-start a replayed stream with zero prep.
"""
import numpy as np
import pytest

from repro.core.oracle import mine_bruteforce
from repro.data.synth import random_db
from repro.mining import MineSpec, MiningEngine
from repro.mining.service import MiningService
from repro.mining.stream import StreamSpec

SPEC = MineSpec(algorithm="hprepost", max_k=4, candidate_unit=8, min_sup=0.3)


def _batches(seed=0, sizes=(30, 14, 22), n_items=10, max_len=6):
    rng = np.random.default_rng(seed)
    return [random_db(rng, n, n_items, max_len) for n in sizes], n_items


def _stream_engine(batches, n_items, spec=SPEC, stream_spec=None, **eng_kwargs):
    eng = MiningEngine(**eng_kwargs)
    for b in batches:
        eng.append(b, n_items, spec=spec, stream_spec=stream_spec)
    return eng


# ---------------------------------------------------------------- parity
@pytest.mark.parametrize("min_sup", [0.5, 0.3, 0.2, 0.1])
def test_stream_matches_oneshot_and_oracle(min_sup):
    batches, n_items = _batches(1, sizes=(25, 18, 31, 12))
    eng = _stream_engine(batches, n_items)
    res = eng.submit_stream(SPEC.with_(min_sup=min_sup))
    allrows = np.concatenate(batches)
    assert res.n_rows == len(allrows)
    oneshot = MiningEngine().submit(allrows, n_items, SPEC.with_(min_sup=min_sup))
    oracle = mine_bruteforce(allrows, n_items, res.min_count, max_k=SPEC.max_k)
    assert res.itemsets == oneshot.itemsets == oracle
    assert res.min_count == oneshot.min_count


def test_stream_min_count_spec_and_fractional_boundary():
    # 7 + 3 rows: min_sup=0.3 over 10 rows must demand count 3 (ceiling)
    batches, n_items = _batches(2, sizes=(7, 3))
    eng = _stream_engine(batches, n_items)
    res = eng.submit_stream(SPEC.with_(min_sup=0.3))
    assert res.min_count == 3
    allrows = np.concatenate(batches)
    assert res.itemsets == mine_bruteforce(allrows, n_items, 3, max_k=4)
    res_c = eng.submit_stream(SPEC.with_(min_count=2))
    assert res_c.itemsets == mine_bruteforce(allrows, n_items, 2, max_k=4)


def test_stream_pad_heavy_batches():
    from repro.core.encoding import pad_transactions

    # short transactions padded wide, plus entirely empty rows
    b1 = pad_transactions([[0], [1, 2], [], [0, 2]], max_len=8)
    b2 = pad_transactions([[2], [], [], [0, 1, 2]], max_len=8)
    b3 = np.full((3, 8), -1, np.int32)  # an all-PAD batch (rows still count)
    eng = MiningEngine()
    for b in (b1, b2, b3):
        eng.append(b, 3, spec=SPEC)
    assert eng.stream().stats["empty_batches"] == 1
    res = eng.submit_stream(SPEC.with_(min_sup=0.2))
    allrows = np.concatenate([b1, b2, b3])
    assert res.n_rows == 11  # empty rows resolve thresholds too
    assert res.itemsets == mine_bruteforce(allrows, 3, res.min_count, max_k=4)


def test_stream_flist_growth_on_unseen_items():
    rng = np.random.default_rng(5)
    b1 = random_db(rng, 24, 5, 4)  # items 0..4 only
    b2 = random_db(rng, 24, 12, 6)  # introduces 5..11 mid-stream
    eng = MiningEngine()
    s1 = eng.append(b1, 12, spec=SPEC)
    s2 = eng.append(b2, 12, spec=SPEC)
    assert s1["new_items"] == 5 and s2["new_items"] == 7
    res = eng.submit_stream(SPEC.with_(min_sup=0.15))
    b1w = np.pad(b1, ((0, 0), (0, b2.shape[1] - b1.shape[1])), constant_values=-1)
    allrows = np.concatenate([b1w, b2])
    assert res.itemsets == mine_bruteforce(allrows, 12, res.min_count, max_k=4)


def test_stream_row_padding_is_support_neutral():
    batches, n_items = _batches(6, sizes=(13, 9, 17))
    padded = _stream_engine(batches, n_items, stream_spec=StreamSpec(row_pad=16))
    plain = _stream_engine(batches, n_items)
    a = padded.submit_stream(SPEC.with_(min_sup=0.2))
    b = plain.submit_stream(SPEC.with_(min_sup=0.2))
    assert a.n_rows == b.n_rows == 39  # pad rows don't shift thresholds
    assert a.itemsets == b.itemsets


# --------------------------------------------------------- incrementality
def test_append_preps_exactly_one_segment():
    batches, n_items = _batches(7, sizes=(20, 25, 15, 30))
    eng = MiningEngine()
    eng.append(batches[0], n_items, spec=SPEC)
    stream = eng.stream()
    miner = stream.miner
    for b in batches[1:]:
        before = dict(miner.stage_counters)
        eng.append(b, n_items, spec=SPEC)
        delta = {k: miner.stage_counters[k] - before.get(k, 0)
                 for k in miner.stage_counters}
        # the map step runs on the new batch alone: one Job 2 / pack / F2,
        # no device Job 1 (the host histogram is the stream's word count),
        # and — the no-full-rebuild guarantee — nothing times S
        assert delta["job2"] == 1 and delta["pack"] == 1 and delta["f2"] == 1
        assert delta["job1"] == 0 and delta["waves"] == 0
    assert stream.stats["seg_prepares"] == len(batches)
    assert eng.stats["prepares"] == 0  # group-prep counter untouched
    # queries run waves only — no prep stage moves
    before = dict(miner.stage_counters)
    eng.submit_stream(SPEC.with_(min_sup=0.1))
    after = miner.stage_counters
    assert all(after[k] == before[k] for k in ("job1", "job2", "pack", "f2"))
    assert after["waves"] > before["waves"]


def test_stream_requires_matching_device_config_and_algorithm():
    batches, n_items = _batches(8, sizes=(12,))
    eng = _stream_engine(batches, n_items)
    with pytest.raises(ValueError, match="device config"):
        eng.submit_stream(SPEC.with_(candidate_unit=64))
    with pytest.raises(ValueError, match="hprepost"):
        eng.submit_stream(MineSpec(algorithm="apriori", min_sup=0.3))
    with pytest.raises(KeyError, match="no stream"):
        eng.submit_stream(SPEC, stream="nope")
    eng.append(batches[0])  # existing stream: n_items may be omitted
    with pytest.raises(ValueError, match="n_items"):
        MiningEngine().append(batches[0])  # creation needs n_items
    with pytest.raises(ValueError, match="n_items"):
        eng.stream(n_items=n_items + 1)  # must match at re-touch


# ------------------------------------------------------------- compaction
@pytest.mark.parametrize("compact_async", [False, True])
def test_compaction_preserves_answers_bit_for_bit(compact_async):
    batches, n_items = _batches(9, sizes=(14, 9, 21, 7, 26, 11))
    ss = StreamSpec(max_segments=3, compact_fanin=3, compact_async=compact_async)
    eng = _stream_engine(batches, n_items, stream_spec=ss)
    stream = eng.stream()
    stream.flush()
    assert stream.stats["compactions"] >= 1
    assert len(stream.db.segments) < len(batches)
    res = eng.submit_stream(SPEC.with_(min_sup=0.15))
    flat = _stream_engine(batches, n_items)  # same appends, no compaction
    ref = flat.submit_stream(SPEC.with_(min_sup=0.15))
    assert len(flat.stream().db.segments) == len(batches)
    assert res.itemsets == ref.itemsets
    assert res.itemsets == mine_bruteforce(
        np.concatenate(batches), n_items, res.min_count, max_k=4
    )


def test_forced_compaction_pass_reduces_segments():
    batches, n_items = _batches(10, sizes=(10, 12, 9, 11))
    eng = _stream_engine(batches, n_items)  # defaults: no auto trigger
    stream = eng.stream()
    before = eng.submit_stream(SPEC.with_(min_sup=0.2))
    assert len(stream.db.segments) == 4
    stream.compact()
    assert len(stream.db.segments) == 1  # fanin 4 folds them all
    assert stream.stats["segments_compacted"] == 4
    after = eng.submit_stream(SPEC.with_(min_sup=0.2))
    assert before.itemsets == after.itemsets


def test_auto_compaction_failure_never_fails_the_append():
    batches, n_items = _batches(18, sizes=(10, 11, 12))
    ss = StreamSpec(max_segments=2, compact_fanin=2)
    eng = MiningEngine()
    eng.append(batches[0], n_items, spec=SPEC, stream_spec=ss)
    eng.append(batches[1], n_items, spec=SPEC)
    stream = eng.stream()

    def boom(*a, **k):
        raise RuntimeError("merge prepare blew up")

    stream._compact_job = boom
    # the 3rd append trips the auto trigger; its data must land anyway
    st = eng.append(batches[2], n_items)
    assert st["segments"] == 3 and st["total_rows"] == 33
    res = eng.submit_stream(SPEC.with_(min_sup=0.2))
    assert res.itemsets == mine_bruteforce(
        np.concatenate(batches), n_items, res.min_count, max_k=4
    )
    # ... but an EXPLICIT pass propagates the failure to its caller
    with pytest.raises(RuntimeError, match="blew up"):
        stream.compact()


def test_small_byte_fraction_trigger():
    batches, n_items = _batches(11, sizes=(6, 7, 5, 8))
    ss = StreamSpec(small_rows=50, small_byte_frac=0.5, compact_fanin=4)
    eng = _stream_engine(batches, n_items, stream_spec=ss)
    stream = eng.stream()
    stream.flush()
    # every segment is "small": the byte fraction fires well before
    # max_segments (16) would
    assert stream.stats["compactions"] >= 1
    assert len(stream.db.segments) < 4


# ---------------------------------------------------- snapshot warm-start
def test_segment_snapshots_warm_start_replayed_stream(tmp_path):
    batches, n_items = _batches(12, sizes=(18, 23, 14))
    eng = _stream_engine(batches, n_items, snapshot_dir=str(tmp_path))
    ref = eng.submit_stream(SPEC)
    s1 = eng.stream().stats
    assert s1["seg_prepares"] == 3 and s1["seg_snapshot_hits"] == 0

    # "process restart": a fresh engine replays the same append log
    eng2 = _stream_engine(batches, n_items, snapshot_dir=str(tmp_path))
    s2 = eng2.stream().stats
    assert s2["seg_prepares"] == 0  # every segment restored from disk
    assert s2["seg_snapshot_hits"] == 3
    res = eng2.submit_stream(SPEC)
    assert res.itemsets == ref.itemsets

    # a replay with different history must NOT hit the same snapshots:
    # the key carries the imposed item order, not just the batch bytes
    eng3 = _stream_engine(batches[::-1], n_items, snapshot_dir=str(tmp_path))
    res3 = eng3.submit_stream(SPEC)
    assert res3.itemsets == ref.itemsets  # answers agree regardless
    assert eng3.stream().stats["seg_prepares"] >= 1


def test_segment_set_digest_tracks_layout():
    batches, n_items = _batches(13, sizes=(10, 12))
    eng = _stream_engine(batches[:1], n_items)
    d1 = eng.stream().db.digest()
    eng.append(batches[1], n_items)
    d2 = eng.stream().db.digest()
    assert d1 != d2
    r = eng.submit_stream(SPEC)
    assert r.service_stats["stream_digest"] == d2
    assert r.service_stats["stream_segments"] == 2
    assert r.service_stats["prep_source"] == "stream"
    assert r.prep_shared  # prep was paid at append time, not by the query


# ------------------------------------------------------- service wiring
def test_service_append_then_query_sees_the_segment():
    batches, n_items = _batches(14, sizes=(20, 16))
    with MiningService(batch_window_s=0.25) as svc:
        fa = svc.append(batches[0], n_items, spec=SPEC)
        fb = svc.append(batches[1], n_items, spec=SPEC)
        fq = svc.submit_stream(SPEC)
        fm = svc.submit(np.concatenate(batches), n_items, SPEC)
        sa, sb = fa.result(timeout=120), fb.result(timeout=120)
        rq, rm = fq.result(timeout=120), fm.result(timeout=120)
    assert sa["segments"] == 1 and sb["segments"] == 2
    assert rq.n_rows == 36  # the query observed both earlier appends
    assert rq.itemsets == rm.itemsets
    assert rq.service_stats["batch_size"] == 4


def test_service_append_copies_at_submit_time():
    batches, n_items = _batches(19, sizes=(14, 14))
    buf = batches[0].copy()
    with MiningService(batch_window_s=0.3) as svc:
        svc.append(buf, n_items, spec=SPEC)
        buf[:] = batches[1]  # caller reuses its buffer inside the window
        svc.append(buf, n_items)
        fq = svc.submit_stream(SPEC.with_(min_sup=0.2))
        rq = fq.result(timeout=120)
    # both intended batches were ingested — not batch[1] twice
    allrows = np.concatenate(batches)
    assert rq.itemsets == mine_bruteforce(allrows, n_items, rq.min_count, max_k=4)


def test_service_stream_failure_is_isolated():
    batches, n_items = _batches(15, sizes=(15,))
    with MiningService(batch_window_s=0.2) as svc:
        bad = svc.submit_stream(SPEC)  # no such stream yet
        good = svc.append(batches[0], n_items, spec=SPEC)
        with pytest.raises(KeyError):
            bad.result(timeout=120)
        assert good.result(timeout=120)["segments"] == 1


# ------------------------------------------------------- additivity anchor
def test_additivity_exhaustive_paper_db(paper_db):
    """Deterministic (hypothesis-free) anchor for the reduce-step
    invariant: every 2-way split of the paper's Table 1 database is
    support-additive for every itemset up to k=3. The randomized version
    (arbitrary DBs, up to 4-way partitions) lives in
    tests/test_stream_properties.py under hypothesis."""
    from repro.core.encoding import pad_transactions

    rows, n_items = paper_db
    full = mine_bruteforce(rows, n_items, 1, max_k=3)
    tx = [[int(i) for i in r if i >= 0] for r in rows]
    n = len(tx)

    def _mine(part):
        if not part:
            return {}
        return mine_bruteforce(
            pad_transactions(part, max_len=rows.shape[1]), n_items, 1, max_k=3
        )

    for mask in range(2 ** (n - 1)):  # up to symmetry
        pa = _mine([tx[i] for i in range(n) if (mask >> i) & 1])
        pb = _mine([tx[i] for i in range(n) if not (mask >> i) & 1])
        for itemset, support in full.items():
            assert support == pa.get(itemset, 0) + pb.get(itemset, 0)
        for itemset in (*pa, *pb):
            assert itemset in full


# ------------------------------------------------------------- edge cases
def test_stream_query_paths_max_k1_and_empty():
    batches, n_items = _batches(16, sizes=(12,))
    eng = _stream_engine(batches, n_items)
    r1 = eng.submit_stream(SPEC.with_(max_k=1))
    full = eng.submit_stream(SPEC)
    assert r1.itemsets == {k: v for k, v in full.itemsets.items() if len(k) == 1}
    # a stream with no rows answers empty instead of erroring
    eng2 = MiningEngine()
    eng2.stream(n_items=5, spec=SPEC)
    r = eng2.submit_stream(SPEC)
    assert r.itemsets == {} and r.n_rows == 0


def test_append_copies_the_batch():
    batches, n_items = _batches(17, sizes=(15, 10))
    eng = MiningEngine()
    b0 = batches[0].copy()
    eng.append(b0, n_items, spec=SPEC)
    ref = eng.submit_stream(SPEC)
    b0[:] = -1  # caller scribbles over its batch after the append
    eng.append(batches[1], n_items)
    eng.stream().compact()  # compaction re-prepares from the stream's copy
    res = eng.submit_stream(SPEC)
    allrows = np.concatenate([batches[0], batches[1]])
    assert res.itemsets == mine_bruteforce(allrows, n_items, res.min_count, max_k=4)
    del ref
