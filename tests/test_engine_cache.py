"""Persistent PreparedDB cache: repeated ad-hoc ``submit`` s must stop
re-running Job 1 / Job 2 / pack / F2, under an LRU byte budget.

The acceptance anchor: two consecutive ``engine.submit`` s on the same rows
re-run zero prep stages — proven by the miner's ``stage_counters`` and the
engine's ``cache_info()`` — and eviction honors ``prep_cache_bytes``.
"""
import numpy as np
import pytest

from repro.data.synth import random_db
from repro.mining import MineSpec, MiningEngine

SPEC = MineSpec(algorithm="hprepost", max_k=4, candidate_unit=8, min_sup=0.3,
                nlist_width=16)


def _db(seed=0, n_tx=60, n_items=10):
    return random_db(np.random.default_rng(seed), n_tx, n_items, 6), n_items


def _counters(eng, spec=SPEC):
    return dict(eng.frontend("hprepost").miner_for(spec).stage_counters)


def test_second_submit_reruns_zero_prep_stages():
    rows, n_items = _db()
    eng = MiningEngine()
    r1 = eng.submit(rows, n_items, SPEC)
    c1 = _counters(eng)
    assert c1["job1"] == c1["job2"] == c1["pack"] == c1["f2"] == 1
    assert not r1.prep_shared

    r2 = eng.submit(rows, n_items, SPEC)
    c2 = _counters(eng)
    for stage in ("job1", "job2", "pack", "f2"):
        assert c2[stage] == 1, stage  # zero prep re-runs
    info = eng.cache_info()
    assert info["hits"] == 1 and info["misses"] == 1 and info["entries"] == 1
    assert info["bytes_in_use"] > 0
    assert r2.prep_shared  # honest attribution: this submit paid no prep
    for k in ("job1_flist", "job2_ppc_pack", "f2_scan"):
        assert r2.stage_times_s[k] == 0.0
    assert r2.itemsets == r1.itemsets and r2.peak_bytes == r1.peak_bytes


def test_tighter_threshold_served_looser_rebuilds():
    rows, n_items = _db(1)
    eng = MiningEngine()
    eng.submit(rows, n_items, SPEC.with_(min_sup=0.2))
    # tighter threshold: floor structures are supersets -> cache hit
    tight = eng.submit(rows, n_items, SPEC.with_(min_sup=0.4))
    assert eng.cache_info()["hits"] == 1
    assert _counters(eng)["job1"] == 1
    assert tight.itemsets == MiningEngine().submit(
        rows, n_items, SPEC.with_(min_sup=0.4)).itemsets
    # looser threshold: below the cached floor -> rebuild, entry replaced
    loose = eng.submit(rows, n_items, SPEC.with_(min_sup=0.1))
    info = eng.cache_info()
    assert info["misses"] == 2 and info["entries"] == 1
    assert _counters(eng)["job1"] == 2
    assert loose.itemsets == MiningEngine().submit(
        rows, n_items, SPEC.with_(min_sup=0.1)).itemsets


def test_f1_only_entry_upgrades_for_wave_traffic():
    rows, n_items = _db(2)
    eng = MiningEngine()
    spec = SPEC.with_(min_sup=0.15)  # frequent pairs exist at this threshold
    eng.submit(rows, n_items, spec.with_(max_k=1))
    assert _counters(eng)["job2"] == 0  # F1-only prep skipped the tree build
    res = eng.submit(rows, n_items, spec.with_(max_k=3))  # needs waves: rebuild
    assert eng.cache_info()["misses"] == 2
    assert _counters(eng)["job2"] == 1
    assert any(len(s) > 1 for s in res.itemsets)
    # and the upgraded (full) entry serves max_k=1 traffic right back
    r1 = eng.submit(rows, n_items, spec.with_(max_k=1))
    assert eng.cache_info()["hits"] == 1
    assert all(len(s) == 1 for s in r1.itemsets)


def test_f1_only_build_never_evicts_wave_state():
    rows, n_items = _db(11)
    eng = MiningEngine()
    spec = SPEC.with_(min_sup=0.3)
    eng.submit(rows, n_items, spec)  # full entry (Job2/pack/F2) at floor 0.3
    # a looser max_k=1 request misses (floor too tight) and builds F1-only
    # prep — but must not replace the expensive waves-capable entry
    eng.submit(rows, n_items, spec.with_(min_sup=0.2, max_k=1))
    assert eng.cache_info()["entries"] == 1
    # ...which keeps serving k>1 traffic at the original floor prep-free
    res = eng.submit(rows, n_items, spec)
    assert eng.cache_info()["hits"] == 1 and res.prep_shared
    assert _counters(eng)["job2"] == 1  # the tree build ran exactly once


def test_eviction_honors_byte_budget():
    rows_a, n_items = _db(3)
    rows_b, _ = _db(4)  # same shape + nlist_width -> same prep footprint
    probe = MiningEngine()
    probe.submit(rows_a, n_items, SPEC)
    one = probe.cache_info()["bytes_in_use"]
    assert one > 0

    eng = MiningEngine(prep_cache_bytes=int(one * 1.5))  # fits 1, not 2
    eng.submit(rows_a, n_items, SPEC)
    eng.submit(rows_b, n_items, SPEC)
    info = eng.cache_info()
    assert info["evictions"] == 1 and info["entries"] == 1
    assert info["bytes_in_use"] <= info["byte_budget"]
    # rows_a was the LRU victim: resubmitting it is a miss again
    eng.submit(rows_a, n_items, SPEC)
    assert eng.cache_info()["misses"] == 3
    # rows_b stays warm until evicted in turn
    assert _counters(eng)["job1"] == 3


def test_lru_order_is_recency_not_insertion():
    rows_a, n_items = _db(5)
    rows_b, _ = _db(6)
    rows_c, _ = _db(7)
    probe = MiningEngine()
    probe.submit(rows_a, n_items, SPEC)
    one = probe.cache_info()["bytes_in_use"]

    eng = MiningEngine(prep_cache_bytes=int(one * 2.5))  # fits 2, not 3
    eng.submit(rows_a, n_items, SPEC)
    eng.submit(rows_b, n_items, SPEC)
    eng.submit(rows_a, n_items, SPEC)  # touch a: b becomes the LRU entry
    eng.submit(rows_c, n_items, SPEC)  # evicts b, not a
    assert eng.cache_info()["evictions"] == 1
    eng.submit(rows_a, n_items, SPEC)
    assert eng.cache_info()["hits"] == 2  # a survived both inserts


def test_zero_budget_disables_caching():
    rows, n_items = _db(8)
    eng = MiningEngine(prep_cache_bytes=0)
    r1 = eng.submit(rows, n_items, SPEC)
    r2 = eng.submit(rows, n_items, SPEC)
    info = eng.cache_info()
    assert info["entries"] == 0 and info["hits"] == 0 and info["misses"] == 0
    assert _counters(eng)["job1"] == 2  # one-shot path both times
    assert r1.itemsets == r2.itemsets


def test_sweep_then_adhoc_submit_hits_group_prep():
    rows, n_items = _db(9)
    eng = MiningEngine()
    eng.sweep(rows, n_items, SPEC, [0.4, 0.2])
    assert eng.stats["prepares"] == 1
    # ad-hoc traffic after the sweep rides the group's PreparedDB
    res = eng.submit(rows, n_items, SPEC.with_(min_sup=0.3))
    assert eng.cache_info()["hits"] == 1
    assert _counters(eng)["job1"] == 1
    assert res.prep_shared
    assert res.itemsets == MiningEngine().submit(
        rows, n_items, SPEC.with_(min_sup=0.3)).itemsets


def test_different_device_config_is_a_different_entry():
    rows, n_items = _db(10)
    eng = MiningEngine()
    eng.submit(rows, n_items, SPEC)
    eng.submit(rows, n_items, SPEC.with_(candidate_unit=16))
    info = eng.cache_info()
    assert info["entries"] == 2 and info["misses"] == 2 and info["hits"] == 0


def test_execution_only_knobs_share_one_entry():
    """PR 7: kernel blocks / backend / early_stop / tune are execution-only
    knobs — a retune or backend switch must keep hitting the warm
    PreparedDB (same LRU entry), never re-run prep, and answer
    bit-identically."""
    rows, n_items = _db(18)
    eng = MiningEngine()
    base = eng.submit(rows, n_items, SPEC)
    variants = (
        SPEC.with_(la_block=128, ly_block=128, batch_block=4),
        SPEC.with_(backend="jnp"),
        SPEC.with_(early_stop=False),
        SPEC.with_(tune=True),
    )
    for spec in variants:
        res = eng.submit(rows, n_items, spec)
        assert res.prep_shared, spec
        assert res.itemsets == base.itemsets, spec
    info = eng.cache_info()
    assert info["entries"] == 1 and info["misses"] == 1
    assert info["hits"] == len(variants)
    assert _counters(eng)["job1"] == 1  # prep ran exactly once


def test_snapshot_warm_across_execution_config_change(tmp_path):
    """PR 7: snapshot keys are block-independent too — a cold process with
    different execution knobs must warm-start from the other process's
    spilled PreparedDB."""
    rows, n_items = _db(19)
    MiningEngine(snapshot_dir=str(tmp_path)).submit(rows, n_items, SPEC)
    eng2 = MiningEngine(snapshot_dir=str(tmp_path))
    res = eng2.submit(
        rows, n_items,
        SPEC.with_(la_block=128, batch_block=4, backend="jnp", early_stop=False),
    )
    assert res.service_stats["prep_source"] == "snapshot"
    info = eng2.cache_info()
    assert info["snapshot_hits"] == 1 and info["snapshot_misses"] == 0
    assert _counters(eng2)["job1"] == 0  # zero prep stages in this process


# ---------------------------------------------- fingerprint memoization
def test_fingerprint_memoized_per_array_identity(monkeypatch):
    rows, n_items = _db(12)
    eng = MiningEngine()
    digests = []
    real = MiningEngine._digest
    monkeypatch.setattr(
        MiningEngine, "_digest",
        staticmethod(lambda arr: digests.append(1) or real(arr)),
    )
    eng.submit(rows, n_items, SPEC)
    eng.submit(rows, n_items, SPEC.with_(min_sup=0.35))
    eng.sweep(rows, n_items, SPEC, [0.4, 0.35])
    assert len(digests) == 1  # the resident DB was hashed exactly once
    # same content in a different array object: re-hashed, same cache entry
    eng.submit(rows.copy(), n_items, SPEC)
    assert len(digests) == 2
    assert eng.cache_info()["entries"] == 1


def test_fingerprint_memo_invalidation_story():
    rows, n_items = _db(13)
    eng = MiningEngine()
    fp1 = eng._fingerprint(rows)
    assert eng._fingerprint(rows) == fp1 and len(eng._fp_memo) == 1

    # memoization froze the array: silent in-place mutation is impossible
    assert not rows.flags.writeable
    with pytest.raises(ValueError, match="read-only"):
        rows[0, 0] = (rows[0, 0] + 1) % n_items

    # sanctioned route 1: invalidate_fingerprints restores writeability
    eng.invalidate_fingerprints(rows)
    assert rows.flags.writeable
    rows[0, 0] = (rows[0, 0] + 1) % n_items
    fp2 = eng._fingerprint(rows)
    assert fp2 != fp1

    # sanctioned route 2: unfreezing by hand auto-invalidates on next use
    rows.setflags(write=True)
    rows[0, 0] = (rows[0, 0] + 1) % n_items
    fp2b = eng._fingerprint(rows)
    assert fp2b != fp2

    # a dead array's memo slot can never serve a recycled id: the weakref
    # guard forces a re-hash for any new object, whatever id() it got
    ident = id(rows)
    del rows
    other = np.full((3, 2), 1, np.int32)
    fp3 = eng._fingerprint(other)
    assert fp3 != fp2b and fp3[0] == (3, 2)
    eng.invalidate_fingerprints()
    assert other.flags.writeable  # bulk invalidation thaws every live array
    assert not eng._fp_memo
    del ident


def test_in_place_mutation_cannot_serve_stale_prep():
    """The PR 4 memo hole, closed: mutating a submitted array in place can
    never make the engine answer from the stale PreparedDB — the direct
    write raises, and both sanctioned mutation routes invalidate the memo
    so the next submit re-hashes and re-prepares."""
    rows, n_items = _db(15)
    eng = MiningEngine()
    first = eng.submit(rows, n_items, SPEC)
    with pytest.raises(ValueError, match="read-only"):
        rows[0, 0] = (rows[0, 0] + 1) % n_items

    rows.setflags(write=True)
    rng = np.random.default_rng(16)
    rows[:] = random_db(rng, len(rows), n_items, rows.shape[1])
    res = eng.submit(rows, n_items, SPEC)
    fresh = MiningEngine().submit(rows.copy(), n_items, SPEC)
    assert res.itemsets == fresh.itemsets
    del first
    # two distinct databases -> two cache entries, nothing overwritten
    assert eng.cache_info()["entries"] == 2
    # the resubmitted array is frozen again (memoized anew)
    assert not rows.flags.writeable


def test_preexisting_writeable_view_cannot_serve_stale_prep():
    """The residual memo hole, closed: a writeable view taken *before*
    the first submit keeps its own writeable flag when the memo freezes
    the base, so writing through it mutates the frozen array without
    tripping any flag. The stride-sampled digest re-checked on every hit
    catches the changed bytes and forces a full re-hash + re-prepare."""
    rows, n_items = _db(17)
    view = rows[: len(rows) // 2]  # writeable view, taken before submit
    eng = MiningEngine()
    first = eng.submit(rows, n_items, SPEC)
    assert not rows.flags.writeable and view.flags.writeable

    view[0, :] = view[1, :]  # mutates the frozen base, no flag moves
    res = eng.submit(rows, n_items, SPEC)
    fresh = MiningEngine().submit(rows.copy(), n_items, SPEC)
    assert res.itemsets == fresh.itemsets
    del first
    # the stale hit was detected: a second content entry, nothing reused
    assert eng.cache_info()["entries"] == 2
    # the re-memoized entry still remembers the memo froze this array
    eng.invalidate_fingerprints(rows)
    assert rows.flags.writeable
