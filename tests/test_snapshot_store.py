"""Cross-process PreparedDB persistence: the snapshot store.

Acceptance anchor (ISSUE 4): a fresh process pointed at a snapshot dir
serves a sweep with ``prepares == 0`` in engine stats, zero prep stage
counters on the miner, zeroed prep stage keys on every result, and
itemsets identical to a cold mine. Plus: corrupted/partial snapshots are
rejected (and healed), the store GC honors its byte budget, and shard-
count mismatches degrade to a rebuild instead of wrong answers.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.data.synth import random_db
from repro.mining import MineRequest, MineSpec, MiningEngine, SnapshotStore

SPEC = MineSpec(algorithm="hprepost", max_k=4, candidate_unit=8, min_sup=0.3,
                nlist_width=16)
PREP_KEYS = ("job1_flist", "job2_ppc_pack", "f2_scan")


def _db(seed=0, n_tx=60, n_items=10):
    return random_db(np.random.default_rng(seed), n_tx, n_items, 6), n_items


def _counters(eng, spec=SPEC):
    return dict(eng.frontend("hprepost").miner_for(spec).stage_counters)


# ---------------------------------------------------------- warm-start parity
def test_fresh_engine_warm_starts_sweep_with_zero_prep_stages(tmp_path):
    rows, n_items = _db()
    cold = MiningEngine(snapshot_dir=str(tmp_path))
    ref = cold.sweep(rows, n_items, SPEC, [0.4, 0.3, 0.2])
    assert cold.snapshot_store.stats["stores"] == 1

    warm = MiningEngine(snapshot_dir=str(tmp_path))  # fresh "process"
    out = warm.sweep(rows, n_items, SPEC, [0.4, 0.3, 0.2])
    assert warm.stats["prepares"] == 0  # the acceptance criterion
    c = _counters(warm)
    assert c["job1"] == c["job2"] == c["pack"] == c["f2"] == 0
    assert warm.cache_info()["snapshot_hits"] == 1
    for a, b in zip(ref, out):
        assert b.itemsets == a.itemsets
        assert b.total_count == a.total_count
        assert b.peak_bytes == a.peak_bytes
        assert b.prep_shared  # nobody paid prep in this process
        assert b.service_stats["prep_source"] == "snapshot"
        for k in PREP_KEYS:  # zeroed prep stage keys
            assert b.stage_times_s[k] == 0.0


def test_adhoc_submit_warm_starts_and_loads_once(tmp_path):
    rows, n_items = _db(1)
    ref = MiningEngine(snapshot_dir=str(tmp_path)).submit(rows, n_items, SPEC)

    warm = MiningEngine(snapshot_dir=str(tmp_path))
    r1 = warm.submit(rows, n_items, SPEC)
    r2 = warm.submit(rows, n_items, SPEC)
    assert r1.itemsets == ref.itemsets and r2.itemsets == ref.itemsets
    info = warm.cache_info()
    # disk is consulted once; the loaded entry then serves from the LRU
    assert info["snapshot_hits"] == 1 and info["hits"] == 1
    assert r1.service_stats["prep_source"] == "snapshot"
    assert r2.service_stats["prep_source"] == "cache"
    assert _counters(warm)["job1"] == 0


def test_tighter_threshold_served_from_snapshot_looser_rebuilds(tmp_path):
    rows, n_items = _db(2)
    MiningEngine(snapshot_dir=str(tmp_path)).submit(rows, n_items, SPEC)

    warm = MiningEngine(snapshot_dir=str(tmp_path))
    tight = warm.submit(rows, n_items, SPEC.with_(min_sup=0.4))
    assert tight.service_stats["prep_source"] == "snapshot"
    # looser than the stored floor: unusable -> rebuild (and re-spill)
    loose = warm.submit(rows, n_items, SPEC.with_(min_sup=0.15))
    assert loose.service_stats["prep_source"] == "built"
    assert warm.cache_info()["snapshot_misses"] == 1
    assert _counters(warm)["job1"] == 1
    fresh = MiningEngine()
    assert loose.itemsets == fresh.submit(rows, n_items, SPEC.with_(min_sup=0.15)).itemsets
    # the re-spill replaced the entry: its looser floor serves a third process
    third = MiningEngine(snapshot_dir=str(tmp_path))
    assert third.submit(
        rows, n_items, SPEC.with_(min_sup=0.15)
    ).service_stats["prep_source"] == "snapshot"


def test_spill_policy_keeps_the_better_entry(tmp_path):
    rows, n_items = _db(3)
    eng = MiningEngine(snapshot_dir=str(tmp_path))
    eng.submit(rows, n_items, SPEC)
    store = eng.snapshot_store
    assert store.stats["stores"] == 1
    # a tighter-floor rebuild in another "process" must not degrade the store
    other = MiningEngine(snapshot_store=store)
    other.clear_prep_cache()
    other.submit(rows, n_items, SPEC.with_(min_sup=0.4))  # snapshot hit, no spill
    assert store.stats["stores"] == 1
    # F1-only prep never replaces wave state on disk either, even at a
    # looser floor: the spill is refused, the full entry keeps serving
    other2 = MiningEngine(snapshot_store=SnapshotStore(str(tmp_path)))
    res = other2.submit(rows, n_items, SPEC.with_(max_k=1, min_sup=0.2))
    assert res.itemsets  # built F1-only (floor 0.2 < stored 0.3 -> miss)
    assert other2.snapshot_store.stats["store_skips"] == 1
    (entry,) = other2.snapshot_store.entries()
    meta = other2.snapshot_store.peek_meta(os.path.basename(entry))
    assert meta["f1_only"] is False  # wave state survived the F1-only spill


# ----------------------------------------------------- corruption / partials
def _entry_paths(tmp_path):
    store = SnapshotStore(str(tmp_path))
    return store.entries()


def test_corrupted_array_is_rejected_deleted_and_healed(tmp_path):
    rows, n_items = _db(4)
    ref = MiningEngine(snapshot_dir=str(tmp_path)).submit(rows, n_items, SPEC)
    (entry,) = _entry_paths(tmp_path)
    target = os.path.join(entry, "packed.npy")
    raw = bytearray(open(target, "rb").read())
    raw[-1] ^= 0xFF  # flip one payload byte: digest must catch it
    open(target, "wb").write(bytes(raw))

    warm = MiningEngine(snapshot_dir=str(tmp_path))
    res = warm.submit(rows, n_items, SPEC)  # must rebuild, not crash/misread
    assert res.itemsets == ref.itemsets
    assert res.service_stats["prep_source"] == "built"
    info = warm.cache_info()["snapshot_store"]
    assert info["corrupt"] == 1
    assert warm.cache_info()["snapshot_misses"] == 1
    # the rejected entry was deleted and the rebuild re-spilled a good one
    assert info["stores"] == 1 and info["entries"] == 1
    third = MiningEngine(snapshot_dir=str(tmp_path))
    assert third.submit(
        rows, n_items, SPEC
    ).service_stats["prep_source"] == "snapshot"  # healed


def test_partial_snapshot_missing_manifest_is_a_miss(tmp_path):
    rows, n_items = _db(5)
    MiningEngine(snapshot_dir=str(tmp_path)).submit(rows, n_items, SPEC)
    (entry,) = _entry_paths(tmp_path)
    os.remove(os.path.join(entry, "manifest.json"))
    warm = MiningEngine(snapshot_dir=str(tmp_path))
    res = warm.submit(rows, n_items, SPEC)
    assert res.service_stats["prep_source"] == "built"
    assert warm.cache_info()["snapshot_store"]["corrupt"] == 1


def test_tampered_meta_shape_is_rejected_by_from_host(tmp_path):
    # digests pass (we re-sign), but the payload no longer matches itself:
    # from_host's structural validation is the last line of defense
    rows, n_items = _db(6)
    MiningEngine(snapshot_dir=str(tmp_path)).submit(rows, n_items, SPEC)
    (entry,) = _entry_paths(tmp_path)
    mpath = os.path.join(entry, "manifest.json")
    manifest = json.load(open(mpath))
    manifest["meta"]["width"] = manifest["meta"]["width"] * 2
    json.dump(manifest, open(mpath, "w"))
    warm = MiningEngine(snapshot_dir=str(tmp_path))
    res = warm.submit(rows, n_items, SPEC)
    assert res.service_stats["prep_source"] == "built"
    assert warm.cache_info()["snapshot_misses"] == 1


# ------------------------------------------------------------------ store GC
def test_gc_honors_byte_budget_and_evicts_oldest(tmp_path):
    rows_a, n_items = _db(7)
    rows_b, _ = _db(8)
    probe = MiningEngine(snapshot_dir=str(tmp_path / "probe"))
    probe.submit(rows_a, n_items, SPEC)
    one = probe.snapshot_store.bytes_in_use()
    assert one > 0

    store = SnapshotStore(str(tmp_path / "real"), byte_budget=int(one * 1.5))
    eng = MiningEngine(snapshot_store=store)
    eng.submit(rows_a, n_items, SPEC)
    os.utime(store.entries()[0], (1, 1))  # age entry a well below entry b
    eng.submit(rows_b, n_items, SPEC)
    info = store.info()
    assert info["evictions"] == 1 and info["entries"] == 1
    assert info["bytes_in_use"] <= info["byte_budget"]
    # the survivor is rows_b's entry: a fresh engine warm-starts on b, not a
    fresh = MiningEngine(snapshot_store=store)
    assert fresh.submit(rows_b, n_items, SPEC).service_stats["prep_source"] == "snapshot"
    fresh2 = MiningEngine(snapshot_store=store)
    assert fresh2.submit(rows_a, n_items, SPEC).service_stats["prep_source"] == "built"


def test_zero_budget_store_keeps_nothing(tmp_path):
    rows, n_items = _db(9)
    store = SnapshotStore(str(tmp_path), byte_budget=0)
    eng = MiningEngine(snapshot_store=store)
    eng.submit(rows, n_items, SPEC)
    assert store.info()["entries"] == 0 and store.stats["evictions"] == 1


def test_spill_failure_is_best_effort(tmp_path, monkeypatch):
    # a full/readonly disk must cost the snapshot, never the answer
    rows, n_items = _db(14)
    store = SnapshotStore(str(tmp_path))

    def broken_put(*a, **k):
        raise OSError("disk full")

    monkeypatch.setattr(store, "put", broken_put)
    eng = MiningEngine(snapshot_store=store)
    res = eng.submit(rows, n_items, SPEC)
    assert res.itemsets and res.service_stats["prep_source"] == "built"
    assert eng.cache_info()["snapshot_spill_failures"] == 1
    # the LRU entry made it in regardless: the next submit is prep-free
    assert eng.submit(rows, n_items, SPEC).service_stats["prep_source"] == "cache"


def test_checkpoint_keep_zero_retains_everything(tmp_path):
    # the GC refactor must preserve the old slicing semantics (keep=0
    # deleted nothing) for the checkpoint writer it was factored from
    from repro.checkpoint.ckpt import CheckpointManager

    mgr = CheckpointManager(str(tmp_path), keep=0)
    for step in (1, 2, 3):
        mgr.save(step, {"w": np.ones(2)})
    assert mgr.list_steps() == [1, 2, 3]


# --------------------------------------------------------- shard-count gates
def test_from_host_rejects_shard_count_mismatch():
    from repro.core.hprepost import HPrepostConfig, HPrepostMiner, PreparedDB
    from repro.mining.miners import default_mesh

    rows, n_items = _db(10)
    miner = HPrepostMiner(default_mesh(), config=HPrepostConfig(candidate_unit=8))
    payload = miner.prepare(rows, n_items, 12).to_host()
    payload["n_shards"] = 2
    with pytest.raises(ValueError, match="shard"):
        PreparedDB.from_host(payload, miner)


def test_cross_shard_count_warm_start_where_mesh_allows(tmp_path):
    # snapshots restore onto any mesh with the SAME data-shard count (the
    # model axis is free); a different D degrades to a clean rebuild. Needs
    # fake devices -> subprocess, like benchmarks/bench_scaling.
    script = textwrap.dedent(
        """
        import os, sys
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import numpy as np
        from repro.compat import make_mesh
        from repro.data.synth import random_db
        from repro.mining import MineSpec, MiningEngine

        snap = sys.argv[1]
        rows = random_db(np.random.default_rng(0), 60, 10, 6)
        spec = MineSpec(algorithm="hprepost", max_k=4, candidate_unit=8,
                        min_sup=0.3, nlist_width=16)

        writer = MiningEngine(make_mesh((2, 1), ("data", "model")), snapshot_dir=snap)
        ref = writer.submit(rows, 10, spec)

        # same D=2, different model-axis split: the mesh allows it
        same_d = MiningEngine(make_mesh((2, 1), ("data", "model")), snapshot_dir=snap)
        warm = same_d.submit(rows, 10, spec)
        assert warm.service_stats["prep_source"] == "snapshot", warm.service_stats
        assert same_d.stats["prepares"] == 0
        assert warm.itemsets == ref.itemsets

        # D=1 mesh: per-shard PPC state cannot re-shard -> rebuild, same answer
        other_d = MiningEngine(make_mesh((1, 2), ("data", "model")), snapshot_dir=snap)
        cold = other_d.submit(rows, 10, spec)
        assert cold.service_stats["prep_source"] == "built", cold.service_stats
        assert other_d.cache_info()["snapshot_misses"] == 1
        assert cold.itemsets == ref.itemsets
        print("OK")
        """
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", script, str(tmp_path)],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert proc.returncode == 0, proc.stderr
    assert "OK" in proc.stdout
