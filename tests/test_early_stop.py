"""Early-stopping intersections (PR 7): masked-kernel semantics vs the ref
model, exact-path bit-identity when disabled, and end-to-end answer parity
— single-process, pallas-interpret, streamed (PAD-heavy segments), and
distributed — against the legacy exact path and the brute-force oracle."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import encoding as enc
from repro.core.nlist import INF
from repro.core.oracle import mine_bruteforce
from repro.core.ppc import build_ppc
from repro.data.synth import random_db
from repro.kernels.nlist_intersect.kernel import (
    nlist_intersect_pallas,
    nlist_intersect_pallas_es,
)
from repro.kernels.nlist_intersect.ops import nlist_intersect
from repro.kernels.nlist_intersect.ref import (
    nlist_intersect_masked_ref,
    nlist_intersect_ref,
)
from repro.mining import MineSpec, MiningEngine
from repro.mining.stream import StreamSpec

SPEC = MineSpec(algorithm="hprepost", max_k=None, candidate_unit=8, min_sup=0.3)


def _nlist_batch_cnt(rng, B, La, Ly):
    """Tree-valid PP-code batches (as tests/test_kernels.py) plus A's node
    counts — the early-stop kernel's bound masses."""
    a_pre = np.full((B, La), INF, np.int32)
    a_post = np.full((B, La), -1, np.int32)
    a_cnt = np.zeros((B, La), np.int32)
    y_pre = np.full((B, Ly), INF, np.int32)
    y_post = np.full((B, Ly), -1, np.int32)
    y_cnt = np.zeros((B, Ly), np.int32)
    for b in range(B):
        n_items = int(rng.integers(2, 16))
        rows = random_db(rng, int(rng.integers(5, 120)), n_items, min(8, n_items))
        fl = enc.build_flist(enc.item_support(rows, n_items), 1)
        if fl.k < 2:
            continue
        urows, w = enc.dedup_rows(enc.rank_encode(rows, fl))
        if not len(urows):
            continue
        nls = build_ppc(urows, w).nlists(fl.k)
        qa, qy = sorted(rng.choice(fl.k, size=2, replace=False))
        A, Y = nls[qa][:La], nls[qy][:Ly]
        a_pre[b, : len(A)], a_post[b, : len(A)] = A[:, 0], A[:, 1]
        a_cnt[b, : len(A)] = A[:, 2]
        y_pre[b, : len(Y)], y_post[b, : len(Y)] = Y[:, 0], Y[:, 1]
        y_cnt[b, : len(Y)] = Y[:, 2]
    return map(jnp.asarray, (a_pre, a_post, a_cnt, y_pre, y_post, y_cnt))


# ------------------------------------------------------------ kernel layer
@pytest.mark.parametrize("min_count", [0, 1, 3, 10, 10_000])
@pytest.mark.parametrize("B,La,Ly", [(3, 8, 5), (5, 40, 70), (2, 130, 257)])
def test_masked_kernel_matches_masked_ref(B, La, Ly, min_count):
    """The interpreted early-stop kernel is bit-identical to its tile-order
    ref model, masked supports never exceed exact ones, and any candidate
    whose exact support reaches the threshold is returned exactly."""
    rng = np.random.default_rng(B * La + Ly + min_count)
    a_pre, a_post, a_cnt, y_pre, y_post, y_cnt = _nlist_batch_cnt(rng, B, La, Ly)
    got, sup = nlist_intersect_pallas_es(
        a_pre, a_post, a_cnt, y_pre, y_post, y_cnt, min_count,
        la_block=64, ly_block=64, batch_block=3, interpret=True,
    )
    want, wsup = nlist_intersect_masked_ref(
        a_pre, a_post, a_cnt, y_pre, y_post, y_cnt, min_count, la_block=64
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(sup), np.asarray(wsup))

    exact = np.asarray(nlist_intersect_ref(a_pre, a_post, y_pre, y_post, y_cnt))
    esup = exact.sum(axis=1)
    assert (np.asarray(sup) <= esup).all()
    reached = esup >= min_count
    np.testing.assert_array_equal(np.asarray(sup)[reached], esup[reached])
    np.testing.assert_array_equal(np.asarray(got)[reached], exact[reached])
    # a masked-out candidate's partial support stays below the threshold —
    # downstream thresholding cannot be confused by it
    assert (np.asarray(sup)[~reached] < max(min_count, 1)).all()


def test_stop_zero_is_bit_identical_to_exact_kernel():
    rng = np.random.default_rng(11)
    a_pre, a_post, a_cnt, y_pre, y_post, y_cnt = _nlist_batch_cnt(rng, 5, 40, 33)
    got, sup = nlist_intersect_pallas_es(
        a_pre, a_post, a_cnt, y_pre, y_post, y_cnt, 0,
        la_block=16, ly_block=16, batch_block=2, interpret=True,
    )
    want, wsup = nlist_intersect_pallas(
        a_pre, a_post, y_pre, y_post, y_cnt,
        la_block=16, ly_block=16, batch_block=2, interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(sup), np.asarray(wsup))


def test_op_dispatch_early_stop_vs_exact():
    """The op routes early_stop+min_count to the masked kernel and keeps
    the exact path (jnp, or early_stop=False) byte-stable."""
    rng = np.random.default_rng(5)
    a_pre, a_post, a_cnt, y_pre, y_post, y_cnt = _nlist_batch_cnt(rng, 4, 24, 24)
    exact, esup = nlist_intersect(a_pre, a_post, y_pre, y_post, y_cnt, backend="jnp")
    # early_stop on the exact-threshold-0 path: identical
    m0, s0 = nlist_intersect(
        a_pre, a_post, y_pre, y_post, y_cnt, a_cnt=a_cnt,
        backend="pallas-interpret", la_block=16, ly_block=16, batch_block=2,
        early_stop=True, min_count=0,
    )
    np.testing.assert_array_equal(np.asarray(m0), np.asarray(exact))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(esup))
    # a real threshold: reached candidates exact, doomed ones below it
    mc = 4
    _, s4 = nlist_intersect(
        a_pre, a_post, y_pre, y_post, y_cnt, a_cnt=a_cnt,
        backend="pallas-interpret", la_block=16, ly_block=16, batch_block=2,
        early_stop=True, min_count=mc,
    )
    es = np.asarray(esup)
    got = np.asarray(s4)
    np.testing.assert_array_equal(got[es >= mc], es[es >= mc])
    assert (got[es < mc] < mc).all()
    # early_stop=False ignores a_cnt/min_count entirely
    mf, sf = nlist_intersect(
        a_pre, a_post, y_pre, y_post, y_cnt, a_cnt=a_cnt,
        backend="pallas-interpret", la_block=16, ly_block=16, batch_block=2,
        early_stop=False, min_count=mc,
    )
    np.testing.assert_array_equal(np.asarray(mf), np.asarray(exact))


# ------------------------------------------------------------- end to end
@pytest.mark.parametrize("min_sup", [1 / 7, 2 / 7, 3 / 7, 0.5, 5 / 7])
def test_paper_db_parity_across_thresholds(paper_db, min_sup):
    """Early-stopped answers are bit-identical to the exact legacy path and
    the oracle on the paper's Table 1 database — including the fractional
    thresholds that sit exactly on a support boundary."""
    rows, n_items = paper_db
    eng = MiningEngine()
    spec = SPEC.with_(min_sup=min_sup)
    on = eng.submit(rows, n_items, spec)
    off = eng.submit(rows, n_items, spec.with_(early_stop=False))
    oracle = mine_bruteforce(rows, n_items, spec.resolve(len(rows)))
    assert on.itemsets == oracle
    assert off.itemsets == oracle
    assert on.total_count == off.total_count == len(oracle)


def test_dense_db_parity_and_pruning_counters():
    rng = np.random.default_rng(21)
    n_items = 12
    rows = random_db(rng, 90, n_items, 9)
    eng = MiningEngine()
    spec = SPEC.with_(min_sup=0.12)
    on = eng.submit(rows, n_items, spec)
    off = eng.submit(rows, n_items, spec.with_(early_stop=False))
    assert on.itemsets == off.itemsets == mine_bruteforce(
        rows, n_items, spec.resolve(len(rows)))
    st_on, st_off = on.stage_times_s, off.stage_times_s
    for key in ("planned_candidates", "host_pruned_parent", "host_pruned_subset"):
        assert key in st_on and key in st_off
    # the Apriori-closure subset prune only runs with early_stop on
    assert st_off["host_pruned_subset"] == 0.0
    # pruning shipped strictly fewer candidates to the device
    assert st_on["planned_candidates"] <= st_off["planned_candidates"]


def test_pallas_interpret_backend_end_to_end(paper_db):
    """The masked Pallas kernel runs the whole mine under backend='pallas'
    (interpreter on CPU) and answers bit-identically to jnp and the
    oracle."""
    rows, n_items = paper_db
    eng = MiningEngine()
    spec = SPEC.with_(min_sup=2 / 7, backend="pallas", la_block=16,
                      ly_block=16, batch_block=2)
    res = eng.submit(rows, n_items, spec)
    oracle = mine_bruteforce(rows, n_items, spec.resolve(len(rows)))
    assert res.itemsets == oracle
    assert eng.submit(
        rows, n_items, spec.with_(backend="jnp")).itemsets == oracle


# ---------------------------------------------------- streamed / distributed
def _pad_heavy_batches(seed=2, n_items=11, width=16):
    """Batches whose rows are mostly PAD (lengths 1-4 in width-16 rows) —
    the masked kernel and the bound masses must shrug off sentinel slots."""
    rng = np.random.default_rng(seed)
    out = []
    for n in (23, 9, 31):
        rows = np.full((n, width), -1, np.int32)
        for r in range(n):
            k = int(rng.integers(1, 5))
            rows[r, :k] = np.sort(rng.choice(n_items, size=k, replace=False))
        out.append(rows)
    return out, n_items


@pytest.mark.parametrize("min_sup", [0.08, 3 / 63])
def test_streamed_segments_parity_pad_heavy(min_sup):
    batches, n_items = _pad_heavy_batches()
    spec = SPEC.with_(min_sup=min_sup)
    results = {}
    for es in (True, False):
        eng = MiningEngine()
        for b in batches:
            eng.append(b, n_items, spec=spec.with_(early_stop=es),
                       stream_spec=StreamSpec(row_pad=8))
        results[es] = eng.submit_stream(spec.with_(early_stop=es))
    all_rows = np.concatenate(batches, axis=0)
    oracle = mine_bruteforce(all_rows, n_items, spec.resolve(len(all_rows)))
    assert results[True].itemsets == oracle
    assert results[False].itemsets == oracle


def test_stream_query_execution_knobs_may_differ():
    """A stream packed with early_stop on serves early_stop-off queries
    (and block/backend changes) — only prep-level knobs are pinned."""
    batches, n_items = _pad_heavy_batches(seed=4)
    eng = MiningEngine()
    for b in batches:
        eng.append(b, n_items, spec=SPEC.with_(min_sup=0.1),
                   stream_spec=StreamSpec(row_pad=8))
    on = eng.submit_stream(SPEC.with_(min_sup=0.1))
    off = eng.submit_stream(
        SPEC.with_(min_sup=0.1, early_stop=False, la_block=64, backend="jnp"))
    assert on.itemsets == off.itemsets
    # prep-level knobs stay pinned
    with pytest.raises(ValueError, match="device config"):
        eng.submit_stream(SPEC.with_(min_sup=0.1, candidate_unit=16))


def test_distributed_parity_early_stop(tmp_path):
    """RemoteSegmentExecutor path: a 2-worker distributed mine with early
    stopping answers bit-identically to the exact path and the
    single-process miner."""
    rng = np.random.default_rng(9)
    n_items = 10
    batches = [random_db(rng, n, n_items, 6) for n in (24, 17, 21)]
    sspec = StreamSpec(row_pad=16)
    spec = SPEC.with_(min_sup=0.25, max_k=4)

    single = MiningEngine()
    for b in batches:
        single.append(b, n_items, spec=spec, stream_spec=sspec)
    want = single.submit_stream(spec)

    eng = MiningEngine(snapshot_dir=str(tmp_path))
    dm = eng.distribute(name="es", n_items=n_items, workers=2, spec=spec,
                        stream_spec=sspec)
    try:
        for b in batches:
            dm.append(b)
        on = dm.mine(spec)
        off = dm.mine(spec.with_(early_stop=False))
    finally:
        dm.close()
    all_rows = np.concatenate(batches, axis=0)
    oracle = mine_bruteforce(all_rows, n_items, spec.resolve(len(all_rows)))
    assert on.itemsets == oracle
    assert off.itemsets == oracle
    assert want.itemsets == oracle
