"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracles."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import encoding as enc
from repro.core.nlist import INF, pack_nlists
from repro.core.ppc import build_ppc
from repro.data.synth import random_db
from repro.kernels.cooccur.kernel import cooccur_pallas
from repro.kernels.cooccur.ref import cooccur_ref
from repro.kernels.histogram.kernel import histogram_pallas
from repro.kernels.histogram.ref import histogram_ref
from repro.kernels.nlist_intersect.kernel import nlist_intersect_pallas
from repro.kernels.nlist_intersect.ref import nlist_intersect_ref


@pytest.mark.parametrize("R,L,n_bins", [(1, 1, 1), (7, 3, 5), (64, 8, 33), (300, 12, 129), (513, 5, 1000)])
@pytest.mark.parametrize("weighted", [False, True])
def test_histogram_sweep(R, L, n_bins, weighted):
    rng = np.random.default_rng(R * 1000 + n_bins)
    rows = rng.integers(-1, n_bins, size=(R, L)).astype(np.int32)
    w = (rng.integers(1, 5, size=R) if weighted else np.ones(R)).astype(np.int32)
    got = histogram_pallas(jnp.asarray(rows), jnp.asarray(w), n_bins=n_bins,
                           row_block=64, bin_block=128, interpret=True)
    want = histogram_ref(jnp.asarray(rows), jnp.asarray(w), n_bins=n_bins)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("R,L,K", [(1, 1, 1), (9, 4, 7), (100, 6, 40), (257, 10, 130)])
def test_cooccur_sweep(R, L, K):
    rng = np.random.default_rng(R + K)
    rows = rng.integers(-1, K, size=(R, L)).astype(np.int32)
    w = rng.integers(1, 4, size=R).astype(np.int32)
    got = cooccur_pallas(jnp.asarray(rows), jnp.asarray(w), n_items=K,
                         row_block=64, k_block=64, interpret=True)
    want = cooccur_ref(jnp.asarray(rows), jnp.asarray(w), n_items=K)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def _nlist_batch(rng, B, La, Ly):
    """Batches of *tree-valid* PP-codes: the kernel's contract assumes codes
    come from a real PPC-tree (antichain per item), so we sample exactly that.
    Truncation to (La, Ly) keeps validity (dropping codes only removes
    potential ancestors for both kernel and oracle alike)."""
    a_pre = np.full((B, La), INF, np.int32)
    a_post = np.full((B, La), -1, np.int32)
    y_pre = np.full((B, Ly), INF, np.int32)
    y_post = np.full((B, Ly), -1, np.int32)
    y_cnt = np.zeros((B, Ly), np.int32)
    for b in range(B):
        n_items = int(rng.integers(2, 16))
        rows = random_db(rng, int(rng.integers(5, 120)), n_items, min(8, n_items))
        fl = enc.build_flist(enc.item_support(rows, n_items), 1)
        if fl.k < 2:
            continue
        urows, w = enc.dedup_rows(enc.rank_encode(rows, fl))
        if not len(urows):
            continue
        nls = build_ppc(urows, w).nlists(fl.k)
        qa, qy = sorted(rng.choice(fl.k, size=2, replace=False))
        A, Y = nls[qa][:La], nls[qy][:Ly]
        a_pre[b, : len(A)], a_post[b, : len(A)] = A[:, 0], A[:, 1]
        y_pre[b, : len(Y)], y_post[b, : len(Y)] = Y[:, 0], Y[:, 1]
        y_cnt[b, : len(Y)] = Y[:, 2]
    return map(jnp.asarray, (a_pre, a_post, y_pre, y_post, y_cnt))


@pytest.mark.parametrize("bb", [1, 3, 8])
@pytest.mark.parametrize("B,La,Ly", [(1, 1, 1), (3, 8, 5), (5, 40, 70), (2, 130, 257)])
def test_nlist_intersect_sweep(B, La, Ly, bb):
    """Fused-kernel parity: merged counts match the oracle and the fused
    support output equals ``merged.sum(axis=1)`` — across La/Ly that are not
    block multiples and B that is not a batch_block multiple."""
    rng = np.random.default_rng(B * La + Ly)
    a_pre, a_post, y_pre, y_post, y_cnt = _nlist_batch(rng, B, La, Ly)
    got, sup = nlist_intersect_pallas(a_pre, a_post, y_pre, y_post, y_cnt,
                                      la_block=64, ly_block=64,
                                      batch_block=bb, interpret=True)
    want = nlist_intersect_ref(a_pre, a_post, y_pre, y_post, y_cnt)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(sup), np.asarray(want).sum(axis=1))


def test_nlist_intersect_zero_count_and_pad_slots():
    """Zero-count Y slots contribute nothing; all-PAD rows (the
    pre=INT32_MAX / post=-1 / cnt=0 sentinel convention) yield zero merged
    counts and zero support, including across batch padding."""
    rng = np.random.default_rng(7)
    B, La, Ly = 5, 24, 16
    a_pre, a_post, y_pre, y_post, y_cnt = map(
        np.asarray, _nlist_batch(rng, B, La, Ly))
    y_cnt = y_cnt.copy()
    y_cnt[1] = 0  # candidate 1: every Y slot zero-count
    a_pre, a_post = a_pre.copy(), a_post.copy()
    a_pre[2, :], a_post[2, :] = INF, -1  # candidate 2: all-PAD A list
    y_pre, y_post = y_pre.copy(), y_post.copy()
    y_pre[3, :], y_post[3, :], y_cnt[3, :] = INF, -1, 0  # candidate 3: all-PAD Y
    args = [jnp.asarray(x) for x in (a_pre, a_post, y_pre, y_post, y_cnt)]
    got, sup = nlist_intersect_pallas(*args, la_block=8, ly_block=8,
                                      batch_block=2, interpret=True)
    want = nlist_intersect_ref(*args)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(sup), np.asarray(want).sum(axis=1))
    got, sup = np.asarray(got), np.asarray(sup)
    for b in (1, 2, 3):
        assert not got[b].any() and sup[b] == 0


def test_nlist_intersect_real_tree(paper_db):
    """Kernel vs oracle on the actual paper-example N-lists."""
    rows, n_items = paper_db
    fl = enc.build_flist(enc.item_support(rows, n_items), 3)
    urows, w = enc.dedup_rows(enc.rank_encode(rows, fl))
    tree = build_ppc(urows, w)
    packed = pack_nlists(tree.nlists(fl.k), width=8)  # (K, 8, 3)
    K = fl.k
    # intersect every (a=q, y=p) pair, q < p
    pairs = [(q, p) for p in range(K) for q in range(p)]
    a = packed[[q for q, _ in pairs]]
    y = packed[[p for _, p in pairs]]
    args = [jnp.asarray(x) for x in (a[:, :, 0], a[:, :, 1], y[:, :, 0], y[:, :, 1], y[:, :, 2])]
    got, sup = nlist_intersect_pallas(*args, la_block=8, ly_block=8,
                                      batch_block=4, interpret=True)
    want = nlist_intersect_ref(*args)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(sup), np.asarray(want).sum(axis=1))
    # support(b,c) == 3 per the paper's data (rows containing both b and c)
    idx = pairs.index((0, 2))
    assert int(np.asarray(sup)[idx]) == 3


@pytest.mark.parametrize("dtype", [jnp.int32])
def test_histogram_dtype_and_shape_edge(dtype):
    # single row, single item, n_bins == 1 — degenerate tiling path
    rows = jnp.zeros((1, 1), dtype)
    got = histogram_pallas(rows, jnp.ones(1, jnp.int32), n_bins=1, interpret=True)
    assert int(got[0]) == 1
