"""Property tests for the telemetry histogram's load-bearing contracts.

``LatencyHistogram`` feeds the operator stats surface and the periodic
emitter, so its three promises are pinned down over random inputs:

  - exact counts: every ``record`` lands in exactly one bucket, so the
    bucket counts always sum to ``n`` and min/max/total are exact;
  - ``merge`` is associative and commutative bucket-for-bucket — the
    property that makes per-worker / per-thread histograms aggregable
    in any order without resampling;
  - a quantile estimate is bounded by the edges of the bucket containing
    the true quantile (k-th smallest, k = ceil(q*n)), and by the observed
    min/max — the estimate can be coarse, but never escapes the interval
    the true value is known to lie in.
"""
import math

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.mining.telemetry import LatencyHistogram

# latencies from sub-bucket-zero up to beyond the last edge (overflow),
# negatives included to cover the clamp-to-zero path
values = st.lists(
    st.floats(min_value=-1.0, max_value=1e4,
              allow_nan=False, allow_infinity=False),
    min_size=1, max_size=60,
)


def _fill(vals):
    h = LatencyHistogram()
    for v in vals:
        h.record(v)
    return h


@given(values)
@settings(max_examples=200, deadline=None)
def test_counts_are_exact(vals):
    h = _fill(vals)
    clamped = [max(0.0, v) for v in vals]
    assert h.n == len(vals)
    assert sum(h.counts) == len(vals)
    assert h.vmin == min(clamped) and h.vmax == max(clamped)
    assert h.total == pytest.approx(sum(clamped))


@given(values, values)
@settings(max_examples=200, deadline=None)
def test_merge_commutative(a, b):
    ab = _fill(a).merge(_fill(b))
    ba = _fill(b).merge(_fill(a))
    assert ab.counts == ba.counts and ab.n == ba.n
    assert ab.vmin == ba.vmin and ab.vmax == ba.vmax
    assert ab.total == pytest.approx(ba.total)


@given(values, values, values)
@settings(max_examples=100, deadline=None)
def test_merge_associative_and_lossless(a, b, c):
    left = _fill(a).merge(_fill(b)).merge(_fill(c))
    right = _fill(a).merge(_fill(b).merge(_fill(c)))
    whole = _fill(a + b + c)
    for m in (left, right):
        assert m.counts == whole.counts and m.n == whole.n
        assert m.vmin == whole.vmin and m.vmax == whole.vmax
        assert m.total == pytest.approx(whole.total)


@given(values, st.floats(min_value=0.0, max_value=1.0))
@settings(max_examples=200, deadline=None)
def test_quantile_bounded_by_bucket_edges(vals, q):
    h = _fill(vals)
    clamped = sorted(max(0.0, v) for v in vals)
    k = min(len(clamped), max(1, math.ceil(q * len(clamped))))
    true = clamped[k - 1]
    lo, hi = h.quantile_bounds(q)
    est = h.quantile(q)
    assert lo <= true <= hi
    assert lo <= est <= hi
    assert h.vmin <= est <= h.vmax
