"""PPC-tree construction: paper example + sort-based vs pointer oracle."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import encoding as enc
from repro.core.ppc import _build_ppc_pointer, build_ppc, build_ppc_jnp
from repro.data.synth import random_db


def _ranked(rows, n_items, min_count):
    fl = enc.build_flist(enc.item_support(rows, n_items), min_count)
    return enc.dedup_rows(enc.rank_encode(rows, fl)), fl


def test_paper_example(paper_db):
    """Fig. 1 / Fig. 2 of the paper (rootless codes: paper pre = ours + 1)."""
    rows, n_items = paper_db
    (urows, w), fl = _ranked(rows, n_items, 3)
    assert list(fl.items) == [1, 0, 2, 3, 4]  # F-list: b a c d e
    assert list(fl.supports) == [5, 4, 3, 3, 3]
    tree = build_ppc(urows, w)
    nls = tree.nlists(fl.k)
    # paper N-list of b: (1,5):5  -> rootless (0,5):5
    assert nls[0].tolist() == [[0, 5, 5]]
    # paper N-list of d: {5,2}:1, {8,7}:2 -> (4,2):1, (7,7):2
    assert nls[3].tolist() == [[4, 2, 1], [7, 7, 2]]
    # paper N-list of e: (3,0):1 (6,3):1 (9,6):1 -> shifted by 1
    assert nls[4].tolist() == [[2, 0, 1], [5, 3, 1], [8, 6, 1]]


@settings(max_examples=60, deadline=None)
@given(
    n_tx=st.integers(1, 60),
    n_items=st.integers(1, 20),
    max_len=st.integers(1, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_sort_based_equals_pointer(n_tx, n_items, max_len, seed):
    rng = np.random.default_rng(seed)
    rows = random_db(rng, n_tx, n_items, min(max_len, n_items))
    (urows, w), _ = _ranked(rows, n_items, 1)
    if len(urows) == 0:
        return
    a = build_ppc(urows, w)
    b = _build_ppc_pointer(urows, w)
    assert a.n_nodes == b.n_nodes
    for f in ("item", "count", "pre", "post", "depth"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f), err_msg=f)


@settings(max_examples=25, deadline=None)
@given(
    n_tx=st.integers(1, 40),
    n_items=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_jnp_build_matches_numpy(n_tx, n_items, seed):
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    rows = random_db(rng, n_tx, n_items, min(6, n_items))
    (urows, w), _ = _ranked(rows, n_items, 1)
    if len(urows) == 0:
        return
    ref = build_ppc(urows, w)
    max_nodes = urows.size
    item, count, pre, post, valid = build_ppc_jnp(
        jnp.asarray(urows), jnp.asarray(w), max_nodes
    )
    n = int(valid.sum())
    assert n == ref.n_nodes
    np.testing.assert_array_equal(np.asarray(item)[:n], ref.item)
    np.testing.assert_array_equal(np.asarray(count)[:n], ref.count)
    np.testing.assert_array_equal(np.asarray(pre)[:n], ref.pre)
    np.testing.assert_array_equal(np.asarray(post)[:n], ref.post)


def test_subtree_interval_invariants(rng):
    """Pre/post codes must encode ancestry: disjoint-or-nested intervals."""
    rows = random_db(rng, 80, 15, 8)
    (urows, w), _ = _ranked(rows, 15, 1)
    t = build_ppc(urows, w)
    # root-level counts sum to number of (nonempty) weighted rows
    top = t.depth == 0
    assert t.count[top].sum() == w[(urows != enc.PAD).any(axis=1)].sum()
    # ancestry iff (pre <, post >): check transitivity-free pairwise coherence
    pre, post = t.pre, t.post
    anc = (pre[:, None] < pre[None, :]) & (post[:, None] > post[None, :])
    # a node never "crosses" another: either nested or disjoint
    crossing = (pre[:, None] < pre[None, :]) & (post[:, None] < post[None, :]) & (
        pre[None, :] < post[:, None] + 1
    )
    # crossing in interval terms is impossible for a tree encoding
    for i, j in zip(*np.nonzero(anc)):
        assert t.depth[i] < t.depth[j]
