"""Closed / maximal / top-rank-k pattern families vs first principles."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.oracle import mine_bruteforce
from repro.core.patterns import closed_itemsets, maximal_itemsets, top_rank_k
from repro.core.prepost import mine_prepost
from repro.data.synth import random_db


def _brute_closed(itemsets):
    return {
        s: v
        for s, v in itemsets.items()
        if not any(set(s) < set(t) and itemsets[t] == v for t in itemsets)
    }


def _brute_maximal(itemsets):
    return {
        s: v for s, v in itemsets.items() if not any(set(s) < set(t) for t in itemsets)
    }


@settings(max_examples=25, deadline=None)
@given(
    n_tx=st.integers(1, 40),
    n_items=st.integers(1, 9),
    min_count=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_closed_and_maximal_match_definitions(n_tx, n_items, min_count, seed):
    rng = np.random.default_rng(seed)
    rows = random_db(rng, n_tx, n_items, min(6, n_items))
    mined = mine_prepost(rows, n_items, min_count).itemsets
    assert closed_itemsets(mined) == _brute_closed(mined)
    assert maximal_itemsets(mined) == _brute_maximal(mined)
    # maximal ⊆ closed ⊆ all
    assert set(maximal_itemsets(mined)) <= set(closed_itemsets(mined)) <= set(mined)


def test_closed_on_paper_example(paper_db):
    rows, n_items = paper_db
    mined = mine_prepost(rows, n_items, 3).itemsets
    closed = closed_itemsets(mined)
    # {c} (sup 3) is NOT closed: superset {b,c} has the same support
    assert (2,) not in closed and (1, 2) in closed
    # {b} (sup 5) is closed (no superset at 5)
    assert (1,) in closed


def test_top_rank_k():
    mined = {(1,): 5, (2,): 5, (3,): 4, (1, 2): 3, (4,): 2}
    assert top_rank_k(mined, 1) == {(1,): 5, (2,): 5}
    assert top_rank_k(mined, 2) == {(1,): 5, (2,): 5, (3,): 4}
    assert len(top_rank_k(mined, 10)) == 5
