"""The resident mining service: async group scheduler + MiningService.

Anchors: scheduler results are itemset-identical to independent submits
(whatever the overlap did), overlap attribution is honest (group g+1's
prepare marked overlapped only when it ran while group g mined), host
algorithms ride worker threads in the same batch, and the service facade
batches concurrent submits, isolates per-request failures, and drains
cleanly.
"""
import threading
import time

import numpy as np
import pytest

from repro.data.synth import random_db
from repro.mining import MineRequest, MineSpec, MiningEngine
from repro.mining.service import GroupScheduler, MiningService

SPEC = MineSpec(algorithm="hprepost", max_k=4, candidate_unit=8, min_sup=0.3,
                nlist_width=16)


def _db(seed=0, n_tx=60, n_items=10):
    return random_db(np.random.default_rng(seed), n_tx, n_items, 6), n_items


# ------------------------------------------------------------- scheduler
def test_scheduler_matches_independent_submits_across_groups():
    rows_a, n_items = _db(0)
    rows_b, _ = _db(1)
    reqs = [
        MineRequest(rows_a, n_items, SPEC.with_(min_sup=0.4)),
        MineRequest(rows_a, n_items, SPEC.with_(min_sup=0.25)),
        MineRequest(rows_b, n_items, SPEC.with_(min_sup=0.3)),
        MineRequest(rows_a, n_items, MineSpec(algorithm="fpgrowth", min_sup=0.3, max_k=4)),
        MineRequest(rows_b, n_items, MineSpec(algorithm="apriori", min_sup=0.3, max_k=4)),
    ]
    eng = MiningEngine()
    with GroupScheduler(eng) as sched:
        out = sched.run(reqs)
    assert sched.stats["device_groups"] == 2 and sched.stats["host_requests"] == 2
    fresh = MiningEngine()
    for r, res in zip(reqs, out):
        assert res.algorithm == r.spec.algorithm
        assert res.itemsets == fresh.submit(r.rows, r.n_items, r.spec).itemsets
    # both sweeps were planned: one prepare per distinct database
    assert eng.stats["prepares"] == 2


def test_scheduler_overlap_attribution_and_counters():
    rows_a, n_items = _db(2)
    rows_b, _ = _db(3)
    eng = MiningEngine()
    with GroupScheduler(eng) as sched:
        out = sched.run([
            MineRequest(rows_a, n_items, SPEC),
            MineRequest(rows_b, n_items, SPEC),
        ])
    # group 0's prepare had nothing to hide under; group 1's ran while
    # group 0 was mining
    assert out[0].service_stats["prep_overlapped"] is False
    assert out[1].service_stats["prep_overlapped"] is True
    assert sched.stats["overlapped_prepares"] == 1
    # cache hits are never "overlapped prepares": rerun the same batch
    with GroupScheduler(eng) as sched2:
        out2 = sched2.run([
            MineRequest(rows_a, n_items, SPEC),
            MineRequest(rows_b, n_items, SPEC),
        ])
    assert sched2.stats["overlapped_prepares"] == 0
    assert all(r.service_stats["prep_source"] == "cache" for r in out2)


def test_scheduler_sequential_mode_matches_overlapped():
    rows_a, n_items = _db(4)
    rows_b, _ = _db(5)
    reqs = [
        MineRequest(rows_a, n_items, SPEC.with_(min_sup=0.25)),
        MineRequest(rows_b, n_items, SPEC.with_(min_sup=0.25)),
    ]
    with GroupScheduler(MiningEngine(), overlap=False) as seq:
        a = seq.run(list(reqs))
    with GroupScheduler(MiningEngine()) as ovl:
        b = ovl.run(list(reqs))
    assert seq.stats["overlapped_prepares"] == 0
    for x, y in zip(a, b):
        assert x.itemsets == y.itemsets


def test_scheduler_group_guard_degrades_per_request():
    from repro.core.encoding import pad_transactions

    # loose floor trips max_f1 (K=10 > 6); the tight request alone passes
    tx = [[0, 1, 2, 3, 4, 5]] * 8 + [[6, 7, 8, 9]] * 2
    rows = pad_transactions(tx)
    spec = SPEC.with_(max_f1=6, nlist_width=None)
    eng = MiningEngine()
    with GroupScheduler(eng) as sched:
        out = sched.run(
            [MineRequest(rows, 10, spec.with_(min_sup=0.5)),
             MineRequest(rows, 10, spec.with_(min_sup=0.2))],
            return_exceptions=True,
        )
    assert sched.stats["degraded_groups"] == 1
    assert out[0].itemsets  # the feasible request still answered
    assert isinstance(out[1], ValueError)  # the infeasible one failed alone


def test_scheduler_error_isolation_as_values_or_raise():
    rows, n_items = _db(6)
    bad = MineRequest(rows, n_items,
                      MineSpec(algorithm="prepost+", min_sup=0.3, patterns="closed"))
    good = MineRequest(rows, n_items, SPEC)
    with GroupScheduler(MiningEngine()) as sched:
        out = sched.run([bad, good], return_exceptions=True)
        assert isinstance(out[0], ValueError)  # CPE subset can't do closed
        assert out[1].itemsets
        with pytest.raises(ValueError):
            sched.run([bad, good])


# --------------------------------------------------------------- service
def test_service_coalesces_concurrent_submits_into_one_planned_batch():
    rows, n_items = _db(7)
    with MiningService(batch_window_s=0.25) as svc:
        futs = svc.sweep(rows, n_items, SPEC, [0.4, 0.3, 0.2])
        svc.drain()
        out = [f.result() for f in futs]
        assert svc.stats["batches"] == 1 and svc.stats["max_batch"] == 3
        assert svc.engine.stats["prepares"] == 1  # one group, prep once
    fresh = MiningEngine()
    for frac, res in zip([0.4, 0.3, 0.2], out):
        assert res.itemsets == fresh.submit(rows, n_items, SPEC.with_(min_sup=frac)).itemsets
        assert res.service_stats["batch_size"] == 3
        assert res.service_stats["queue_time_s"] >= 0.0


def test_service_telemetry_and_mixed_algorithms():
    rows, n_items = _db(8)
    with MiningService(batch_window_s=0.2) as svc:
        f1 = svc.submit(rows, n_items, SPEC)
        f2 = svc.submit(rows, n_items, MineSpec(algorithm="apriori", min_sup=0.3, max_k=4))
        r1, r2 = f1.result(timeout=120), f2.result(timeout=120)
    assert r1.itemsets == r2.itemsets  # same db, same threshold, same answer
    assert r1.service_stats["prep_source"] == "built"
    assert "prep_overlapped" in r1.service_stats
    assert r2.service_stats["batch_size"] == r1.service_stats["batch_size"]


def test_service_per_request_failure_does_not_poison_the_batch():
    rows, n_items = _db(9)
    with MiningService(batch_window_s=0.2) as svc:
        bad = svc.submit(rows, n_items,
                         MineSpec(algorithm="prepost+", min_sup=0.3, patterns="maximal"))
        good = svc.submit(rows, n_items, SPEC)
        with pytest.raises(ValueError):
            bad.result(timeout=120)
        assert good.result(timeout=120).itemsets


def test_service_warm_starts_from_snapshot_dir(tmp_path):
    rows, n_items = _db(10)
    with MiningService(snapshot_dir=str(tmp_path), batch_window_s=0.05) as svc:
        ref = [f.result(timeout=120) for f in svc.sweep(rows, n_items, SPEC, [0.4, 0.3])]
    with MiningService(snapshot_dir=str(tmp_path), batch_window_s=0.05) as svc2:
        out = [f.result(timeout=120) for f in svc2.sweep(rows, n_items, SPEC, [0.4, 0.3])]
        assert svc2.engine.stats["prepares"] == 0
        assert svc2.engine.cache_info()["snapshot_hits"] == 1
    for a, b in zip(ref, out):
        assert a.itemsets == b.itemsets
        assert b.service_stats["prep_source"] == "snapshot"


def test_service_drain_close_and_submit_after_close():
    rows, n_items = _db(11)
    svc = MiningService(batch_window_s=0.01)
    futs = [svc.submit(rows, n_items, SPEC.with_(min_sup=s)) for s in (0.4, 0.3)]
    svc.drain()
    assert all(f.done() for f in futs)
    svc.close()
    svc.close()  # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(rows, n_items, SPEC)


def test_service_cancelled_future_neither_kills_worker_nor_blocks_drain():
    rows, n_items = _db(14)
    with MiningService(batch_window_s=0.3) as svc:
        doomed = svc.submit(rows, n_items, SPEC)
        live = svc.submit(rows, n_items, SPEC.with_(min_sup=0.25))
        assert doomed.cancel()  # still queued: cancellable
        svc.drain()  # must account the cancelled slot, not hang on it
        assert doomed.cancelled()
        res = live.result(timeout=120)
        assert res.itemsets
        assert res.service_stats["batch_size"] == 1  # cancelled slot dropped
        # the worker survived: the service still serves
        assert svc.submit(rows, n_items, SPEC).result(timeout=120).itemsets


def test_service_threaded_producers_all_resolve():
    rows_a, n_items = _db(12)
    rows_b, _ = _db(13)
    futs, lock = [], threading.Lock()

    def producer(rows, fracs, svc):
        for s in fracs:
            f = svc.submit(rows, n_items, SPEC.with_(min_sup=s))
            with lock:
                futs.append((rows, s, f))
            time.sleep(0.002)

    with MiningService(batch_window_s=0.05) as svc:
        threads = [
            threading.Thread(target=producer, args=(rows_a, (0.4, 0.3, 0.25), svc)),
            threading.Thread(target=producer, args=(rows_b, (0.35, 0.3, 0.25), svc)),
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        svc.drain()
        assert svc.stats["requests"] == 6
        fresh = MiningEngine()
        for rows, s, f in futs:
            assert f.result(timeout=120).itemsets == fresh.submit(
                rows, n_items, SPEC.with_(min_sup=s)
            ).itemsets


# ------------------------------------------------------ close() (PR 8 fix)
def test_close_drains_queued_requests_to_results():
    """Requests still queued when close() is called must resolve with
    their results — the pre-hardening close joined the worker without
    draining, orphaning whatever sat in the queue."""
    rows, n_items = _db(14)
    svc = MiningService(batch_window_s=0.2)
    futs = [svc.submit(rows, n_items, SPEC.with_(min_sup=s))
            for s in (0.4, 0.3, 0.25)]
    svc.close()  # default drain=True
    fresh = MiningEngine()
    for s, f in zip((0.4, 0.3, 0.25), futs):
        assert f.result(timeout=120).itemsets == fresh.submit(
            rows, n_items, SPEC.with_(min_sup=s)
        ).itemsets


def test_close_without_drain_fails_queued_fast():
    from repro.mining.service import ServiceClosed

    rows, n_items = _db(15)
    svc = MiningService(batch_window_s=0.0)
    # gate the scheduler so the first batch provably sits mid-execution
    # while more requests pile up behind it in the queue
    gate = threading.Event()
    orig_run = svc.scheduler.run

    def gated_run(reqs, **kw):
        gate.wait(60)
        return orig_run(reqs, **kw)

    svc.scheduler.run = gated_run
    first = svc.submit(rows, n_items, SPEC)
    deadline = time.monotonic() + 10
    while svc._q.depth and time.monotonic() < deadline:
        time.sleep(0.01)  # worker popped `first`, now blocked at the gate
    queued = [svc.submit(rows, n_items, SPEC) for _ in range(3)]
    closer = threading.Thread(target=lambda: svc.close(drain=False))
    closer.start()
    # the queued requests fail fast with the typed error — while the
    # in-flight batch is still executing, not 30s later
    for f in queued:
        with pytest.raises(ServiceClosed):
            f.result(timeout=10)
    gate.set()  # release the batch; close() can now join the worker
    closer.join(120)
    assert not closer.is_alive()
    assert first.result(timeout=120).itemsets  # the running batch finished
