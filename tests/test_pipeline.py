"""GPipe pipeline parallelism: schedule correctness on fake devices."""
import os
import subprocess
import sys
import textwrap

_SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.compat import make_mesh
    from repro.training.pipeline import gpipe_forward

    mesh = make_mesh((4,), ("pipe",))
    L, D = 8, 16          # 8 layers over 4 stages
    n_micro, mb = 6, 4
    rng = np.random.default_rng(0)
    ws = jnp.asarray(rng.normal(size=(L, D, D)) / np.sqrt(D), jnp.float32)
    bs = jnp.asarray(rng.normal(size=(L, D)) * 0.1, jnp.float32)
    x = jnp.asarray(rng.normal(size=(n_micro, mb, D)), jnp.float32)

    def layer(lp, h):
        w, b = lp
        return jnp.tanh(h @ w + b)

    got = jax.jit(lambda p, x: gpipe_forward(layer, p, x, mesh=mesh))((ws, bs), x)

    # sequential reference
    def seq(x):
        h = x
        for i in range(L):
            h = layer((ws[i], bs[i]), h)
        return h
    want = jax.vmap(seq)(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
    print("PIPELINE_OK")
    """
)


def test_gpipe_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC], env=env, capture_output=True, text=True, timeout=560
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PIPELINE_OK" in out.stdout
