"""Distributed HPrepost vs single-shard PrePost.

In-process tests use a 1-device mesh; true multi-device behaviour (psum
across DB blocks, candidate partitioning over `model`, the shuffle) runs in
a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8 since
device count is locked at first JAX init.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.encoding import pad_transactions
from repro.core.hprepost import HPrepostConfig, HPrepostMiner
from repro.core.prepost import mine_prepost
from repro.data.synth import random_db


@pytest.fixture(scope="module")
def mesh11():
    import jax
    from repro.compat import make_mesh

    return make_mesh((1, 1), ("data", "model"))


def test_paper_example_distributed(mesh11, paper_db):
    rows, n_items = paper_db
    miner = HPrepostMiner(mesh11, config=HPrepostConfig(candidate_unit=4))
    res = miner.mine(rows, n_items, 3)
    ref = mine_prepost(rows, n_items, 3)
    assert res.itemsets == ref.itemsets


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("min_count", [1, 3])
def test_random_matches_single_shard(mesh11, seed, min_count):
    rng = np.random.default_rng(seed)
    rows = random_db(rng, 80, 12, 7)
    miner = HPrepostMiner(mesh11, config=HPrepostConfig(candidate_unit=8))
    res = miner.mine(rows, 12, min_count)
    ref = mine_prepost(rows, 12, min_count)
    assert res.itemsets == ref.itemsets


def test_mode_a_no_model_axis(mesh11, paper_db):
    rows, n_items = paper_db
    miner = HPrepostMiner(
        mesh11, model_axis=None, config=HPrepostConfig(candidate_unit=4, partition_candidates=False)
    )
    res = miner.mine(rows, n_items, 2)
    ref = mine_prepost(rows, n_items, 2)
    assert res.itemsets == ref.itemsets


_SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    from repro.compat import make_mesh
    from repro.core.hprepost import HPrepostMiner, HPrepostConfig
    from repro.core.prepost import mine_prepost
    from repro.data.synth import random_db

    mesh = make_mesh((4, 2), ("data", "model"))
    for seed in range(4):
        rng = np.random.default_rng(seed)
        rows = random_db(rng, 100, 12, 6)
        for mode_b in (True, False):
            miner = HPrepostMiner(
                mesh,
                config=HPrepostConfig(candidate_unit=8, partition_candidates=mode_b),
            )
            res = miner.mine(rows, 12, 2)
            ref = mine_prepost(rows, 12, 2)
            assert res.itemsets == ref.itemsets, (seed, mode_b)

    # multi-pod style: data over two axes
    mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
    rng = np.random.default_rng(7)
    rows = random_db(rng, 64, 10, 5)
    miner = HPrepostMiner(mesh3, data_axis=("pod", "data"), config=HPrepostConfig(candidate_unit=8))
    res = miner.mine(rows, 10, 2)
    ref = mine_prepost(rows, 10, 2)
    assert res.itemsets == ref.itemsets
    print("MULTIDEV_OK")
    """
)


def test_multidevice_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC], env=env, capture_output=True, text=True, timeout=600
    )
    assert out.returncode == 0, out.stderr[-4000:]
    assert "MULTIDEV_OK" in out.stdout
