"""Dry-run integration: lower+compile cells on a small fake-device mesh.

The production 512-device sweep runs via ``python -m repro.launch.dryrun``;
here we verify the same machinery end-to-end on 8 fake devices in a
subprocess (device count locks at first JAX init, so in-process is out).
"""
import json
import os
import subprocess
import sys

import pytest


def _run(args, tmp):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env["REPRO_XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--out", str(tmp), "--force"] + args,
        env=env, capture_output=True, text=True, timeout=560,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out


@pytest.mark.parametrize(
    "arch,shape",
    [
        ("tinyllama_1_1b", "train_4k"),
        ("granite_moe", "train_4k"),
        ("xlstm_125m", "decode_32k"),
        ("zamba2_2_7b", "long_500k"),
        ("seamless_m4t_v2", "prefill_32k"),
        ("internvl2_26b", "train_4k"),
    ],
)
def test_cell_compiles_small_mesh(tmp_path, arch, shape):
    # reduced seq/batch keep the 8-device CPU compile fast; mesh 2x2x2
    # exercises the multi-pod (pod, data, model) axis handling
    _run(["--mesh", "2x2x2", "--arch", arch, "--shape", shape,
          "--seq", "512", "--batch", "8"], tmp_path)
    recs = [json.load(open(tmp_path / f)) for f in os.listdir(tmp_path)]
    assert len(recs) == 1
    r = recs[0]
    assert "error" not in r, r
    assert r["flops_per_device"] > 0
    assert r["hbm_bytes_per_device"] > 0
    assert r["bottleneck"] in ("compute", "memory", "collective")


def test_skip_policy(tmp_path):
    _run(["--mesh", "2x2", "--arch", "qwen1_5_0_5b", "--shape", "long_500k",
          "--seq", "1024", "--batch", "1"], tmp_path)
    recs = [json.load(open(tmp_path / f)) for f in os.listdir(tmp_path)]
    assert recs[0].get("skipped"), recs[0]


def test_production_results_exist_and_clean():
    """The committed 512-device sweep must be complete: 64 compiled cells +
    16 documented skips, zero errors."""
    res = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
    if not os.path.isdir(res) or len(os.listdir(res)) < 80:
        pytest.skip("production sweep not present (run repro.launch.dryrun --all --both-meshes)")
    recs = [json.load(open(os.path.join(res, f))) for f in os.listdir(res)]
    errors = [r for r in recs if "error" in r]
    assert not errors, errors[:3]
    done = [r for r in recs if "skipped" not in r]
    model_cells = [r for r in done if not r["arch"].startswith("hprepost_")]
    fim_cells = [r for r in done if r["arch"].startswith("hprepost_")]
    assert len(model_cells) == 64
    assert len(fim_cells) >= 8  # job1/job2/f2/waves on both meshes
    assert {r["mesh"] for r in done} == {"pod16x16", "2pod16x16"}
