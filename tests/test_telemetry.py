"""Telemetry (repro.mining.telemetry): histograms, traces, the periodic
emitter, and the wiring through the serving stack.

Anchors, per the PR acceptance criteria:
  - ``LatencyHistogram`` keeps exact counts under concurrency, merges
    bucket-for-bucket, and its quantile estimates stay inside the bucket
    that contains the true quantile (deterministic versions here; the
    hypothesis sweeps live in test_telemetry_properties.py);
  - ``TraceRecorder`` nests spans implicitly per thread and explicitly
    across threads, exports valid Chrome trace events, and costs one
    global read when detached;
  - ``StatsEmitter`` keeps ticking through chaos drops and sink errors —
    a lost emit is a counted line, never an exception;
  - after a multi-request serve, ``service.stats()['histograms']``
    reports populated queue-wait / prep / mine / request histograms, and
    a distributed mine records per-worker wave RPC histograms.
"""
import io
import json
import math
import threading
import time

import numpy as np
import pytest

from repro.data.synth import random_db
from repro.fault.failures import ChaosInjector, installed
from repro.mining import MineSpec, MiningEngine
from repro.mining.telemetry import (
    DEFAULT_EDGES, SCHEMA_VERSION, LatencyHistogram, Registry, StatsEmitter,
    TraceRecorder, trace,
)


def _true_quantile(vals, q):
    k = min(len(vals), max(1, math.ceil(q * len(vals))))
    return sorted(vals)[k - 1]


# ------------------------------------------------------------- histogram
def test_record_exact_counts_and_bucket_placement():
    h = LatencyHistogram()
    h.record(0.0)        # bucket 0 (v <= first edge)
    h.record(1e-6)       # still bucket 0 (edges are upper bounds)
    h.record(1.5e-6)     # bucket 1
    h.record(10.0)       # mid-range
    h.record(1e9)        # above the last edge -> overflow bucket
    assert h.n == 5 and sum(h.counts) == 5
    assert h.counts[0] == 2 and h.counts[1] == 1
    assert h.counts[-1] == 1  # overflow
    assert h.vmin == 0.0 and h.vmax == 1e9
    assert h.total == pytest.approx(10.0 + 1e9 + 2.5e-6)


def test_negative_and_nan_clamp_to_zero():
    h = LatencyHistogram()
    h.record(-3.0)
    h.record(float("nan"))
    assert h.n == 2 and h.counts[0] == 2
    assert h.vmin == 0.0 and h.vmax == 0.0 and h.total == 0.0


def test_empty_histogram_is_well_defined():
    h = LatencyHistogram()
    assert h.quantile(0.5) == 0.0
    assert h.quantile_bounds(0.99) == (0.0, 0.0)
    snap = h.snapshot()
    assert snap["count"] == 0 and snap["buckets"] == {}
    assert snap["min_s"] == 0.0 and snap["max_s"] == 0.0


def test_quantile_estimate_bounded_by_bucket_and_extremes():
    vals = [3e-6, 5e-6, 5e-6, 2e-4, 1e-3, 1e-3, 4e-2, 0.3, 0.3, 7.0]
    h = LatencyHistogram()
    for v in vals:
        h.record(v)
    for q in (0.01, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0):
        lo, hi = h.quantile_bounds(q)
        true = _true_quantile(vals, q)
        est = h.quantile(q)
        assert lo <= true <= hi
        assert lo <= est <= hi
        assert h.vmin <= est <= h.vmax
    # monotone in q (bucket index can only move right)
    qs = [h.quantile(q) for q in (0.1, 0.5, 0.9, 0.99)]
    assert qs == sorted(qs)


def test_merge_is_exact_and_order_free():
    rng = np.random.default_rng(7)
    parts = [rng.uniform(0, 2.0, 40) for _ in range(3)]
    hs = []
    for p in parts:
        h = LatencyHistogram()
        for v in p:
            h.record(float(v))
        hs.append(h)
    whole = LatencyHistogram()
    for v in np.concatenate(parts):
        whole.record(float(v))
    ab_c = hs[0].copy().merge(hs[1]).merge(hs[2])
    a_bc = hs[0].copy().merge(hs[1].copy().merge(hs[2]))
    ba = hs[1].copy().merge(hs[0])
    for m in (ab_c, a_bc):
        assert m.counts == whole.counts and m.n == whole.n
        assert m.vmin == whole.vmin and m.vmax == whole.vmax
        assert m.total == pytest.approx(whole.total)
    assert ba.counts == hs[0].copy().merge(hs[1]).counts


def test_merge_rejects_mismatched_edges():
    with pytest.raises(ValueError):
        LatencyHistogram().merge(LatencyHistogram(edges=(1.0, 2.0)))
    with pytest.raises(ValueError):
        LatencyHistogram(edges=(2.0, 1.0))  # must be strictly increasing


def test_concurrent_records_and_merges_lose_nothing():
    target = LatencyHistogram()
    n_threads, per_thread = 8, 4000

    def hammer(tid):
        local = LatencyHistogram()
        for i in range(per_thread):
            v = (tid * per_thread + i) % 997 * 1e-5
            if i % 2:
                target.record(v)  # direct contended records
            else:
                local.record(v)  # plus a merged batch
        target.merge(local)

    threads = [threading.Thread(target=hammer, args=(t,)) for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert target.n == n_threads * per_thread
    assert sum(target.counts) == target.n
    assert target.vmin == 0.0 and target.vmax == 996 * 1e-5


def test_registry_get_or_create_and_snapshot_shape():
    r = Registry()
    assert r.histogram("a.b_s") is r.histogram("a.b_s")
    r.histogram("a.b_s").record(0.01)
    r.counter("c").inc(3)
    r.gauge("g").set(2.5)
    r.gauge("g").add(-0.5)
    snap = r.snapshot()
    assert snap["schema"] == SCHEMA_VERSION
    assert snap["histograms"]["a.b_s"]["count"] == 1
    assert snap["counters"] == {"c": 3}
    assert snap["gauges"] == {"g": 2.0}
    json.dumps(snap)  # the whole snapshot must be JSON-clean


# ----------------------------------------------------------------- trace
def test_span_is_noop_when_detached():
    assert trace.active() is None
    with trace.span("anything", k=2) as sid:
        assert sid is None  # shared null context manager


def test_spans_nest_implicitly_and_export_chrome():
    rec = TraceRecorder()
    with trace.attached(rec):
        with rec.span("request", kind="mine") as root:
            with rec.span("group.serve"):
                with rec.span("mine.wave", k=2):
                    pass
                with rec.span("mine.wave", k=3):
                    pass
        rec.add("admission.wait", rec.epoch, rec.epoch + 0.001, parent=root)
    assert trace.active() is None  # detached on exit
    roots = rec.to_json()
    assert len(roots) == 1 and roots[0]["name"] == "request"
    serve = next(c for c in roots[0]["children"] if c["name"] == "group.serve")
    assert [c["args"]["k"] for c in serve["children"]] == [2, 3]
    wait = next(c for c in roots[0]["children"] if c["name"] == "admission.wait")
    assert wait["dur_s"] == pytest.approx(0.001)
    events = rec.to_chrome()
    assert len(events) == len(rec) == 5
    for ev in events:
        assert ev["ph"] == "X" and ev["ts"] >= 0 and ev["dur"] >= 0
        assert ev["name"] and "span_id" in ev["args"]


def test_explicit_parent_crosses_threads():
    rec = TraceRecorder()
    root = rec.open("request")

    def worker():
        with rec.span("host.mine", parent=root):
            time.sleep(0.001)

    t = threading.Thread(target=worker)
    t.start()
    t.join()
    rec.close(root)
    roots = rec.to_json()
    assert len(roots) == 1
    assert roots[0]["children"][0]["name"] == "host.mine"


def test_close_is_idempotent_and_open_spans_export():
    rec = TraceRecorder()
    sid = rec.open("request")
    rec.close(sid, ok=True)
    t1 = rec.spans[sid]["t1"]
    rec.close(sid, ok=False)  # second close: no-op
    assert rec.spans[sid]["t1"] == t1 and rec.spans[sid]["args"] == {"ok": True}
    dangling = rec.open("stuck")
    ev = {e["args"].get("span_id"): e for e in rec.to_chrome()}
    assert ev[dangling]["args"]["open"] is True
    assert rec.spans[dangling]["t1"] is None  # export did not mutate it


def test_save_chrome_roundtrips(tmp_path):
    rec = TraceRecorder()
    with rec.span("request"):
        pass
    path = tmp_path / "trace.json"
    assert rec.save_chrome(str(path)) == 1
    events = json.loads(path.read_text())
    assert events[0]["name"] == "request" and events[0]["cat"] == "mining"


# --------------------------------------------------------------- emitter
def test_emitter_periodic_lines_and_final_snapshot():
    sink = io.StringIO()
    reg = Registry()
    reg.histogram("x_s").record(0.01)
    with StatsEmitter(reg.snapshot, sink, interval_s=0.01) as em:
        time.sleep(0.08)
    lines = [json.loads(l) for l in sink.getvalue().splitlines()]
    assert em.stats["periodic"] >= 2 and em.stats["errors"] == 0
    assert len(lines) == em.stats["emits"]
    assert lines[-1]["reason"] == "final"
    for i, line in enumerate(lines):
        assert line["schema"] == SCHEMA_VERSION and line["seq"] == i
        assert line["stats"]["histograms"]["x_s"]["count"] == 1
        assert line["uptime_s"] >= 0


def test_emitter_swallows_chaos_drops_and_keeps_ticking():
    sink = io.StringIO()
    em = StatsEmitter(lambda: {"ok": 1}, sink, interval_s=0.01)
    inj = ChaosInjector().arm("telemetry.emit", times=2)
    with installed(inj):
        assert em.emit_once() is False
        assert em.emit_once() is False
        assert em.emit_once() is True  # schedule exhausted -> line lands
    assert em.stats["dropped"] == 2 and em.stats["emits"] == 1
    assert em.stats["errors"] == 0
    assert len(sink.getvalue().splitlines()) == 1


def test_emitter_counts_snapshot_and_sink_errors():
    def boom():
        raise RuntimeError("snapshot failed")

    em = StatsEmitter(boom, io.StringIO(), interval_s=0.01)
    assert em.emit_once() is False and em.stats["errors"] == 1

    class BadSink:
        def write(self, s):
            raise OSError("disk gone")

    em2 = StatsEmitter(lambda: {}, BadSink(), interval_s=0.01)
    assert em2.emit_once() is False and em2.stats["errors"] == 1
    em2.stop(final=False)


def test_emitter_file_sink_creates_parents(tmp_path):
    path = tmp_path / "deep" / "stats.jsonl"
    with StatsEmitter(lambda: {"n": 1}, str(path), interval_s=5.0):
        pass  # no periodic tick fits; stop() emits the final line
    lines = path.read_text().splitlines()
    assert len(lines) == 1 and json.loads(lines[0])["reason"] == "final"


def test_emitter_rejects_bad_interval():
    with pytest.raises(ValueError):
        StatsEmitter(lambda: {}, io.StringIO(), interval_s=0.0)


# ---------------------------------------------------------------- wiring
def test_engine_records_stage_and_prep_histograms():
    eng = MiningEngine()
    rows = random_db(np.random.default_rng(2), 100, 10, 6)
    spec = MineSpec(algorithm="hprepost", max_k=4, candidate_unit=8, min_sup=0.3)
    eng.submit(rows, 10, spec)
    hs = eng.telemetry.snapshot()["histograms"]
    assert hs["engine.mine_s"]["count"] == 1
    assert hs["engine.prep_s"]["count"] == 1
    for stage in ("job1_flist", "job2_ppc_pack", "f2_scan"):
        assert hs[f"engine.stage.{stage}_s"]["count"] == 1
    eng.submit(rows, 10, spec)  # warm: served from the prep cache
    hs = eng.telemetry.snapshot()["histograms"]
    assert hs["engine.cache_hit_s"]["count"] >= 1
    assert hs["engine.mine_s"]["count"] == 2


def test_service_stats_report_populated_histograms():
    from repro.mining.service import MiningService

    rows = random_db(np.random.default_rng(1), 140, 10, 6)
    spec = MineSpec(algorithm="hprepost", max_k=4, candidate_unit=8, min_sup=0.3)
    rec = TraceRecorder()
    with MiningService(batch_window_s=0.01) as svc, trace.attached(rec):
        futs = svc.sweep(rows, 10, spec, [0.3, 0.2])
        futs.append(svc.submit(rows, 10, spec.with_(algorithm="apriori")))
        svc.drain()
        for f in futs:
            f.result()
        snap = svc.stats()
    hists = snap["histograms"]
    for key in ("admission.queue_wait_s", "engine.prep_s", "engine.mine_s",
                "service.request_s", "scheduler.serve_s"):
        h = hists[key]
        assert h["count"] >= 1, key
        assert h["min_s"] <= h["p50_s"] <= h["p95_s"] <= h["p99_s"] <= h["max_s"]
    assert hists["service.request_s"]["count"] == 3
    assert snap["telemetry"]["schema"] == SCHEMA_VERSION
    # drained: gauges back to zero
    assert snap["telemetry"]["gauges"]["admission.queue_depth"] == 0
    assert snap["telemetry"]["gauges"]["admission.bytes_in_flight"] == 0
    json.dumps(snap, default=str)
    # every request produced a full span tree under the attached recorder
    roots = [r for r in rec.to_json() if r["name"] == "request"]
    assert len(roots) == 3
    for r in roots:
        names = {c["name"] for c in r["children"]}
        assert "admission.wait" in names and "resolve" in names


def test_stream_append_and_query_histograms():
    eng = MiningEngine()
    spec = MineSpec(algorithm="hprepost", max_k=4, candidate_unit=8, min_sup=0.3)
    rng = np.random.default_rng(3)
    for _ in range(2):
        eng.append(random_db(rng, 40, 10, 6), 10, spec=spec)
    eng.submit_stream(spec)
    hs = eng.telemetry.snapshot()["histograms"]
    assert hs["stream.default.append_s"]["count"] == 2
    assert hs["stream.default.query_s"]["count"] == 1


def test_distributed_mine_records_per_worker_wave_histograms():
    rng = np.random.default_rng(1)
    batches = [random_db(rng, n, 10, 6) for n in (25, 18, 31)]
    spec = MineSpec(algorithm="hprepost", max_k=4, candidate_unit=8, min_sup=0.15)
    from repro.mining.stream import StreamSpec

    eng = MiningEngine()
    dm = eng.distribute(name="t", n_items=10, workers=2, spec=spec,
                        stream_spec=StreamSpec(row_pad=16))
    try:
        for b in batches:
            dm.append(b)
        res = dm.mine(spec)
        assert any(len(s) >= 2 for s in res.itemsets)  # waves really ran
        hs = eng.telemetry.snapshot()["histograms"]
        worker_hists = [k for k in hs if k.startswith("dist.t.worker")]
        assert len(worker_hists) == 2  # one wave-RPC histogram per worker
        for k in worker_hists:
            assert k.endswith(".wave_rpc_s") and hs[k]["count"] >= 1
        assert hs["dist.t.append_s"]["count"] == len(batches)
        assert hs["dist.t.query_s"]["count"] == 1
    finally:
        dm.close()
