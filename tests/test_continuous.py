"""Continuous mining (repro.mining.continuous): sliding windows, decayed
supports, and standing queries over the segmented database.

Anchors, per the PR acceptance criteria:
  - retraction: ``SegmentedDB.drop_segments`` subtracts a segment's
    histogram and F2 block exactly — counts/C/n_rows match a database
    that never saw the dropped batch (ranks stay append-only);
  - windowed parity: every windowed mine is bit-identical to a one-shot
    mine (and the brute-force oracle) over exactly the window's rows —
    across thresholds, PAD-heavy batches, the paper's Table 1 database,
    single-process and distributed, and through checkpoint restore;
  - decay: the time-decayed mode matches a float64 damped-window Apriori
    oracle exactly (dyadic decay weights make float equality exact);
  - standing queries: every append/expiry delivers a ``MineDiff``, and
    the diff stream replayed from empty reconstructs the delivered
    answer exactly — including under chaos on the expiry/diff points
    and with settled-wave seed pruning engaged;
  - telemetry: expiry counts and diff latency ride ``stats()``.
"""
import numpy as np
import pytest

from repro.core.encoding import PAD, pad_transactions
from repro.core.oracle import mine_bruteforce
from repro.data.synth import random_db
from repro.mining import MineSpec, MiningEngine
from repro.mining.continuous import damped_oracle, replay_diffs
from repro.mining.stream import StreamSpec
from repro.mining.stream.segmented import SegmentedDB

SPEC = MineSpec(algorithm="hprepost", max_k=4, candidate_unit=8, min_sup=0.3)


def _batches(seed=0, sizes=(30, 14, 22), n_items=10, max_len=6):
    rng = np.random.default_rng(seed)
    return [random_db(rng, n, n_items, max_len) for n in sizes], n_items


def _windowed_engine(batches, n_items, stream_spec, spec=SPEC):
    eng = MiningEngine()
    reports = [eng.append(b, n_items, spec=spec, stream_spec=stream_spec)
               for b in batches]
    return eng, reports


def _retained_rows(eng, stream="default"):
    db = eng.stream(stream).db
    return np.concatenate([s.rows[:s.n_rows] for s in db.segments])


# -------------------------------------------------- StreamSpec validation
def test_stream_spec_rejects_contradictory_compaction_knobs():
    # fanin larger than the segment cap could never fire a full pass
    with pytest.raises(ValueError, match="compact_fanin"):
        StreamSpec(max_segments=4, compact_fanin=8)
    StreamSpec(max_segments=8, compact_fanin=8)  # boundary is legal


def test_stream_spec_validates_continuous_knobs():
    with pytest.raises(ValueError, match="window_rows"):
        StreamSpec(window_rows=-1)
    with pytest.raises(ValueError, match="at most one"):
        StreamSpec(window_rows=100, window_batches=4)
    with pytest.raises(ValueError, match="decay"):
        StreamSpec(decay=0.0)
    with pytest.raises(ValueError, match="decay"):
        StreamSpec(decay=1.5)
    with pytest.raises(ValueError, match="decay"):
        StreamSpec(decay=0.5, small_rows=64)  # decayed streams never compact
    assert StreamSpec(window_rows=100).windowed
    assert StreamSpec(window_batches=3).windowed
    assert not StreamSpec().windowed


# ------------------------------------------------------ retraction primitive
def test_drop_segments_is_exact_retraction():
    batches, n_items = _batches(3, sizes=(20, 15, 25))
    eng, _ = _windowed_engine(batches, n_items, StreamSpec(max_segments=99))
    db = eng.stream().db
    victim = db.segments[0].seg_id
    dropped = db.drop_segments({victim})
    assert [s.seg_id for s in dropped] == [victim]
    # counts/C/n_rows equal a database that never saw batch 0 (the rank
    # space differs only by zero-count rows, which mining ignores)
    eng2, _ = _windowed_engine(batches[1:], n_items, StreamSpec(max_segments=99))
    db2 = eng2.stream().db
    assert db.n_rows == db2.n_rows
    rest = np.concatenate(batches[1:])
    res = eng.submit_stream(SPEC)
    assert res.itemsets == mine_bruteforce(rest, n_items, res.min_count, max_k=4)
    assert res.itemsets == eng2.submit_stream(SPEC).itemsets
    assert db.drop_segments({victim}) == []  # already gone: a no-op


def test_replace_segments_refuses_expired_victims():
    batches, n_items = _batches(4, sizes=(18, 12, 16))
    eng, _ = _windowed_engine(batches, n_items, StreamSpec(max_segments=99))
    db = eng.stream().db
    a, b = db.segments[0], db.segments[1]
    merged_src = [s for s in db.segments]
    db.drop_segments({a.seg_id})
    before = (db.n_rows, db.counts.copy(), len(db.segments))
    # a compaction merge planned before the expiry must be discarded
    assert db.replace_segments({a.seg_id, b.seg_id}, merged_src[2]) is False
    assert db.n_rows == before[0] and len(db.segments) == before[2]
    np.testing.assert_array_equal(db.counts, before[1])


# ---------------------------------------------------------- windowed parity
@pytest.mark.parametrize("min_sup", [0.5, 0.3, 0.15])
def test_window_rows_parity_across_thresholds(min_sup):
    batches, n_items = _batches(5, sizes=(25, 18, 31, 12, 20))
    ss = StreamSpec(window_rows=40)
    eng, reports = _windowed_engine(batches, n_items, ss)
    assert any(r["expired"] for r in reports)
    retained = _retained_rows(eng)
    spec = SPEC.with_(min_sup=min_sup)
    res = eng.submit_stream(spec)
    assert res.n_rows == len(retained)
    oneshot = MiningEngine().submit(retained, n_items, spec)
    oracle = mine_bruteforce(retained, n_items, res.min_count, max_k=4)
    assert res.itemsets == oneshot.itemsets == oracle
    # the window is the minimal suffix: dropping the oldest retained
    # segment would land under window_rows
    db = eng.stream().db
    assert db.n_rows - db.segments[0].n_rows < ss.window_rows


def test_window_batches_parity_and_telemetry():
    batches, n_items = _batches(6, sizes=(25, 18, 31, 12))
    eng, reports = _windowed_engine(batches, n_items, StreamSpec(window_batches=2))
    assert [r["expired"] for r in reports] == [0, 0, 1, 1]
    retained = np.concatenate(batches[-2:])
    res = eng.submit_stream(SPEC)
    assert res.n_rows == len(retained)
    assert res.itemsets == mine_bruteforce(retained, n_items, res.min_count, max_k=4)
    st = eng.stream_stats()["default"]
    assert st["expires"] == 2 and st["expired_segments"] == 2
    assert st["expired_rows"] == len(batches[0]) + len(batches[1])


def test_window_parity_pad_heavy_batches():
    from repro.core.encoding import pad_transactions

    b1 = pad_transactions([[0], [1, 2], [], [0, 2]], max_len=8)
    b2 = pad_transactions([[2], [], [], [0, 1, 2]], max_len=8)
    b3 = np.full((3, 8), -1, np.int32)  # all-PAD rows still count and expire
    b4 = pad_transactions([[0, 1], [1, 2], [0]], max_len=8)
    eng = MiningEngine()
    ss = StreamSpec(window_rows=7)
    reports = [eng.append(b, 3, spec=SPEC, stream_spec=ss)
               for b in (b1, b2, b3, b4)]
    # the all-PAD batch made no segment but its rows joined the window:
    # b1's segment expired (b2+b3+b4 = 10 rows is the minimal suffix)
    assert [r["expired_rows"] for r in reports] == [0, 0, 4, 0]
    res = eng.submit_stream(SPEC.with_(min_sup=0.2))
    assert res.n_rows == len(b2) + len(b3) + len(b4) == 10
    oracle_rows = np.concatenate([b2, b3, b4])
    assert res.itemsets == mine_bruteforce(oracle_rows, 3, res.min_count, max_k=4)
    # ... and the all-PAD rows age out too: two more small batches push
    # them (and b2) past the 7-row window
    b5 = pad_transactions([[0, 2], [1]], max_len=8)
    eng.append(b5, 3)
    rep = eng.append(b5, 3)
    assert rep["expired_rows"] > 0
    sm = eng.stream()
    assert not sm._empty_trail  # the segment-less rows were retracted
    res2 = eng.submit_stream(SPEC.with_(min_sup=0.2))
    assert res2.n_rows == sum(s.n_rows for s in sm.db.segments)


def test_window_parity_paper_db_anchor(paper_db):
    # the paper's Table 1 database, split 2+3, window of one batch: the
    # windowed answer is exactly the last 3 transactions' frequent sets
    rows, n_items = paper_db
    eng = MiningEngine()
    ss = StreamSpec(window_batches=1)
    spec = SPEC.with_(min_count=2, max_k=3)
    eng.append(rows[:2], n_items, spec=spec, stream_spec=ss)
    eng.append(rows[2:], n_items, spec=spec, stream_spec=ss)
    res = eng.submit_stream(spec)
    assert res.n_rows == len(rows) - 2
    assert res.itemsets == mine_bruteforce(rows[2:], n_items, 2, max_k=3)


def test_windowed_compaction_respects_window_boundaries():
    # compaction inside a windowed stream folds a contiguous append-order
    # run, so expiry stays segment-granular and answers stay exact
    batches, n_items = _batches(7, sizes=(12, 10, 14, 11, 13, 12))
    ss = StreamSpec(window_rows=45, max_segments=3, compact_fanin=2,
                    compact_async=False)
    eng, _ = _windowed_engine(batches, n_items, ss)
    sm = eng.stream()
    assert sm.stats["compactions"] >= 1
    # every retained segment is a contiguous run: seg_ids sorted == order
    ids = [s.seg_id for s in sm.db.segments]
    assert ids == sorted(ids)
    retained = _retained_rows(eng)
    res = eng.submit_stream(SPEC)
    assert res.n_rows == len(retained)
    assert res.itemsets == mine_bruteforce(retained, n_items, res.min_count, max_k=4)


def test_deterministic_interleaving_parity_and_diff_reconstruction():
    # hypothesis-free anchor for the property tests: a fixed pseudo-random
    # append/compact interleaving where every step must keep (1) windowed
    # parity with the oracle over the retained rows and (2) the standing
    # diff stream replaying to the live answer
    rng = np.random.default_rng(11)
    n_items = 8
    ss = StreamSpec(window_rows=60, max_segments=4, compact_fanin=2,
                    compact_async=False)
    eng = MiningEngine()
    eng.stream(n_items=n_items, spec=SPEC, stream_spec=ss)
    q = eng.register_standing(SPEC)
    for k in range(8):
        eng.append(random_db(rng, 12 + int(rng.integers(0, 18)), n_items, 5),
                   n_items)
        retained = _retained_rows(eng)
        res = eng.submit_stream(SPEC)
        assert res.n_rows == len(retained)
        assert res.itemsets == mine_bruteforce(
            retained, n_items, res.min_count, max_k=4)
        assert replay_diffs(q.diffs) == q.latest == res.itemsets


# ------------------------------------------------------------------- decay
def test_decayed_supports_match_damped_oracle():
    batches, n_items = _batches(8, sizes=(20, 15, 25, 18))
    decay = 0.5  # dyadic: float accumulation is exact, equality is literal
    spec = SPEC.with_(min_sup=None, min_count=3)
    eng = MiningEngine()
    ss = StreamSpec(decay=decay)
    for b in batches:
        eng.append(b, n_items, spec=spec, stream_spec=ss)
    res = eng.submit_stream(spec)
    oracle = damped_oracle(batches, n_items, decay, 3.0, max_k=4)
    assert set(res.itemsets) == set(oracle)
    for t, s in res.itemsets.items():
        assert isinstance(s, float)
        assert s == oracle[t]  # exact dyadic arithmetic, not isclose
    st = res.service_stats
    assert st["decay"] == decay
    assert st["weighted_rows"] == pytest.approx(
        sum(len(b) * decay ** (len(batches) - 1 - i)
            for i, b in enumerate(batches)))


def test_decayed_stream_refuses_compaction():
    batches, n_items = _batches(9, sizes=(15, 15))
    eng = MiningEngine()
    for b in batches:
        eng.append(b, n_items, spec=SPEC, stream_spec=StreamSpec(decay=0.5))
    with pytest.raises(ValueError, match="decay"):
        eng.stream().compact()


# --------------------------------------------------------- standing queries
def test_standing_query_diffs_replay_to_the_live_answer():
    batches, n_items = _batches(10, sizes=(25, 18, 31, 12))
    eng = MiningEngine()
    ss = StreamSpec(window_rows=50)
    eng.stream(n_items=n_items, spec=SPEC, stream_spec=ss)
    q = eng.register_standing(SPEC)
    assert q.diffs[0].cause == "register" and q.diffs[0].total == 0
    causes = []
    for b in batches:
        rep = eng.append(b, n_items)
        assert rep["diffs"] == 1
        causes.append(q.diffs[-1].cause)
    assert "append" in causes and "expire" in causes
    final = eng.submit_stream(SPEC)
    assert replay_diffs(q.diffs) == q.latest == final.itemsets
    assert q.diffs[-1].n_rows == final.n_rows
    retained = _retained_rows(eng)
    assert final.itemsets == mine_bruteforce(
        retained, n_items, final.min_count, max_k=4)


def test_standing_query_seed_pruning_stays_exact():
    # pairs (0,1)/(0,2)/(1,2) are frequent but the triple is rare: the
    # first refresh dispatches {0,1,2} and settles it at 10 < min_count;
    # the next refresh's seed bound (10 + 5 rows appended since) proves
    # it dead without dispatching it — a pruned candidate, same answer
    n_items = 4
    spec = SPEC.with_(min_sup=None, min_count=35)
    tx = [[0, 1]] * 30 + [[0, 2]] * 30 + [[1, 2]] * 30 + [[0, 1, 2]] * 10
    b1 = pad_transactions(tx, max_len=3)
    b2 = pad_transactions([[0, 1, 2]] * 5, max_len=3)
    eng = MiningEngine()
    eng.stream(n_items=n_items, spec=spec, stream_spec=StreamSpec())
    q = eng.register_standing(spec)
    eng.append(b1, n_items)
    eng.append(b2, n_items)
    st = eng.stream_stats()["default"]
    assert st["seed_pruned_candidates"] > 0  # the seed actually pruned
    allrows = _retained_rows(eng)
    assert q.latest == mine_bruteforce(allrows, n_items, 35, max_k=4)
    assert replay_diffs(q.diffs) == q.latest
    # and an unseeded mine agrees bit-for-bit
    assert eng.submit_stream(spec).itemsets == q.latest


def test_standing_query_patterns_ride_the_delivered_view():
    from repro.core.patterns import closed_itemsets

    batches, n_items = _batches(12, sizes=(25, 20, 22))
    eng = MiningEngine()
    eng.stream(n_items=n_items, spec=SPEC, stream_spec=StreamSpec())
    q = eng.register_standing(SPEC.with_(patterns="closed"))
    for b in batches:
        eng.append(b, n_items)
    full = eng.submit_stream(SPEC).itemsets
    assert q.latest == closed_itemsets(full)
    assert replay_diffs(q.diffs) == q.latest


def test_standing_query_next_diff_future_and_cancel():
    batches, n_items = _batches(13, sizes=(20, 15, 18))
    eng = MiningEngine()
    eng.stream(n_items=n_items, spec=SPEC, stream_spec=StreamSpec())
    q = eng.register_standing(SPEC)
    f = q.next_diff()
    assert not f.done()
    eng.append(batches[0], n_items)
    assert f.result(timeout=5) is q.diffs[-1]
    eng.cancel_standing(q)
    n = len(q.diffs)
    eng.append(batches[1], n_items)
    assert len(q.diffs) == n and not q.active
    assert eng.stream_stats()["default"]["standing_queries"] == 0


def test_standing_register_rejects_bad_spec_and_registers_nothing():
    batches, n_items = _batches(14, sizes=(20,))
    eng = MiningEngine()
    eng.append(batches[0], n_items, spec=SPEC, stream_spec=StreamSpec())
    with pytest.raises(ValueError):
        eng.register_standing(SPEC.with_(algorithm="apriori"))
    assert eng.stream_stats()["default"]["standing_queries"] == 0


# -------------------------------------------------------------------- chaos
def test_expiry_failure_skips_and_self_heals():
    from repro.fault.failures import ChaosInjector, installed

    batches, n_items = _batches(15, sizes=(20, 15, 25, 18, 22))
    ss = StreamSpec(window_rows=40)
    eng = MiningEngine()
    inj = ChaosInjector(seed=0).arm("stream.expire", times=2)
    with installed(inj):
        for b in batches[:4]:
            eng.append(b, n_items, spec=SPEC, stream_spec=ss)
    st = eng.stream_stats()["default"]
    assert st["expire_errors"] == 2
    # chaos is off: the next append expires everything the window owes
    eng.append(batches[4], n_items)
    db = eng.stream().db
    assert db.n_rows - db.segments[0].n_rows < ss.window_rows
    retained = _retained_rows(eng)
    res = eng.submit_stream(SPEC)
    assert res.n_rows == len(retained)
    assert res.itemsets == mine_bruteforce(retained, n_items, res.min_count, max_k=4)


def test_diff_failure_keeps_the_chain_consistent():
    from repro.fault.failures import ChaosInjector, installed

    batches, n_items = _batches(16, sizes=(20, 15, 18, 22))
    eng = MiningEngine()
    eng.stream(n_items=n_items, spec=SPEC, stream_spec=StreamSpec())
    q = eng.register_standing(SPEC)
    inj = ChaosInjector(seed=0).arm("stream.diff", after=1, times=1)
    with installed(inj):
        for b in batches[:3]:
            eng.append(b, n_items)
    eng.append(batches[3], n_items)
    st = eng.stream_stats()["default"]
    assert st["diff_errors"] == 1
    assert len(q.diffs) == 4  # register + 3 delivered (one append skipped)
    final = eng.submit_stream(SPEC)
    assert replay_diffs(q.diffs) == q.latest == final.itemsets


# ------------------------------------------------------------------ service
def test_service_standing_query_futures_arrive_in_order():
    from repro.mining.service import MiningService

    batches, n_items = _batches(17, sizes=(22, 18, 20))
    with MiningService(batch_window_s=0.01) as svc:
        svc.engine.stream("w", n_items=n_items, spec=SPEC,
                          stream_spec=StreamSpec(window_rows=40))
        q = svc.register_standing(SPEC, stream="w").result(timeout=60)
        afuts = [svc.append(b, n_items, stream="w") for b in batches]
        res = svc.submit_stream(SPEC, stream="w").result(timeout=60)
        reps = [f.result(timeout=60) for f in afuts]
        assert all(r["diffs"] == 1 for r in reps)
        # the query submitted after the appends observed all of them
        assert replay_diffs(q.diffs) == q.latest == res.itemsets
        svc.cancel_standing(q, stream="w").result(timeout=60)
        assert svc.engine.stream_stats()["w"]["standing_queries"] == 0


# -------------------------------------------------------------- distributed
@pytest.fixture(scope="module")
def windowed_cluster(tmp_path_factory):
    batches, n_items = _batches(18, sizes=(25, 18, 31, 12))
    ck = tmp_path_factory.mktemp("cont-ck")
    eng = MiningEngine()
    dm = eng.distribute(
        name="w", n_items=n_items, workers=2, spec=SPEC,
        stream_spec=StreamSpec(window_batches=2), checkpoint_dir=str(ck),
    )
    q = dm.register(SPEC)
    reports = [dm.append(b) for b in batches]
    yield eng, dm, q, reports, batches, n_items, str(ck)
    dm.close()


def test_distributed_window_parity_and_standing(windowed_cluster):
    _, dm, q, reports, batches, n_items, _ = windowed_cluster
    assert [r["expired"] for r in reports] == [0, 0, 1, 1]
    retained = np.concatenate(batches[-2:])
    res = dm.mine(SPEC)
    assert res.n_rows == len(retained)
    assert res.itemsets == mine_bruteforce(retained, n_items, res.min_count, max_k=4)
    assert replay_diffs(q.diffs) == q.latest == res.itemsets
    assert dm.stats["expired_segments"] == 2
    assert dm.stats["diffs_delivered"] == len(q.diffs)


def test_distributed_restore_replays_expired_segments(windowed_cluster):
    _, dm, _, _, batches, n_items, ck = windowed_cluster
    before = dm.mine(SPEC)
    eng2 = MiningEngine()
    dm2 = eng2.distribute(
        name="w2", n_items=n_items, workers=2, spec=SPEC,
        stream_spec=StreamSpec(window_batches=2), checkpoint_dir=ck,
    )
    try:
        assert dm2._expired == dm._expired
        res = dm2.mine(SPEC)
        assert res.itemsets == before.itemsets
        assert res.n_rows == before.n_rows
        # the restored rank space matches: digests of live segments agree
        assert dm2._db_digest() == dm._db_digest()
    finally:
        dm2.close()


def test_distributed_empty_batches_age_out_of_the_window(tmp_path):
    # an all-PAD batch creates no segment but its rows join db.n_rows;
    # the append-order ledger must expire them like any other entry —
    # and a restored coordinator must agree
    n_items = 6
    b1 = pad_transactions(
        [[0, 1], [1, 2], [0, 2], [3], [0, 1, 2], [2, 3], [1, 3], [0, 3]],
        max_len=4)
    b_pad = np.full((6, 4), PAD, np.int32)
    b2 = pad_transactions(
        [[0, 1], [0, 1, 2], [2, 3], [1, 2], [0, 3], [1, 3], [0, 2], [3]],
        max_len=4)
    b3 = pad_transactions([[0, 1], [1, 2], [0, 1, 2], [2]], max_len=4)
    eng = MiningEngine()
    dm = eng.distribute(
        name="we", n_items=n_items, workers=1, spec=SPEC,
        stream_spec=StreamSpec(window_rows=10), checkpoint_dir=str(tmp_path),
    )
    try:
        reports = [dm.append(b) for b in (b1, b_pad, b2, b3)]
        # append 3 expires the 8-row segment; append 4 expires the 6
        # segment-less PAD rows (a rows-only expiry: no segment dropped)
        assert [r["expired"] for r in reports] == [0, 0, 1, 0]
        assert [r["expired_rows"] for r in reports] == [0, 0, 8, 6]
        assert not dm._empty_rows
        retained = np.concatenate([b2, b3])
        res = dm.mine(SPEC)
        assert res.n_rows == len(retained) == 12
        assert res.itemsets == mine_bruteforce(
            retained, n_items, res.min_count, max_k=4)
        eng2 = MiningEngine()
        dm2 = eng2.distribute(
            name="we2", n_items=n_items, workers=1, spec=SPEC,
            stream_spec=StreamSpec(window_rows=10),
            checkpoint_dir=str(tmp_path),
        )
        try:
            res2 = dm2.mine(SPEC)
            assert res2.n_rows == res.n_rows
            assert res2.itemsets == res.itemsets
            assert dm2._db_digest() == dm._db_digest()
        finally:
            dm2.close()
    finally:
        dm.close()


def test_distributed_rejects_decay():
    eng = MiningEngine()
    with pytest.raises(ValueError, match="decay"):
        eng.distribute(name="nope", n_items=8, workers=1,
                       stream_spec=StreamSpec(decay=0.5))
