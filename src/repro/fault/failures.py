"""Fault tolerance: failure injection, restart policy, straggler monitor.

On a real cluster the runtime signals (preemption notice, ICI link error,
host heartbeat loss) arrive from the platform; here they are modeled so the
*recovery logic* — which is what this framework owns — is real and tested:

  - ``FailureInjector``: deterministic or probabilistic step failures
    (raises ``SimulatedFailure`` mid-loop).
  - ``run_with_restarts``: supervisor that restarts the training loop from
    the latest checkpoint, with bounded retries — the Hadoop-style task
    re-execution the paper gets from MapReduce, at trainer granularity.
  - ``StragglerMonitor``: per-step wall-time EWMA; steps slower than
    ``threshold ×`` the EWMA are flagged, and the data loader can be told
    to skip/redistribute the slow shard (mitigation hook).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    fail_at_steps: tuple = ()  # deterministic failures (once each)
    fail_prob: float = 0.0  # plus i.i.d. failures
    seed: int = 0

    def __post_init__(self):
        self._fired: set[int] = set()
        import random

        self._rng = random.Random(self.seed)

    def maybe_fail(self, step: int):
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")
        if self.fail_prob and self._rng.random() < self.fail_prob:
            raise SimulatedFailure(f"random failure at step {step}")


class StragglerMonitor:
    def __init__(self, threshold: float = 3.0, ewma: float = 0.9):
        self.threshold = threshold
        self.ewma_coef = ewma
        self.mean: float | None = None
        self.flagged: list[int] = []

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        if self.mean is None:
            self.mean = dt
            return False
        is_straggler = dt > self.threshold * self.mean
        if is_straggler:
            self.flagged.append(step)
        else:  # stragglers don't contaminate the baseline
            self.mean = self.ewma_coef * self.mean + (1 - self.ewma_coef) * dt
        return is_straggler


def run_with_restarts(
    run: Callable[[int], int],
    latest_step: Callable[[], int | None],
    max_restarts: int = 5,
) -> int:
    """Supervisor: call ``run(start_step)``; on failure, resume from the
    latest checkpoint. Returns the final step reached."""
    restarts = 0
    while True:
        start = (latest_step() or -1) + 1
        try:
            return run(start)
        except SimulatedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
