"""Fault tolerance: failure injection, restart policy, straggler monitor.

On a real cluster the runtime signals (preemption notice, ICI link error,
host heartbeat loss) arrive from the platform; here they are modeled so the
*recovery logic* — which is what this framework owns — is real and tested:

  - ``FailureInjector``: deterministic or probabilistic step failures
    (raises ``SimulatedFailure`` mid-loop) — the training-loop shape.
  - ``ChaosInjector``: the same idea generalized from *steps* to *named
    failure points* threaded through the mining stack (service enqueue,
    prep, wave launch, RPC send/recv, snapshot read, and the continuous
    lane: ``stream.expire`` fires before a sliding-window expiry pass —
    a hit skips the pass, the window self-heals next append — and
    ``stream.diff`` fires before each standing-query refresh — a hit
    leaves that query's delivered state untouched so its diff chain
    stays replayable — and ``telemetry.emit`` fires before each periodic
    stats snapshot (``repro.mining.telemetry.StatsEmitter``) — a hit
    drops that emit line, counted in the emitter's ``dropped`` stat,
    and must never block or fail a request Future). Production code
    calls ``fire(point)`` — a no-op until a test/soak ``install``s an
    injector — and the injector decides, deterministically (nth hit) or
    probabilistically (seeded), whether that hit dies and with what
    exception type. This is how the chaos harness proves the service
    invariant: every accepted Future resolves, whatever we break.
  - ``run_with_restarts``: supervisor that restarts the training loop from
    the latest checkpoint, with bounded retries — the Hadoop-style task
    re-execution the paper gets from MapReduce, at trainer granularity.
  - ``StragglerMonitor``: per-step wall-time EWMA; steps slower than
    ``threshold ×`` the EWMA are flagged, and the data loader can be told
    to skip/redistribute the slow shard (mitigation hook).
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import random
import threading
from typing import Callable


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    fail_at_steps: tuple = ()  # deterministic failures (once each)
    fail_prob: float = 0.0  # plus i.i.d. failures
    seed: int = 0

    def __post_init__(self):
        self._fired: set[int] = set()
        self._rng = random.Random(self.seed)

    def maybe_fail(self, step: int):
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")
        if self.fail_prob and self._rng.random() < self.fail_prob:
            raise SimulatedFailure(f"random failure at step {step}")


# --------------------------------------------------------- chaos (mining)
@dataclasses.dataclass
class _PointPlan:
    """Firing plan for one named point: skip ``after`` hits, then fail the
    next ``times`` matching hits; plus i.i.d. failures at ``prob``."""

    exc: Callable[[str], BaseException]
    after: int = 0
    times: int = 1
    prob: float = 0.0


class ChaosInjector:
    """Named failure points for the mining stack (service / RPC / store).

    ``arm("service.prep", after=1)`` kills the second prep; ``arm("rpc.recv",
    prob=0.05, times=10**9, exc=TimeoutError)`` makes 5% of coordinator
    receives time out. ``fire(point)`` is what the instrumented code calls;
    deterministic countdowns and the seeded RNG make a chaos run (and its
    failure schedule) exactly reproducible. Counters: ``seen`` every hit,
    ``fired`` the hits that actually raised.
    """

    def __init__(self, seed: int = 0):
        self._plans: dict[str, _PointPlan] = {}
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self.seen: collections.Counter = collections.Counter()
        self.fired: collections.Counter = collections.Counter()

    def arm(self, point: str, *, after: int = 0, times: int = 1,
            prob: float = 0.0, exc: Callable[[str], BaseException] = SimulatedFailure):
        self._plans[point] = _PointPlan(exc=exc, after=after, times=times, prob=prob)
        return self

    def disarm(self, point: str) -> None:
        self._plans.pop(point, None)

    def fire(self, point: str) -> None:
        with self._lock:
            self.seen[point] += 1
            plan = self._plans.get(point)
            if plan is None:
                return
            hit = False
            if plan.after > 0:
                plan.after -= 1
            elif plan.times > 0:
                plan.times -= 1
                hit = True
            if not hit and plan.prob and self._rng.random() < plan.prob:
                hit = True
            if not hit:
                return
            self.fired[point] += 1
            n = self.seen[point]
        raise plan.exc(f"chaos: injected failure at {point} (hit #{n})")


_active: ChaosInjector | None = None


def fire(point: str) -> None:
    """Production-side hook: raise iff an installed injector says so.

    The cost when chaos is off is one module-global read — cheap enough to
    sit on hot paths (wave launches, RPC frames)."""
    inj = _active
    if inj is not None:
        inj.fire(point)


@contextlib.contextmanager
def installed(inj: ChaosInjector):
    """Install ``inj`` as the process's active injector for the block.

    Process-global on purpose: the points worth breaking live on service
    worker threads, scheduler pools, and coordinator RPC paths that the
    test cannot reach by argument-passing."""
    global _active
    prev = _active
    _active = inj
    try:
        yield inj
    finally:
        _active = prev


class StragglerMonitor:
    def __init__(self, threshold: float = 3.0, ewma: float = 0.9):
        self.threshold = threshold
        self.ewma_coef = ewma
        self.mean: float | None = None
        self.flagged: list[int] = []

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        if self.mean is None:
            self.mean = dt
            return False
        is_straggler = dt > self.threshold * self.mean
        if is_straggler:
            self.flagged.append(step)
        else:  # stragglers don't contaminate the baseline
            self.mean = self.ewma_coef * self.mean + (1 - self.ewma_coef) * dt
        return is_straggler


def run_with_restarts(
    run: Callable[[int], int],
    latest_step: Callable[[], int | None],
    max_restarts: int = 5,
) -> int:
    """Supervisor: call ``run(start_step)``; on failure, resume from the
    latest checkpoint. Returns the final step reached."""
    restarts = 0
    while True:
        start = (latest_step() or -1) + 1
        try:
            return run(start)
        except SimulatedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
