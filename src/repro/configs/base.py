"""Model/run configuration system.

One frozen dataclass describes every architecture; per-arch files under
``repro/configs/`` instantiate it with the exact public hyperparameters.
``reduced()`` derives the family-preserving tiny config used by CPU smoke
tests (the full configs are exercised only via the allocation-free dry-run).
"""
from __future__ import annotations

import dataclasses
import importlib


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    attn_every: int = 0  # hybrid: one shared attention block every k layers
    slstm_every: int = 0  # xlstm: an sLSTM block every k layers (rest mLSTM)
    # enc-dec
    encoder_layers: int = 0
    # modality frontend (STUB per assignment: precomputed embeddings)
    frontend: str | None = None  # vision | audio
    frontend_tokens: int = 256
    # numerics / layout
    dtype: str = "bfloat16"
    vocab_pad_multiple: int = 128
    # capability flags (drive shape-cell applicability)
    supports_decode: bool = True
    subquadratic: bool = False  # may run long_500k
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return (self.vocab_size + m - 1) // m * m

    @property
    def ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_head_dim

    def reduced(self) -> "ModelConfig":
        """Family-preserving tiny config for CPU smoke tests."""
        layers = 4 if self.family == "hybrid" else 2 if not self.slstm_every else 4
        return dataclasses.replace(
            self,
            n_layers=layers,
            d_model=64,
            n_heads=4,
            n_kv_heads=2 if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            vocab_pad_multiple=16,
            n_experts=4 if self.n_experts else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.n_experts else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            attn_every=2 if self.attn_every else 0,
            slstm_every=2 if self.slstm_every else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            frontend_tokens=8 if self.frontend else 256,
            dtype="float32",
        )


ARCH_IDS = [
    "phi3_5_moe",
    "granite_moe",
    "qwen1_5_0_5b",
    "minitron_8b",
    "internlm2_20b",
    "tinyllama_1_1b",
    "xlstm_125m",
    "zamba2_2_7b",
    "internvl2_26b",
    "seamless_m4t_v2",
]

# CLI aliases (the assignment's hyphenated ids)
ALIASES = {
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "granite-moe-1b-a400m": "granite_moe",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "minitron-8b": "minitron_8b",
    "internlm2-20b": "internlm2_20b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "xlstm-125m": "xlstm_125m",
    "zamba2-2.7b": "zamba2_2_7b",
    "internvl2-26b": "internvl2_26b",
    "seamless-m4t-large-v2": "seamless_m4t_v2",
}


def list_archs() -> list[str]:
    return list(ARCH_IDS)


def get_config(arch: str) -> ModelConfig:
    arch = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG
