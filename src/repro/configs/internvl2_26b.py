"""InternVL2-26B (InternViT + InternLM2 backbone). [arXiv:2404.16821; hf]
Backbone: 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
Vision frontend is a STUB per the assignment: input_specs() supplies
precomputed patch embeddings (projected by a learned connector)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    frontend="vision",
    frontend_tokens=256,
)
