"""SeamlessM4T-large-v2 (enc-dec, multimodal). [arXiv:2308.11596; hf]
24L (per stack) d_model=1024 16H (kv=16) d_ff=8192 vocab=256206.
Speech frontend is a STUB: input_specs() supplies precomputed frame
embeddings to the 24L encoder; the 24L decoder attends via cross-attention.
Decode shapes exercise the decoder KV cache + fixed encoder memory."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,
    encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    frontend="audio",
)
