"""xLSTM-125M (sLSTM + mLSTM blocks). [arXiv:2405.04517; unverified] 12L
d_model=768 4H d_ff=0 (projection factor inside blocks) vocab=50304.
One sLSTM block every 4 layers, rest mLSTM (paper's 7:1-ish mix at small
scale). Recurrent state => O(1)/token decode => long_500k applicable."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_every=4,
    supports_decode=True,
    subquadratic=True,
)
