"""Zamba2-2.7B (Mamba2 backbone + shared attention). [arXiv:2411.15242; hf]
54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000, ssm_state=64.
One *shared* (weight-tied) full-attention block applied every 6 layers
(the public model interleaves 2 shared blocks; we model the weight-tying
with a single shared block, noted in DESIGN.md). Mamba2 state + periodic
attention => subquadratic decode => long_500k applicable (attention KV is
sequence-sharded)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,
    subquadratic=True,
)
