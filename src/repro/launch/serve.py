"""Serving launcher: batched greedy generation with the static-cache engine.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama_1_1b --reduced
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models.common import init_params
from repro.models.registry import build_model
from repro.serving.engine import Engine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--requests", type=int, default=4)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    eng = Engine(cfg, params, batch_size=args.batch, max_seq=args.max_seq)

    rng = np.random.default_rng(0)
    reqs = [
        Request(rng.integers(1, cfg.vocab_size, size=rng.integers(4, 24)).astype(np.int32),
                max_new=args.max_new)
        for _ in range(args.requests)
    ]
    done = []
    for i in range(0, len(reqs), args.batch):
        done += eng.generate(reqs[i : i + args.batch])
    for i, r in enumerate(done):
        print(f"req{i}: prompt[{len(r.prompt)}] -> {r.out}")
    return done


if __name__ == "__main__":
    main()
