"""Loop-aware HLO cost model (XLA's cost_analysis counts while bodies once).

Parses post-optimization HLO text into computations, builds the call graph
(fusion ``calls=``, ``while`` body/condition with ``known_trip_count``,
``to_apply``), and rolls up per-computation costs with call multipliers:

  flops      — 2·M·N·K per ``dot``/``convolution`` (resolving operand shapes
               through a per-computation symbol table) + 1 flop/element for
               elementwise ops
  hbm bytes  — Σ (operand + result bytes) of memory-touching top-level ops
               in non-fused computations (post-fusion, operands/results are
               materialized buffers — the standard traffic model; tuple/gte/
               bitcast/parameter plumbing is free)
  collective — payload bytes per collective op type

Validated against analytically-known workloads in tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "s4": 1, "u4": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OPCODE_RE = re.compile(r"([a-z][a-z0-9\-]*)\(")
_TRIP_RE = re.compile(r'known_trip_count[":{\s]+n[":\s]+"?(\d+)')
_CALLEE_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")

# ops that don't touch memory (plumbing) — excluded from the byte model.
# "copy" is excluded deliberately: the CPU-backend scheduled HLO copies
# while-loop carries (residual stacks) every iteration, but XLA:TPU aliases
# loop carries in place — counting them would charge TBs of phantom traffic
# to every scanned-layer model (validated in tests/test_roofline.py).
_FREE_OPS = {
    "tuple", "get-tuple-element", "bitcast", "parameter", "constant",
    "after-all", "add-dependency", "while", "conditional", "call", "custom-call",
    "partition-id", "replica-id", "domain", "opt-barrier", "copy",
}
# elementwise-ish opcodes: 1 flop per output element
_EW_FLOPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "exponential",
    "log", "tanh", "rsqrt", "sqrt", "power", "select", "compare", "and", "or",
    "negate", "abs", "floor", "sign", "convert", "exponential-minus-one", "logistic",
}


def _size_of(shapes: list[tuple[str, str]]) -> tuple[int, int]:
    elems = byts = 0
    for dt, dims in shapes:
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


def _operand_shapes(args_text: str, symbols: dict) -> list[tuple[str, str]]:
    """Shapes of an op's operands, one entry per operand.

    The symbol table is authoritative; the inline type annotation
    (``f32[16,512,512]{2,1,0} %p``) is only a fallback for refs defined on
    lines the parser skipped. Counting both — as a naive
    symbols-plus-findall scan does — double-charges every typed operand,
    which inflated scanned-slice programs by a whole extra copy of the
    stacked operand per iteration (caught by tests/test_roofline.py).
    """
    shapes: list[tuple[str, str]] = []
    for m in re.finditer(
        r"(?:([a-z][a-z0-9]*\[[0-9,]*\])(?:\{[^}]*\})?\s+)?%([\w\.\-]+)", args_text
    ):
        ref_shapes = symbols.get(m.group(2))
        if ref_shapes:
            shapes += ref_shapes
        elif m.group(1):
            shapes += _SHAPE_RE.findall(m.group(1))
    return shapes


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_payload: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    calls: list = dataclasses.field(default_factory=list)  # (callee, multiplier)
    fused: bool = False
    has_slice: bool = False  # dynamic-slice/gather in body
    has_dus: bool = False  # dynamic-update-slice/scatter in body


def parse(hlo: str) -> tuple[dict[str, CompCost], str | None]:
    comps: dict[str, CompCost] = {}
    name = None
    entry = None
    symbols: dict[str, list[tuple[str, str]]] = {}  # %op -> result shapes

    for raw in hlo.splitlines():
        line = raw.strip()
        if line.endswith("{") and ") -> " in line and ("%" in line.split("(")[0] or line.startswith("ENTRY")):
            header = line.split("(")[0].strip()
            name = header.replace("ENTRY", "").strip().lstrip("%")
            comps[name] = CompCost(fused="fused" in name or "wrapped" in name)
            if raw.startswith("ENTRY"):
                entry = name
            symbols = {}
            continue
        if name is None or " = " not in line:
            continue
        lhs, rhs = line.split(" = ", 1)
        lhs = lhs.strip().lstrip("%")
        cc = comps[name]

        # strip metadata/backend_config before shape-scanning operands
        core = rhs.split(", metadata=")[0]
        # result shapes = shapes before the opcode's '('
        op_m = _OPCODE_RE.search(core)
        opcode = op_m.group(1) if op_m else ""
        res_text = core[: op_m.start()] if op_m else core
        res_shapes = _SHAPE_RE.findall(res_text)
        symbols[lhs] = res_shapes

        # ---- call graph
        if opcode == "while":
            trip = 1
            tm = _TRIP_RE.search(rhs)
            if tm:
                trip = int(tm.group(1))
            body = _CALLEE_RE.search(core)
            cond = _COND_RE.search(core)
            if body:
                cc.calls.append((body.group(1), trip))
            if cond:
                cc.calls.append((cond.group(1), trip + 1))
            continue
        if opcode in ("fusion", "call", "conditional", "sort", "reduce", "scatter",
                      "reduce-window", "map", "reduce-scatter", "all-reduce"):
            for callee in _CALLEE_RE.findall(core):
                cc.calls.append((callee, 1))
            for callee in re.findall(
                r"(?:true_computation|false_computation)=%?([\w\.\-]+)", core
            ):
                cc.calls.append((callee, 1))

        # ---- operand shapes via symbol table (inline types as fallback)
        args_m = re.search(rf"{re.escape(opcode)}\(([^)]*)\)", core) if opcode else None
        operand_shapes: list[tuple[str, str]] = []
        if args_m:
            operand_shapes = _operand_shapes(args_m.group(1), symbols)

        # ---- flops
        if opcode in ("dot", "convolution"):
            res_elems, _ = _size_of(res_shapes)
            k = 1
            cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", core)
            if cm:
                # lhs operand = first %ref in the dot args
                refs = re.findall(r"%([\w\.\-]+)", args_m.group(1)) if args_m else []
                lhs_shape = symbols.get(refs[0], [("", "")])[0] if refs else ("", "")
                dims = [int(x) for x in lhs_shape[1].split(",") if x]
                for ci in cm.group(1).split(","):
                    if ci and int(ci) < len(dims):
                        k *= dims[int(ci)]
            elif opcode == "convolution":
                km = re.search(r"window=\{size=([0-9x]+)", core)
                if km:
                    for d in km.group(1).split("x"):
                        k *= int(d)
            cc.flops += 2.0 * res_elems * k
        elif opcode in _EW_FLOPS:
            res_elems, _ = _size_of(res_shapes)
            cc.flops += res_elems

        # ---- collectives
        base_op = opcode.replace("-start", "")
        if base_op in _COLLECTIVES and not opcode.endswith("-done"):
            _, b = _size_of(res_shapes)
            cc.coll_payload[base_op] += b

        # record slice/scatter presence (drives fusion traffic modeling)
        if opcode in ("dynamic-slice", "gather"):
            cc.has_slice = True
        if opcode in ("dynamic-update-slice", "scatter"):
            cc.has_dus = True

        # ---- HBM traffic (top-level ops of non-fused computations)
        if not cc.fused and opcode and opcode not in _FREE_OPS:
            _, rb = _size_of(res_shapes)
            per_op = [_size_of([s])[1] for s in operand_shapes]
            ob = sum(per_op)
            if opcode == "fusion":
                callee = _CALLEE_RE.search(core)
                sub = comps.get(callee.group(1)) if callee else None
                if sub is not None and (sub.has_dus or sub.has_slice) and per_op:
                    big = max(per_op)
                    if sub.has_dus:
                        # in-place update: traffic ≈ read+write of the update
                        cc.bytes += 2 * (ob - big)
                    else:
                        # slice/gather: read ≈ result, not the whole operand
                        cc.bytes += rb + (ob - big) + rb
                    continue
            if opcode in ("dynamic-slice", "gather"):
                cc.bytes += 2 * rb + (ob - max(per_op) if per_op else 0)
                continue
            if opcode in ("dynamic-update-slice", "scatter"):
                big = max(per_op) if per_op else 0
                cc.bytes += 2 * (ob - big)
                continue
            cc.bytes += rb + ob
    return comps, entry


@dataclasses.dataclass
class ProgramCost:
    flops: float
    hbm_bytes: float
    collectives: dict
    wire_bytes: float


def top_contributors(hlo: str, n: int = 15) -> list[tuple[float, str]]:
    """Byte-weighted op sources (same filters/multipliers as the rollup),
    aggregated by ``op_name`` metadata — the profiling view for §Perf."""
    comps, entry = parse(hlo)
    mults: dict[str, float] = {}

    def visit(name, m, depth=0):
        if depth > 64 or name not in comps:
            return
        mults[name] = mults.get(name, 0) + m
        for callee, cm in comps[name].calls:
            visit(callee, m * cm, depth + 1)

    visit(entry, 1)
    agg: dict[str, float] = {}
    name = None
    symbols: dict[str, list] = {}
    for raw in hlo.splitlines():
        line = raw.strip()
        if line.endswith("{") and ") -> " in line:
            name = line.split("(")[0].replace("ENTRY", "").strip().lstrip("%")
            symbols = {}
            continue
        if name is None or " = " not in line or name not in comps or comps[name].fused:
            continue
        lhs, rhs = line.split(" = ", 1)
        core = rhs.split(", metadata=")[0]
        op_m = _OPCODE_RE.search(core)
        opcode = op_m.group(1) if op_m else ""
        res_shapes = _SHAPE_RE.findall(core[: op_m.start()] if op_m else core)
        symbols[lhs.strip().lstrip("%")] = res_shapes
        if not opcode or opcode in _FREE_OPS:
            continue
        args_m = re.search(rf"{re.escape(opcode)}\(([^)]*)\)", core)
        operand_shapes = _operand_shapes(args_m.group(1), symbols) if args_m else []
        _, rb = _size_of(res_shapes)
        _, ob = _size_of(operand_shapes)
        src = re.search(r'op_name="([^"]+)"', line)
        key = (src.group(1) if src else f"<{opcode}>")[:120]
        agg[key] = agg.get(key, 0.0) + (rb + ob) * mults.get(name, 0)
    return sorted(((b, k) for k, b in agg.items()), reverse=True)[:n]


def rollup(hlo: str) -> ProgramCost:
    comps, entry = parse(hlo)
    memo: dict[str, tuple[float, float, dict]] = {}

    def visit(name: str, depth=0) -> tuple[float, float, dict]:
        if name in memo:
            return memo[name]
        cc = comps.get(name)
        if cc is None or depth > 64:
            return (0.0, 0.0, {})
        memo[name] = (0.0, 0.0, {})  # cycle guard
        fl, by = cc.flops, cc.bytes
        coll: dict = dict(cc.coll_payload)
        for callee, mult in cc.calls:
            cf, cb, ccoll = visit(callee, depth + 1)
            fl += mult * cf
            by += mult * cb
            for k, v in ccoll.items():
                coll[k] = coll.get(k, 0.0) + mult * v
        memo[name] = (fl, by, coll)
        return memo[name]

    fl, by, coll = visit(entry) if entry else (0.0, 0.0, {})
    wire = sum(2 * v if k == "all-reduce" else v for k, v in coll.items())
    return ProgramCost(flops=fl, hbm_bytes=by, collectives=coll, wire_bytes=wire)
