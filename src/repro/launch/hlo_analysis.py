"""HLO analysis: collective-byte accounting + roofline terms.

``cost_analysis()`` gives HLO FLOPs/bytes but not collective traffic, so we
parse the (post-SPMD, per-device) HLO text and sum the result-shape bytes of
every collective op, converting to wire bytes with the standard ring
accounting (all-reduce moves 2·(n-1)/n ≈ 2× its payload; gather/scatter
(n-1)/n ≈ 1×; permute exactly 1×).

Hardware constants: TPU v5e-like — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (values fixed by the assignment).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e5m2": 1, "f8e4m3fn": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-op-type payload and ring-wire bytes from HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    wire = 0
    for line in hlo_text.splitlines():
        if " = " not in line:
            continue
        lhs, rhs = line.split(" = ", 1)
        op = None
        for c in _COLLECTIVES:
            # match the opcode at the start of the rhs (e.g. "f32[..] all-reduce(")
            if re.search(rf"\b{c}(-start|-done)?\(", rhs):
                op = c
                break
        if op is None:
            continue
        if op == "all-reduce" and ("-done(" in rhs):
            continue  # avoid double counting start/done pairs
        # result shapes appear on the rhs before the opcode token
        head = rhs.split("(", 1)[0]
        size = sum(_shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(head))
        out[op] += size
        wire += 2 * size if op == "all-reduce" else size
    out["wire_bytes"] = wire
    out["payload_bytes"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float  # per-device HLO flops
    hbm_bytes: float  # per-device bytes accessed
    coll_bytes: float  # per-device collective wire bytes
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""

    def finalize(self) -> "Roofline":
        self.t_compute = self.flops / PEAK_FLOPS
        self.t_memory = self.hbm_bytes / HBM_BW
        self.t_collective = self.coll_bytes / LINK_BW
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        self.bottleneck = max(terms, key=terms.get)
        return self


def analyze(compiled, hlo_text: str) -> Roofline:
    """Loop-aware roofline terms (hlo_cost rollup — XLA's cost_analysis counts
    while bodies once, so scanned-layer models would be undercounted by L×)."""
    from repro.launch.hlo_cost import rollup

    pc = rollup(hlo_text)
    return Roofline(
        flops=pc.flops, hbm_bytes=pc.hbm_bytes, coll_bytes=pc.wire_bytes
    ).finalize()


def analyze_xla_raw(compiled) -> dict:
    """XLA's own (loop-unaware) numbers, recorded for reference."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    return {
        "xla_flops_once": float(cost.get("flops", 0.0)),
        "xla_bytes_once": float(cost.get("bytes accessed", 0.0)),
    }


def model_flops(cfg, shape_kind: str, seq: int, global_batch: int, n_chips: int) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params,
    per chip."""
    from repro.models.common import n_params
    from repro.models.registry import build_model

    n = n_params(build_model(cfg).param_specs())
    if cfg.n_experts:  # active params: replace E experts by top-k in FFN
        ffn = cfg.n_layers * 3 * cfg.d_model * cfg.d_ff
        n = n - cfg.n_experts * ffn + cfg.experts_per_token * ffn
    tokens = global_batch * (seq if shape_kind != "decode" else 1)
    mult = 6.0 if shape_kind == "train" else 2.0
    return mult * n * tokens / n_chips
