"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama_1_1b \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1

On a real TPU fleet the same entrypoint runs under `jax.distributed` with
the production mesh (launch/mesh.py); on CPU it trains the reduced config
end-to-end (this is the assignment's "train a ~100M model" driver —
example wrapper: examples/train_lm.py).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.base import get_config
from repro.data import corpus
from repro.fault.failures import FailureInjector
from repro.models.registry import build_model
from repro.sharding.rules import MeshRules
from repro.training.optim import OptConfig
from repro.training.step import TrainConfig
from repro.training.trainer import LoopConfig, Trainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", help="tiny family-preserving config (CPU)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compression", default=None, choices=[None, "int8", "topk"])
    ap.add_argument("--mesh", default=None, help="e.g. 4x2 (needs fake/real devices)")
    ap.add_argument("--inject-failure-at", type=int, default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)

    rules = None
    if args.mesh:
        from repro.launch.mesh import make_mesh_from_spec

        rules = MeshRules(make_mesh_from_spec(args.mesh))

    toks = corpus.token_stream(2_000_000, cfg.vocab_size, seed=0)

    def batches():
        gen = corpus.batches(toks, args.batch, args.seq, seed=0)
        if cfg.family == "vlm":
            P = cfg.frontend_tokens
            def wrap():
                for b in gen:
                    b["patches"] = np.zeros((args.batch, P, cfg.d_model), np.float32)
                    yield b
            return wrap()
        if cfg.family == "encdec":
            def wrap():
                for b in gen:
                    b["frames"] = np.zeros((args.batch, max(args.seq // 4, 1), cfg.d_model), np.float32)
                    yield b
            return wrap()
        return gen

    injector = (
        FailureInjector(fail_at_steps=(args.inject_failure_at,))
        if args.inject_failure_at is not None
        else None
    )
    trainer = Trainer(
        model,
        TrainConfig(
            opt=OptConfig(lr=args.lr, warmup_steps=min(20, args.steps // 10 + 1), total_steps=args.steps),
            compression=args.compression,
        ),
        LoopConfig(
            total_steps=args.steps, ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
            log_every=max(args.steps // 20, 1),
        ),
        batches,
        rules=rules,
        failure_injector=injector,
    )
    final = trainer.train()
    hist = trainer.history
    print(f"finished at step {final}; loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    for h in hist[:: max(len(hist) // 10, 1)]:
        print(f"  step {h['step']:5d} loss {h['loss']:.4f} ({h['dt']*1e3:.0f} ms)")
    return hist


if __name__ == "__main__":
    main()
