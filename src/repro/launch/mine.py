"""Production mining launcher (the paper's pipeline as a CLI).

Any registered miner is selectable; all of them speak MineSpec/MineResult:

    PYTHONPATH=src python -m repro.launch.mine --dataset kosarak --min-sup 0.01
    PYTHONPATH=src python -m repro.launch.mine --algo fpgrowth --dataset chess --min-sup 0.8
    PYTHONPATH=src python -m repro.launch.mine --corpus --vocab 1024 --min-sup 0.02

``--sweep`` runs the paper's x-axis (several thresholds over one database)
through the engine's planned path — prep stages run once at the loosest
threshold, every threshold is served from the shared PreparedDB:

    PYTHONPATH=src python -m repro.launch.mine --dataset mushroom --sweep 0.4,0.3,0.2
"""
from __future__ import annotations

import argparse

from repro.data import corpus, synth
from repro.mining import MineSpec, MiningEngine, list_miners


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="hprepost", choices=list_miners())
    ap.add_argument("--dataset", default=None, choices=[None, *synth.FIMI_SURROGATES])
    ap.add_argument("--corpus", action="store_true", help="mine token n-grams from the LM corpus")
    ap.add_argument("--vocab", type=int, default=1024)
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--min-sup", type=float, default=0.01)
    ap.add_argument(
        "--sweep", default=None, metavar="S1,S2,...",
        help="comma-separated min-sup thresholds mined as one planned sweep "
             "(shared prep at the loosest threshold); overrides --min-sup",
    )
    ap.add_argument("--max-k", type=int, default=5)
    ap.add_argument("--patterns", default="all", choices=["all", "closed", "maximal", "top_rank_k"])
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--top", type=int, default=10)
    args = ap.parse_args(argv)

    from repro.launch.mesh import make_mesh_from_spec

    if args.corpus:
        toks = corpus.token_stream(200_000, args.vocab, seed=0)
        rows = corpus.ngram_transactions(toks, window=8, stride=4)
        n_items = args.vocab
        name = "corpus-ngrams"
    else:
        rows, n_items = synth.load(args.dataset or "mushroom", scale=args.scale)
        name = args.dataset or "mushroom"

    engine = MiningEngine(make_mesh_from_spec(args.mesh))
    spec = MineSpec(
        algorithm=args.algo, min_sup=args.min_sup, max_k=args.max_k, patterns=args.patterns
    )
    if args.sweep:
        fracs = [float(s) for s in args.sweep.split(",")]
        results = engine.sweep(rows, n_items, spec, fracs)
        plan = (f"shared prep x{engine.stats['prepares']}" if engine.stats["prepares"]
                else "per-request path")
        print(f"{name}: {len(rows)} tx, sweep over min_sup={fracs} ({plan})")
        for frac, res in zip(fracs, results):
            tag = " [shared prep]" if res.prep_shared else ""
            print(f"  min_sup={frac:g} -> {res.summary()}{tag}")
        return results
    res = engine.submit(rows, n_items, spec)
    print(f"{name}: {len(rows)} tx, min_count={res.min_count} -> {res.summary()}")
    for items, sup in res.top(args.top):
        print(f"  {items}: {sup}")
    return res


if __name__ == "__main__":
    main()
