"""Production mining launcher (the paper's pipeline as a CLI).

    PYTHONPATH=src python -m repro.launch.mine --dataset kosarak --min-sup 0.01
    PYTHONPATH=src python -m repro.launch.mine --corpus --vocab 1024 --min-sup 0.02
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np
from jax.sharding import AxisType

from repro.core.hprepost import HPrepostConfig, HPrepostMiner
from repro.data import corpus, synth


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default=None, choices=[None, *synth.FIMI_SURROGATES])
    ap.add_argument("--corpus", action="store_true", help="mine token n-grams from the LM corpus")
    ap.add_argument("--vocab", type=int, default=1024)
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--min-sup", type=float, default=0.01)
    ap.add_argument("--max-k", type=int, default=5)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--top", type=int, default=10)
    args = ap.parse_args(argv)

    from repro.launch.mesh import make_mesh_from_spec

    mesh = make_mesh_from_spec(args.mesh)
    if args.corpus:
        toks = corpus.token_stream(200_000, args.vocab, seed=0)
        rows = corpus.ngram_transactions(toks, window=8, stride=4)
        n_items = args.vocab
        name = "corpus-ngrams"
    else:
        rows, n_items = synth.load(args.dataset or "mushroom", scale=args.scale)
        name = args.dataset or "mushroom"

    min_count = max(1, int(args.min_sup * len(rows)))
    miner = HPrepostMiner(
        mesh,
        data_axis=("pod", "data") if "pod" in mesh.shape else "data",
        config=HPrepostConfig(max_k=args.max_k),
    )
    t0 = time.time()
    res = miner.mine(rows, n_items, min_count)
    dt = time.time() - t0
    print(f"{name}: {len(rows)} tx, min_count={min_count} -> "
          f"{res.total_count} frequent itemsets in {dt:.2f}s")
    top = sorted(res.itemsets.items(), key=lambda kv: (-len(kv[0]), -kv[1]))[: args.top]
    for items, sup in top:
        print(f"  {items}: {sup}")
    return res


if __name__ == "__main__":
    main()
