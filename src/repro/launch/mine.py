"""Production mining launcher (the paper's pipeline as a CLI).

Any registered miner is selectable; all of them speak MineSpec/MineResult:

    PYTHONPATH=src python -m repro.launch.mine --dataset kosarak --min-sup 0.01
    PYTHONPATH=src python -m repro.launch.mine --algo fpgrowth --dataset chess --min-sup 0.8
    PYTHONPATH=src python -m repro.launch.mine --corpus --vocab 1024 --min-sup 0.02

``--sweep`` runs the paper's x-axis (several thresholds over one database)
through the engine's planned path — prep stages run once at the loosest
threshold, every threshold is served from the shared PreparedDB:

    PYTHONPATH=src python -m repro.launch.mine --dataset mushroom --sweep 0.4,0.3,0.2

``--snapshot-dir`` binds the persistent PreparedDB store: prep built in
one process is spilled to disk, and a later process on the same database
warm-starts with zero prep stages. ``--serve`` routes the request load
through the resident ``MiningService`` (concurrent submits, batching
window, cross-group overlap) instead of blocking per call; with
``--expect-warm`` the run fails unless it was served entirely from
snapshots (the serve-smoke CI check):

    PYTHONPATH=src python -m repro.launch.mine --serve --snapshot-dir /tmp/snaps \\
        --dataset mushroom --sweep 0.4,0.3,0.2

``--append N`` exercises the streaming path: the dataset is split into N
batches ingested one by one through ``engine.append`` (each batch preps
only its own segment — the map step), and the sweep is served from the
live segmented database (the reduce). With ``--snapshot-dir`` every
segment is persisted; a second run replays the append log and must
warm-start every segment, which ``--expect-warm`` enforces (the
stream-smoke CI check):

    PYTHONPATH=src python -m repro.launch.mine --append 3 --snapshot-dir /tmp/snaps \\
        --dataset mushroom --sweep 0.4,0.3 --expect-warm

``--workers W`` (with ``--append``) runs the same ingest through the
distributed coordinator/worker subsystem: W spawned worker processes own
disjoint segment sets, queries broadcast waves over RPC and sum supports
across workers. ``--kill-worker`` hard-kills a worker after the first
sweep and fails unless the re-mined sweep is bit-identical, with
re-assigned segments restored from the snapshot store only (the
dist-smoke CI check):

    PYTHONPATH=src python -m repro.launch.mine --append 3 --workers 2 \\
        --kill-worker --snapshot-dir /tmp/snaps --dataset mushroom --sweep 0.4,0.3
"""
from __future__ import annotations

import argparse
import json

from repro.data import corpus, synth
from repro.mining import MineSpec, MiningEngine, list_miners
from repro.mining.tune import registered_backends


def _report_plans(engine, expect: str | None) -> None:
    """Print the engine tuner's counters; with ``--expect-plans`` enforce
    the cold (searched this process) / warm (served entirely from
    kernel_plans.json, zero trials) contract — the tune-smoke CI check."""
    st = engine.tuner.stats
    print(
        f"tuner: trials={st['trials']} tuned={st['tuned']} "
        f"plan_hits={st['plan_hits']} loaded_plans={st['loaded_plans']}"
    )
    if expect == "cold" and (st["trials"] == 0 or st["tuned"] == 0):
        raise SystemExit(f"expected a cold tune (timed trials > 0) but tuner stats = {st}")
    if expect == "warm" and (
        st["trials"] != 0 or st["loaded_plans"] == 0 or st["plan_hits"] == 0
    ):
        raise SystemExit(
            f"expected warm plans (zero trials, served from kernel_plans.json) "
            f"but tuner stats = {st}"
        )


def _verify_obs(args, snap, emitter, rec) -> None:
    """``--expect-obs``: the obs-smoke CI check — fail unless the run
    emitted live periodic stats snapshots (not just the final one), wrote
    a loadable Chrome trace-event file, and populated the queue-wait /
    prep / mine latency histograms in the service stats snapshot."""
    if emitter is None or emitter.stats["periodic"] < 2:
        periodic = emitter.stats["periodic"] if emitter is not None else 0
        raise SystemExit(
            f"expected >=2 periodic stats snapshots during the run but the "
            f"emitter delivered {periodic} (interval={args.stats_interval}s); "
            f"emitter stats = {emitter.stats if emitter else None}"
        )
    with open(args.trace) as f:
        events = json.load(f)
    bad = [e for e in events if not ("name" in e and "ph" in e and "ts" in e)]
    if not events or bad:
        raise SystemExit(
            f"{args.trace} is not a valid Chrome trace-event list: "
            f"{len(events)} events, {len(bad)} malformed"
        )
    if rec is not None and len(rec) != len(events):
        raise SystemExit(
            f"trace file lost spans: recorder holds {len(rec)}, "
            f"file holds {len(events)}"
        )
    hists = (snap or {}).get("histograms", {})
    for key in ("admission.queue_wait_s", "engine.prep_s", "engine.mine_s",
                "service.request_s"):
        h = hists.get(key)
        if not h or h.get("count", 0) < 1 or "p95_s" not in h:
            raise SystemExit(
                f"expected a populated latency histogram {key!r} in "
                f"stats()['histograms'] but found {h!r} "
                f"(present: {sorted(hists)})"
            )
    print(
        f"observability verified: {emitter.stats['periodic']} periodic "
        f"snapshot(s), {len(events)} trace event(s), "
        f"{len(hists)} live histogram(s)"
    )


def _serve(args, rows, n_items: int, name: str, spec: MineSpec, mesh):
    """Serve the request load through a resident MiningService: the sweep
    (or the single threshold) submitted concurrently, plus one
    host-algorithm request riding the same batch on a worker thread.
    ``--stats-interval`` rides a background ``StatsEmitter`` over
    ``svc.stats`` for the whole serve; ``--trace`` attaches a
    ``TraceRecorder`` and saves the request span trees as Chrome trace
    events after the drain."""
    import contextlib

    from repro.mining.service import MiningService
    from repro.mining.telemetry import StatsEmitter, TraceRecorder, trace

    fracs = [float(s) for s in args.sweep.split(",")] if args.sweep else [args.min_sup]
    rec = TraceRecorder() if args.trace else None
    emitter = None
    snap = None
    with contextlib.ExitStack() as stack:
        svc = stack.enter_context(MiningService(
            mesh=mesh, snapshot_dir=args.snapshot_dir, batch_window_s=0.05
        ))
        if args.stats_interval:
            emitter = stack.enter_context(StatsEmitter(
                svc.stats, args.stats_out, interval_s=args.stats_interval
            ))
        if rec is not None:
            stack.enter_context(trace.attached(rec))
        futures = svc.sweep(rows, n_items, spec, fracs)
        labels = [f"min_sup={f:g}" for f in fracs]
        if spec.algorithm != "apriori":
            futures.append(svc.submit(
                rows, n_items, spec.with_(algorithm="apriori", min_sup=min(fracs))
            ))
            labels.append("apriori (host pool)")
        svc.drain()
        results = [f.result() for f in futures]
        engine = svc.engine
        print(
            f"{name}: {len(rows)} tx served as {svc.stats['batches']} batch(es), "
            f"{svc.stats['requests']} concurrent requests"
        )
        for label, res in zip(labels, results):
            s = res.service_stats
            extras = [f"queue {s.get('queue_time_s', 0) * 1e3:.1f}ms"]
            if "prep_source" in s:
                extras.append(f"prep={s['prep_source']}")
            if s.get("prep_overlapped"):
                extras.append("overlapped")
            print(f"  {label} -> {res.summary()} [{', '.join(extras)}]")
        info = engine.cache_info()
        print(
            f"engine: prepares={engine.stats['prepares']} "
            f"snapshot_hits={info['snapshot_hits']} "
            f"scheduler={svc.scheduler.stats}"
        )
        if args.expect_warm:
            # per-request attribution, not just aggregate counters:
            # stats["prepares"] counts group builds only, so a degraded
            # per-request rebuild would slip past it — any hprepost result
            # whose prep was "built" means the warm start did not hold
            built = [
                label for label, res in zip(labels, results)
                if res.algorithm == "hprepost"
                and res.service_stats.get("prep_source") not in ("snapshot", "cache")
            ]
            if (engine.stats["prepares"] != 0 or info["snapshot_hits"] < 1
                    or info["snapshot_misses"] != 0 or built):
                raise SystemExit(
                    f"expected a snapshot warm start but prepares="
                    f"{engine.stats['prepares']}, snapshot_hits={info['snapshot_hits']}, "
                    f"snapshot_misses={info['snapshot_misses']}, "
                    f"non-snapshot requests={built} "
                    f"(snapshot store: {info.get('snapshot_store')})"
                )
            print("warm start verified: zero prep stages, served from snapshots")
        if args.tune or args.expect_plans:
            _report_plans(engine, args.expect_plans)
        if args.stats or args.expect_obs:
            snap = svc.stats()
        if args.stats:
            print(json.dumps(snap, indent=2, sort_keys=True, default=str))
    if rec is not None:
        n_ev = rec.save_chrome(args.trace)
        print(f"trace: {n_ev} span event(s) -> {args.trace}")
    if emitter is not None:
        print(
            f"stats emitter: {emitter.stats['periodic']} periodic + 1 final "
            f"snapshot(s) -> {args.stats_out}, dropped={emitter.stats['dropped']}"
        )
    if args.expect_obs:
        _verify_obs(args, snap, emitter, rec)
    return results


def _append_distributed(args, rows, n_items: int, name: str, spec: MineSpec, mesh):
    """Distributed path: spawn ``--workers`` worker processes behind the
    coordinator, stream the dataset in as ``--append`` batches (each
    placed on one worker), serve the sweep with waves broadcast over RPC.
    With ``--kill-worker`` the lowest live worker is hard-killed after the
    first sweep; the re-mined sweep must answer bit-identically, and with
    a snapshot dir the re-assigned segments must restore without any
    rebuild (the dist-smoke CI check)."""
    import numpy as np

    engine = MiningEngine(mesh, snapshot_dir=args.snapshot_dir)
    dm = engine.distribute(
        n_items=n_items, workers=args.workers, spec=spec,
        restart_budget=args.respawn,
    )
    try:
        batches = np.array_split(rows, args.append)
        for i, batch in enumerate(batches):
            st = dm.append(batch)
            print(
                f"  append[{i}]: +{st['rows']} rows -> worker {st['worker']}, "
                f"{st['segments']} segment(s), prep={st['prep_source']}, "
                f"{st['append_s'] * 1e3:.1f}ms"
            )
        fracs = [float(s) for s in args.sweep.split(",")] if args.sweep else [args.min_sup]
        results = []
        for frac in fracs:
            res = engine.submit_stream(spec.with_(min_sup=frac))
            results.append(res)
            print(f"  min_sup={frac:g} -> {res.summary()} "
                  f"[{res.service_stats['stream_segments']} segments, "
                  f"{res.service_stats['workers']} workers]")
        print(
            f"{name}: {len(rows)} tx streamed as {args.append} batches "
            f"over {args.workers} workers"
        )
        if args.kill_worker:
            victim = min(w.wid for w in dm._live())
            print(f"  killing worker {victim} (hard, mid-topology) ...")
            dm.kill_worker(victim)
            for frac, before in zip(fracs, results):
                after = dm.mine(spec.with_(min_sup=frac))
                if after.itemsets != before.itemsets:
                    raise SystemExit(
                        f"post-kill sweep diverged at min_sup={frac:g}: "
                        f"{len(after.itemsets)} vs {len(before.itemsets)} itemsets"
                    )
            st = dm.stats
            print(
                f"  recovered: failovers={st['failovers']} "
                f"reassigned={st['reassigned_segments']} "
                f"snapshot_restores={st['reassign_snapshot_restores']} "
                f"rebuilds={st['reassign_rebuilds']} "
                f"respawns={st['respawns']} live={len(dm._live())}"
            )
            if args.respawn and st["respawns"] == 0:
                raise SystemExit(
                    f"--respawn {args.respawn} given but no worker was respawned"
                )
            if args.snapshot_dir and st["reassign_rebuilds"] != 0:
                raise SystemExit(
                    f"expected snapshot-only recovery but "
                    f"{st['reassign_rebuilds']} segment(s) were rebuilt"
                )
            print(
                "recovery verified: bit-identical sweep after worker death"
                + (", segments restored from snapshots only" if args.snapshot_dir else "")
            )
        if args.tune or args.expect_plans:
            _report_plans(engine, args.expect_plans)
        if args.stats:
            # the coordinator's counters plus the engine registry's
            # distribution view (per-worker wave RPC latencies included)
            tel = engine.telemetry.snapshot()
            snap = dict(dm.stats)
            snap["histograms"] = tel["histograms"]
            snap["telemetry"] = {
                "schema": tel["schema"], "counters": tel["counters"],
                "gauges": tel["gauges"],
            }
            print(json.dumps(snap, indent=2, sort_keys=True, default=str))
        return results
    finally:
        dm.close()


def _append(args, rows, n_items: int, name: str, spec: MineSpec, mesh):
    """Streaming path: split the dataset into ``--append`` batches, ingest
    them through the engine's stream, serve the sweep from the live
    SegmentedDB, and (with ``--expect-warm``) verify a replayed process
    restored every segment from the snapshot store with zero prep.

    ``--window W`` turns the stream into a sliding window over the last W
    batches (older segments expire at append time) and verifies the
    windowed answer bit-identical to a one-shot mine over exactly the
    window's rows. ``--watch`` registers a standing query up front and
    prints the ``MineDiff`` each append delivers; at the end the diff
    stream replayed from empty must equal the final answer."""
    import numpy as np

    engine = MiningEngine(mesh, snapshot_dir=args.snapshot_dir)
    sspec = None
    if args.window:
        from repro.mining.stream import StreamSpec

        sspec = StreamSpec(window_batches=args.window)
    watch = None
    if args.watch:
        engine.stream(n_items=n_items, spec=spec, stream_spec=sspec)
        watch = engine.register_standing(spec)
        print(f"  watch: standing query registered "
              f"({watch.diffs[-1].total} itemsets at register)")
    batches = np.array_split(rows, args.append)
    for i, batch in enumerate(batches):
        st = engine.append(batch, n_items, spec=spec, stream_spec=sspec)
        line = (
            f"  append[{i}]: +{st['rows']} rows -> {st['segments']} segment(s), "
            f"{st['new_items']} new item(s), prep={st['prep_source']}, "
            f"{st['append_s'] * 1e3:.1f}ms"
        )
        if args.window:
            line += f", expired={st['expired']} (-{st['expired_rows']} rows)"
        print(line)
        if watch is not None and watch.diffs[-1].cause != "register":
            d = watch.diffs[-1]
            print(f"    diff[{d.seq}] {d.cause}: +{len(d.entered)} "
                  f"-{len(d.left)} ~{len(d.changed)} -> {d.total} itemsets "
                  f"over {d.n_rows} rows ({d.latency_s * 1e3:.1f}ms)")
    fracs = [float(s) for s in args.sweep.split(",")] if args.sweep else [args.min_sup]
    results = []
    for frac in fracs:
        res = engine.submit_stream(spec.with_(min_sup=frac))
        results.append(res)
        print(f"  min_sup={frac:g} -> {res.summary()} "
              f"[{res.service_stats['stream_segments']} segments]")
    stream = engine.stream()
    s = stream.stats
    line = (
        f"{name}: {len(rows)} tx streamed as {args.append} batches; "
        f"seg_prepares={s['seg_prepares']} snapshot_hits={s['seg_snapshot_hits']} "
        f"compactions={s['compactions']}"
    )
    if args.window:
        line += f" expires={s['expires']} expired_rows={s['expired_rows']}"
    print(line)
    if args.window:
        # the windowed answer must be bit-identical to a one-shot mine over
        # exactly the window's rows (the continuous-mining anchor)
        wrows = np.concatenate(batches[-args.window:])
        ref = engine.submit(wrows, n_items, spec)
        live = engine.submit_stream(spec)
        if live.n_rows != len(wrows) or live.itemsets != ref.itemsets:
            raise SystemExit(
                f"windowed mine diverged from the one-shot over the window: "
                f"{len(live.itemsets)} itemsets over {live.n_rows} rows vs "
                f"{len(ref.itemsets)} over {len(wrows)}"
            )
        print(f"window parity verified: last {args.window} batches "
              f"({len(wrows)} rows), {len(live.itemsets)} itemsets bit-identical")
    if watch is not None:
        from repro.mining.continuous import replay_diffs

        final = engine.submit_stream(spec)
        replayed = replay_diffs(watch.diffs)
        if replayed != watch.latest or replayed != final.itemsets:
            raise SystemExit(
                f"standing diff stream does not replay to the live answer: "
                f"{len(replayed)} vs {len(final.itemsets)} itemsets"
            )
        print(f"watch verified: {len(watch.diffs)} diffs replay from empty "
              f"to the live answer ({len(replayed)} itemsets); "
              f"seed-pruned {s['seed_pruned_candidates']} candidate(s)")
    if args.expect_warm:
        # every already-seen segment must restore from its snapshot — a
        # single rebuilt segment means the warm start did not hold
        if s["seg_prepares"] != 0 or s["seg_snapshot_hits"] < args.append:
            raise SystemExit(
                f"expected a segment warm start but seg_prepares="
                f"{s['seg_prepares']}, seg_snapshot_hits={s['seg_snapshot_hits']} "
                f"(appends={args.append}, snapshot_misses={s['seg_snapshot_misses']})"
            )
        print("warm start verified: all segments restored from snapshots")
    if args.tune or args.expect_plans:
        _report_plans(engine, args.expect_plans)
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="hprepost", choices=list_miners())
    ap.add_argument("--dataset", default=None, choices=[None, *synth.FIMI_SURROGATES])
    ap.add_argument("--corpus", action="store_true", help="mine token n-grams from the LM corpus")
    ap.add_argument("--vocab", type=int, default=1024)
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--min-sup", type=float, default=0.01)
    ap.add_argument(
        "--sweep", default=None, metavar="S1,S2,...",
        help="comma-separated min-sup thresholds mined as one planned sweep "
             "(shared prep at the loosest threshold); overrides --min-sup",
    )
    ap.add_argument("--max-k", type=int, default=5)
    ap.add_argument("--patterns", default="all", choices=["all", "closed", "maximal", "top_rank_k"])
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--top", type=int, default=10)
    ap.add_argument(
        "--snapshot-dir", default=None, metavar="DIR",
        help="persistent PreparedDB store: spill prep here and warm-start "
             "from it (works with and without --serve)",
    )
    ap.add_argument(
        "--serve", action="store_true",
        help="route requests through the resident MiningService "
             "(concurrent submits, batching window, cross-group overlap)",
    )
    ap.add_argument(
        "--expect-warm", action="store_true",
        help="with --serve / --append: fail unless the whole load was served "
             "from snapshots with zero prep stages (CI warm-start check)",
    )
    ap.add_argument(
        "--append", type=int, default=0, metavar="N",
        help="streaming path: split the dataset into N batches, ingest them "
             "one by one (each preps only its own segment), and serve "
             "--sweep/--min-sup from the live segmented database",
    )
    ap.add_argument(
        "--window", type=int, default=0, metavar="W",
        help="with --append: sliding window — retain only the last W "
             "batches (older segments expire exactly at append time) and "
             "verify the windowed answer bit-identical to a one-shot mine "
             "over the window's rows",
    )
    ap.add_argument(
        "--watch", action="store_true",
        help="with --append: register a standing query before ingest, print "
             "the MineDiff each append delivers, and verify the diff stream "
             "replays from empty to the final live answer",
    )
    ap.add_argument(
        "--workers", type=int, default=0, metavar="W",
        help="with --append: distributed path — spawn W worker processes "
             "(coordinator/worker over RPC) and place segments on them",
    )
    ap.add_argument(
        "--respawn", type=int, default=0, metavar="N",
        help="with --workers: restart budget — dead workers are replaced by "
             "freshly spawned ones (segments migrate back snapshot-first) up "
             "to N times before the pool is allowed to shrink",
    )
    ap.add_argument(
        "--stats", action="store_true",
        help="after serving, dump the full operator stats snapshot as JSON "
             "(admission/shed/deadline/retry/respawn counters and per-layer "
             "drill-down; with --workers, the coordinator's stats dict)",
    )
    ap.add_argument(
        "--stats-interval", type=float, default=0.0, metavar="S",
        help="with --serve: run a background stats emitter for the whole "
             "serve, writing one JSON-lines snapshot of the full operator "
             "stats (latency histograms included) every S seconds",
    )
    ap.add_argument(
        "--stats-out", default="-", metavar="FILE",
        help="sink for --stats-interval snapshots: a file path (appended, "
             "parent dirs created) or '-' for stderr (the default)",
    )
    ap.add_argument(
        "--trace", default=None, metavar="FILE",
        help="with --serve: record per-request span trees (submit -> "
             "admission wait -> classify -> prep -> waves -> reduce -> "
             "resolve) and save them as Chrome trace events "
             "(chrome://tracing / Perfetto)",
    )
    ap.add_argument(
        "--expect-obs", action="store_true",
        help="with --serve --stats-interval --trace: fail unless >=2 "
             "periodic snapshots were emitted while serving, the trace "
             "file is a valid Chrome trace-event list, and the queue-wait "
             "/ prep / mine histograms are populated (obs-smoke CI check)",
    )
    ap.add_argument(
        "--kill-worker", action="store_true",
        help="with --workers: after the first sweep, hard-kill one worker, "
             "re-mine, and fail unless the answers are bit-identical (and, "
             "with --snapshot-dir, recovered without rebuilding a segment)",
    )
    ap.add_argument(
        "--backend", default="auto", choices=registered_backends(),
        help="kernel backend for the hprepost wave loop (auto resolves to "
             "Pallas on TPU/GPU, jnp elsewhere; pallas falls back to the "
             "interpreter off-accelerator)",
    )
    ap.add_argument(
        "--no-early-stop", action="store_true",
        help="disable early-stopping intersections (host Apriori-closure "
             "pruning + in-kernel bound masking) and run the exact legacy "
             "path bit-for-bit",
    )
    ap.add_argument(
        "--tune", action="store_true",
        help="resolve kernel block knobs through the persisted autotuner "
             "(kernel_plans.json next to --snapshot-dir) instead of the "
             "static la/ly/batch-block defaults",
    )
    ap.add_argument(
        "--expect-plans", default=None, choices=["cold", "warm"],
        help="with --tune: fail unless the tuner ran a timed search this "
             "process (cold) or served every plan from kernel_plans.json "
             "with zero trials (warm) — the tune-smoke CI check",
    )
    args = ap.parse_args(argv)
    if args.expect_plans and not args.tune:
        ap.error("--expect-plans needs --tune")
    if args.append and args.serve:
        ap.error("--append and --serve are separate paths; pick one")
    if args.workers and not args.append:
        ap.error("--workers needs --append N (the distributed ingest path)")
    if (args.window or args.watch) and not args.append:
        ap.error("--window/--watch need --append N (the streaming path)")
    if (args.window or args.watch) and args.workers:
        ap.error("--window/--watch drive the single-process stream; the "
                 "distributed window rides the coordinator's stream_spec")
    if args.kill_worker and args.workers < 2:
        ap.error("--kill-worker needs --workers >= 2 (someone must survive)")
    if args.respawn and not args.workers:
        ap.error("--respawn needs --workers (it budgets worker restarts)")
    if args.stats and not (args.serve or args.workers):
        ap.error("--stats dumps the service/coordinator snapshot; "
                 "use it with --serve or --workers")
    if (args.stats_interval or args.trace) and not args.serve:
        ap.error("--stats-interval/--trace ride the resident service; "
                 "use them with --serve")
    if args.expect_obs and not (args.serve and args.stats_interval and args.trace):
        ap.error("--expect-obs needs --serve --stats-interval S --trace FILE")

    from repro.launch.mesh import make_mesh_from_spec

    if args.corpus:
        toks = corpus.token_stream(200_000, args.vocab, seed=0)
        rows = corpus.ngram_transactions(toks, window=8, stride=4)
        n_items = args.vocab
        name = "corpus-ngrams"
    else:
        rows, n_items = synth.load(args.dataset or "mushroom", scale=args.scale)
        name = args.dataset or "mushroom"

    mesh = make_mesh_from_spec(args.mesh)
    spec = MineSpec(
        algorithm=args.algo, min_sup=args.min_sup, max_k=args.max_k,
        patterns=args.patterns, backend=args.backend,
        early_stop=not args.no_early_stop, tune=args.tune,
    )
    if args.serve:
        return _serve(args, rows, n_items, name, spec, mesh)
    if args.append:
        if args.workers:
            return _append_distributed(args, rows, n_items, name, spec, mesh)
        return _append(args, rows, n_items, name, spec, mesh)

    engine = MiningEngine(mesh, snapshot_dir=args.snapshot_dir)
    if args.sweep:
        fracs = [float(s) for s in args.sweep.split(",")]
        results = engine.sweep(rows, n_items, spec, fracs)
        plan = (f"shared prep x{engine.stats['prepares']}" if engine.stats["prepares"]
                else "per-request path")
        print(f"{name}: {len(rows)} tx, sweep over min_sup={fracs} ({plan})")
        for frac, res in zip(fracs, results):
            tag = " [shared prep]" if res.prep_shared else ""
            print(f"  min_sup={frac:g} -> {res.summary()}{tag}")
        if args.tune or args.expect_plans:
            _report_plans(engine, args.expect_plans)
        return results
    res = engine.submit(rows, n_items, spec)
    print(f"{name}: {len(rows)} tx, min_count={res.min_count} -> {res.summary()}")
    for items, sup in res.top(args.top):
        print(f"  {items}: {sup}")
    if args.tune or args.expect_plans:
        _report_plans(engine, args.expect_plans)
    return res


if __name__ == "__main__":
    main()
