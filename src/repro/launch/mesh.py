"""Production meshes.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module touches no JAX device state. Single pod: 16×16 = 256
chips (data, model). Multi-pod: 2×16×16 = 512 chips (pod, data, model) —
the ``pod`` axis composes with ``data`` for hierarchical gradient
reduction (reduce-scatter intra-pod, all-reduce across the slow axis).

Mesh construction goes through ``repro.compat`` so the ``AxisType``
surface skew between JAX versions is absorbed in one place.
"""
from __future__ import annotations

from repro.compat import make_mesh, make_mesh_from_spec  # noqa: F401  (re-export)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
