"""Production meshes.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module touches no JAX device state. Single pod: 16×16 = 256
chips (data, model). Multi-pod: 2×16×16 = 512 chips (pod, data, model) —
the ``pod`` axis composes with ``data`` for hierarchical gradient
reduction (reduce-scatter intra-pod, all-reduce across the slow axis).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_mesh_from_spec(spec: str):
    """e.g. "4x2" -> (data, model); "2x4x2" -> (pod, data, model)."""
    dims = tuple(int(x) for x in spec.split("x"))
    axes = ("pod", "data", "model")[-len(dims) :] if len(dims) == 3 else ("data", "model")
    return jax.make_mesh(dims, axes, axis_types=(AxisType.Auto,) * len(dims))
