import os
os.environ["XLA_FLAGS"] = os.environ.get("REPRO_XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell, builds allocation-free ShapeDtypeStruct stand-ins for every
input (params, optimizer state, batch, KV-cache), lowers the cell's step
function under the production mesh, compiles it, and records
``memory_analysis()`` / ``cost_analysis()`` / collective-byte roofline
terms to a per-cell JSON under ``results/dryrun/``.

Run (single cell):     python -m repro.launch.dryrun --arch tinyllama_1_1b --shape train_4k
Run (full sweep):      python -m repro.launch.dryrun --all [--multi-pod]
Mesh override (tests): REPRO_XLA_FLAGS=--xla_force_host_platform_device_count=8 \
                          python -m repro.launch.dryrun --mesh 4x2 --arch ... --shape ...

Cell skips (documented in DESIGN.md §5): long_500k runs only for the
subquadratic archs (xlstm, zamba2).
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ARCH_IDS, get_config
from repro.launch import hlo_analysis as ha
from repro.launch.mesh import make_mesh_from_spec, make_production_mesh
from repro.models.common import abstract_params, n_params
from repro.models.registry import SHAPES, applicable, batch_specs, build_model, cache_specs_for
from repro.sharding.rules import MeshRules
from repro.training.optim import moment_specs
from repro.training.step import TrainConfig, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")


def abstract_state(model, rules):
    """Abstract train state: params + ZeRO-sharded AdamW moments."""
    pspecs = model.param_specs()
    mspecs = moment_specs(pspecs, rules)
    return {
        "params": abstract_params(pspecs, rules),
        "opt": {
            "m": abstract_params(mspecs, rules),
            "v": abstract_params(mspecs, rules),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        },
        "rng": jax.ShapeDtypeStruct((2,), jnp.uint32),
    }


def cell_args(cfg, shape_name, mesh, seq=None, batch=None):
    """(fn, abstract_args) for one cell."""
    rules = MeshRules(mesh)
    model = build_model(cfg)
    kind = SHAPES[shape_name]["kind"]
    batch_abs = abstract_params(batch_specs(cfg, shape_name, seq=seq, batch=batch), rules)
    if kind == "train":
        step = make_train_step(model, TrainConfig(), rules)
        return step, (abstract_state(model, rules), batch_abs)
    params_abs = abstract_params(model.param_specs(), rules)
    cache_abs = abstract_params(cache_specs_for(cfg, shape_name, seq=seq, batch=batch), rules)
    fn = model.prefill if kind == "prefill" else model.decode
    return fn, (params_abs, batch_abs, cache_abs)


def bytes_per_device(abstract_tree, mesh) -> int:
    """Exact per-device bytes of a sharded ShapeDtypeStruct tree."""
    total = 0
    for leaf in jax.tree.leaves(abstract_tree):
        n = 1
        for d in leaf.shape:
            n *= d
        shards = 1
        spec = leaf.sharding.spec if leaf.sharding is not None else ()
        for entry in spec:
            if entry is None:
                continue
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                shards *= mesh.shape[ax]
        total += n * leaf.dtype.itemsize // shards
    return total


def run_cell(arch, shape_name, mesh, mesh_name, seq=None, batch=None, verbose=True):
    cfg = get_config(arch)
    ok, why = applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name, "skipped": why}
    t0 = time.time()
    fn, args = cell_args(cfg, shape_name, mesh, seq=seq, batch=batch)
    arg_bytes_dev = bytes_per_device(args, mesh)
    with compat.set_mesh(mesh):
        lowered = jax.jit(fn).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    roof = ha.analyze(compiled, hlo)
    s = SHAPES[shape_name]
    mf = ha.model_flops(
        cfg, s["kind"], seq or s["seq"], batch or s["global_batch"], mesh.devices.size
    )
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "n_devices": int(mesh.devices.size),
        "n_params": int(n_params(build_model(cfg).param_specs())),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops_per_device": roof.flops,
        "hbm_bytes_per_device": roof.hbm_bytes,
        "collective_wire_bytes": roof.coll_bytes,
        "t_compute": roof.t_compute,
        "t_memory": roof.t_memory,
        "t_collective": roof.t_collective,
        "bottleneck": roof.bottleneck,
        "model_flops_per_device": mf,
        "useful_flops_ratio": mf / roof.flops if roof.flops else 0.0,
        "arg_bytes_per_device": arg_bytes_dev,
        "collectives": ha.collective_bytes(hlo),
        **ha.analyze_xla_raw(compiled),
    }
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes", "temp_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                rec[f"mem_{k}"] = int(v)
    if verbose:
        print(f"[{arch} × {shape_name} × {mesh_name}] "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s | "
              f"flops/dev {roof.flops:.3g} hbm {roof.hbm_bytes:.3g} "
              f"coll {roof.coll_bytes:.3g} -> {roof.bottleneck}")
        print("  memory_analysis:", mem)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--mesh", default=None, help="override, e.g. 4x2 or 2x2x2")
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = []
    if args.mesh:
        meshes.append((args.mesh, make_mesh_from_spec(args.mesh)))
    else:
        if args.both_meshes or not args.multi_pod:
            meshes.append(("pod16x16", make_production_mesh(multi_pod=False)))
        if args.both_meshes or args.multi_pod:
            meshes.append(("2pod16x16", make_production_mesh(multi_pod=True)))

    failures = []
    for mesh_name, mesh in meshes:
        for arch in archs:
            for shape in shapes:
                tag = f"{arch}__{shape}__{mesh_name}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[cached] {tag}")
                    continue
                try:
                    rec = run_cell(arch, shape, mesh, mesh_name, seq=args.seq, batch=args.batch)
                except Exception as e:  # a failing cell is a bug: record + surface
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "error": f"{type(e).__name__}: {e}"}
                    failures.append(tag)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
    if failures:
        print("FAILED cells:", failures)
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
