import os
os.environ["XLA_FLAGS"] = os.environ.get("REPRO_XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run of the paper's own technique on the production mesh.

Lowers each HPrepost stage — Job-1 histogram+psum, Job-2 rank-encode +
sort-based PPC-tree build, F2 co-occurrence, and the k>2 mining *wave*
(batched N-list intersections, candidates over `model`, support psum over
`data`) — for a kosarak-production-scale workload, and records the same
roofline terms as the model cells. This is the cell hillclimbed as "most
representative of the paper's technique" in EXPERIMENTS.md §Perf.
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.hprepost import HPrepostConfig, HPrepostMiner
from repro.launch import hlo_analysis as ha
from repro.launch.mesh import make_mesh_from_spec, make_production_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")


def sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def run(mesh, mesh_name, *, R=1_048_576, L=48, n_items=41_270, K=2048, W=512, C=8192,
        out_dir=RESULTS_DIR):
    """Workload: kosarak-scale DB (1M × 48), |F1| = 2048, N-list width 512,
    8192 candidates per wave — a heavy mining level at production scale."""
    miner = HPrepostMiner(mesh, data_axis=("pod", "data") if "pod" in mesh.shape else "data")
    da = miner._da
    cand = miner._cand_spec
    D = miner.D
    R = max(R // D, 1) * D
    C = max(C // (256 * miner.M), 1) * 256 * miner.M
    results = {}

    def cell(name, jitted, *args, **static):
        t0 = time.time()
        lowered = jitted.lower(*args, **static)
        compiled = lowered.compile()
        roof = ha.analyze(compiled, compiled.as_text())
        results[name] = {
            "arch": f"hprepost_{name}", "shape": "fim_wave", "mesh": mesh_name,
            "n_devices": int(mesh.devices.size),
            "compile_s": round(time.time() - t0, 1),
            "flops_per_device": roof.flops,
            "hbm_bytes_per_device": roof.hbm_bytes,
            "collective_wire_bytes": roof.coll_bytes,
            "t_compute": roof.t_compute, "t_memory": roof.t_memory,
            "t_collective": roof.t_collective, "bottleneck": roof.bottleneck,
        }
        print(f"[fim {name} × {mesh_name}] compile {results[name]['compile_s']}s "
              f"-> {roof.bottleneck} (c {roof.t_compute:.2e} m {roof.t_memory:.2e} "
              f"x {roof.t_collective:.2e})")

    rows = sds((R, L), jnp.int32, mesh, P(da, None))
    cell("job1", miner._job1, rows, n_items=n_items)

    lut = sds((n_items + 1,), jnp.int32, mesh, P())
    max_nodes = (R // D) * L
    cell("job2_tree", miner._job2, rows, lut, max_nodes=max_nodes, k=K, n_items=n_items)

    ranked = sds((D, R // D, L), jnp.int32, mesh, P(da, None, None))
    cell("f2", miner._jobf2, ranked, k=K)

    packed = sds((D, K, W, 3), jnp.int32, mesh, P(da, None, None, None))
    idx = sds((C,), jnp.int32, mesh, cand)
    # paper-faithful wave: model-sharded parent state + cross-shard shuffle
    prev_sharded = sds((D, C, W), jnp.int32, mesh, P(da, *cand, None))
    cell("wave_shuffle", miner._wave, packed, prev_sharded, idx, idx, idx)
    # beyond-paper: locality-aware dispatch (parents shard-local)
    cell("wave_local", miner._wave_local, packed, prev_sharded, idx, idx, idx)

    os.makedirs(out_dir, exist_ok=True)
    for name, rec in results.items():
        with open(os.path.join(out_dir, f"fim_{name}__{mesh_name}.json"), "w") as f:
            json.dump(rec, f, indent=1)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--scale", type=float, default=1.0)
    args = ap.parse_args()
    if args.mesh:
        mesh, name = make_mesh_from_spec(args.mesh), args.mesh
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        name = "2pod16x16" if args.multi_pod else "pod16x16"
    s = args.scale
    run(mesh, name, R=int(1_048_576 * s), C=int(8192 * s) or 256, out_dir=args.out)


if __name__ == "__main__":
    main()
