"""Version shims over the moving parts of the JAX sharding API.

The codebase targets the modern surface (``jax.shard_map``,
``jax.sharding.AxisType``, ``jax.sharding.get_abstract_mesh``,
``jax.set_mesh``); older installs (<= 0.4.x) ship the same machinery under
``jax.experimental.shard_map`` and plain ``jax.make_mesh`` without
``axis_types``. Everything mesh- or shard_map-shaped in this repo goes
through these helpers so a single module absorbs the skew.
"""
from __future__ import annotations

import contextlib

import jax

try:  # jax >= 0.5: explicit/auto axis types
    from jax.sharding import AxisType as _AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    _AxisType = None

try:
    from jax.experimental.shard_map import shard_map as _exp_shard_map
except ImportError:  # pragma: no cover
    _exp_shard_map = None


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types when the install supports them."""
    if _AxisType is not None:
        return jax.make_mesh(
            axis_shapes, axis_names, axis_types=(_AxisType.Auto,) * len(axis_names)
        )
    return jax.make_mesh(axis_shapes, axis_names)


def make_mesh_from_spec(spec: str):
    """e.g. "4x2" -> (data, model); "2x4x2" -> (pod, data, model)."""
    dims = tuple(int(x) for x in spec.split("x"))
    axes = ("pod", "data", "model")[-len(dims) :] if len(dims) == 3 else ("data", "model")
    return make_mesh(dims, axes)


def _ambient_physical_mesh():
    env = jax.interpreters.pxla.thread_resources.env
    return env.physical_mesh


def shard_map(f, *, mesh=None, in_specs, out_specs):
    """``jax.shard_map`` when present, else the experimental one.

    ``mesh=None`` binds the ambient mesh (``with mesh:`` / ``jax.set_mesh``)
    on installs whose shard_map cannot infer it.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        if mesh is None:
            return sm(f, in_specs=in_specs, out_specs=out_specs)
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if _exp_shard_map is None:  # pragma: no cover
        raise ImportError("no shard_map implementation in this jax install")
    if mesh is None:
        mesh = _ambient_physical_mesh()
        if mesh.empty:
            raise ValueError("shard_map with mesh=None needs an ambient mesh")
    # check_rep off: the older replication checker rejects valid programs
    # (scatter with mode="drop") that the modern one accepts.
    return _exp_shard_map(f, mesh, in_specs, out_specs, check_rep=False)


def pcast(x, axes, *, to):
    """``jax.lax.pcast`` where it exists. Older shard_map (run with
    ``check_rep=False``) does not track varying-ness, so the cast is an
    identity there."""
    fn = getattr(jax.lax, "pcast", None)
    if fn is not None:
        return fn(x, axes, to=to)
    return x


def get_abstract_mesh():
    """Ambient mesh, or None when no mesh context is active."""
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        return getter()
    mesh = _ambient_physical_mesh()
    return None if mesh.empty else mesh


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh."""
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    return _use_physical_mesh(mesh)


@contextlib.contextmanager
def _use_physical_mesh(mesh):
    with mesh:
        yield mesh
