"""Pure-jnp oracle: batched searchsorted-based N-list intersection."""
import jax.numpy as jnp

from repro.core.nlist import batched_intersect_jnp


def nlist_intersect_ref(a_pre, a_post, y_pre, y_post, y_cnt) -> jnp.ndarray:
    return batched_intersect_jnp(a_pre, a_post, y_pre, y_post, y_cnt).astype(jnp.int32)
