"""Pure-jnp oracle: batched searchsorted-based N-list intersection, plus the
same fused ``(merged, supports)`` surface the Pallas kernel exposes."""
import jax.numpy as jnp

from repro.core.nlist import batched_intersect_jnp


def nlist_intersect_ref(a_pre, a_post, y_pre, y_post, y_cnt) -> jnp.ndarray:
    """Merged counts (B, La) only — the historical single-output oracle the
    parity tests diff the fused kernel against."""
    return batched_intersect_jnp(a_pre, a_post, y_pre, y_post, y_cnt).astype(jnp.int32)


def nlist_intersect_fused_ref(
    a_pre, a_post, y_pre, y_post, y_cnt
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(merged, supports): the op-level contract. Exact integer math — the
    fp32 < 2^24 bound only constrains the Pallas path."""
    merged = nlist_intersect_ref(a_pre, a_post, y_pre, y_post, y_cnt)
    return merged, merged.sum(axis=1).astype(jnp.int32)
