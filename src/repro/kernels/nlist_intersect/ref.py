"""Pure-jnp oracle: batched searchsorted-based N-list intersection, plus the
same fused ``(merged, supports)`` surface the Pallas kernel exposes, and a
tile-order model of the early-stop kernel's masked semantics."""
import numpy as np

import jax.numpy as jnp

from repro.core.nlist import batched_intersect_jnp


def nlist_intersect_ref(a_pre, a_post, y_pre, y_post, y_cnt) -> jnp.ndarray:
    """Merged counts (B, La) only — the historical single-output oracle the
    parity tests diff the fused kernel against."""
    return batched_intersect_jnp(a_pre, a_post, y_pre, y_post, y_cnt).astype(jnp.int32)


def nlist_intersect_fused_ref(
    a_pre, a_post, y_pre, y_post, y_cnt
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(merged, supports): the op-level contract. Exact integer math — the
    fp32 < 2^24 bound only constrains the Pallas path."""
    merged = nlist_intersect_ref(a_pre, a_post, y_pre, y_post, y_cnt)
    return merged, merged.sum(axis=1).astype(jnp.int32)


def nlist_intersect_masked_ref(
    a_pre, a_post, a_cnt, y_pre, y_post, y_cnt, min_count, *, la_block=512
):
    """Models ``nlist_intersect_pallas_es`` exactly: scan A-row tiles of
    ``la_block`` slots in order; before each tile, a candidate is alive iff
    support-so-far plus the inclusive A-count suffix mass of the remaining
    tiles can still reach ``min_count``; dead candidates' tiles are zeroed
    and their support frozen. Per-candidate, so ``ly_block``/``batch_block``
    never enter the semantics. ``min_count <= 0`` reproduces the exact path.
    """
    exact = np.asarray(nlist_intersect_ref(a_pre, a_post, y_pre, y_post, y_cnt))
    a_cnt = np.asarray(a_cnt)
    B, La = exact.shape
    lab = min(la_block, La)
    nt = (La + lab - 1) // lab
    mass = np.zeros((B, nt), np.float64)
    for i in range(nt):
        mass[:, i] = a_cnt[:, i * lab : (i + 1) * lab].sum(axis=1)
    rem = np.cumsum(mass[:, ::-1], axis=1)[:, ::-1]  # inclusive suffix
    merged = np.zeros_like(exact)
    sup = np.zeros(B, np.int64)
    for i in range(nt):
        alive = (sup + rem[:, i]) >= min_count
        tile = exact[:, i * lab : (i + 1) * lab] * alive[:, None]
        merged[:, i * lab : (i + 1) * lab] = tile
        sup += tile.sum(axis=1)
    return jnp.asarray(merged, jnp.int32), jnp.asarray(sup, jnp.int32)
