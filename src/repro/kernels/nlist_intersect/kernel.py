"""Pallas TPU kernel: batched N-list intersection (the paper's Example 2).

For a batch of candidate itemsets, merges the candidate's N-list ``Y``
(codes of its base item with current counts) into the extension item's
N-list ``A``: ``out[b, i] = Σ_j y_cnt[b, j] · [a_pre[b, i] < y_pre[b, j]]
· [a_post[b, i] > y_post[b, j]]``.

Hardware adaptation (GPU/CPU -> TPU): the paper's linear merge — and even
the searchsorted form used on host — is a gather/branch pattern TPUs
execute poorly. Because each ``y`` has at most one ancestor in ``A``
(antichain property, see nlist.py), the merge is *equivalent* to a dense
subsume-mask contraction, which is a matmul: build the ``(La, Ly)`` boolean
mask in VMEM with two broadcast compares and contract against ``y_cnt`` on
the MXU. O(La·Ly) arithmetic beats O(Ly·log La) gathers on a systolic
array by a wide margin at N-list sizes (≤ few thousand codes).

Grid: (batch, La_blocks, Ly_blocks); the (b, La) output tile accumulates
over Ly blocks. Counts are fp32 in-kernel (exact below 2^24 — itemset
supports are bounded by the shard's row count, far below that).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _intersect_kernel(a_pre_ref, a_post_ref, y_pre_ref, y_post_ref, y_cnt_ref, out_ref):
    lyb = pl.program_id(2)

    @pl.when(lyb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a_pre = a_pre_ref[...]  # (1, la)
    a_post = a_post_ref[...]  # (1, la)
    y_pre = y_pre_ref[...]  # (1, ly)
    y_post = y_post_ref[...]  # (1, ly)
    y_cnt = y_cnt_ref[...].astype(jnp.float32)  # (1, ly)

    # subsume mask (la, ly): A[i] is an ancestor of Y[j]
    mask = (a_pre[0, :, None] < y_pre[0, None, :]) & (a_post[0, :, None] > y_post[0, None, :])
    out_ref[...] += jax.lax.dot_general(
        mask.astype(jnp.float32),
        y_cnt[0, :, None],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[None, :, 0]


@functools.partial(jax.jit, static_argnames=("la_block", "ly_block", "interpret"))
def nlist_intersect_pallas(
    a_pre: jnp.ndarray,
    a_post: jnp.ndarray,
    y_pre: jnp.ndarray,
    y_post: jnp.ndarray,
    y_cnt: jnp.ndarray,
    *,
    la_block: int = 512,
    ly_block: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """All inputs (B, La) / (B, Ly) int32; returns merged counts (B, La) int32.

    Padding convention (nlist.pad_nlist): pre = INT32_MAX, post = -1, cnt = 0.
    Padded A slots never pass ``a_pre < y_pre``; padded Y slots carry zero
    count — no extra masks needed.
    """
    B, La = a_pre.shape
    _, Ly = y_pre.shape
    lab = min(la_block, La)
    lyb = min(ly_block, Ly)
    Lap = (La + lab - 1) // lab * lab
    Lyp = (Ly + lyb - 1) // lyb * lyb
    pad_a = ((0, 0), (0, Lap - La))
    pad_y = ((0, 0), (0, Lyp - Ly))
    a_pre = jnp.pad(a_pre, pad_a, constant_values=jnp.iinfo(jnp.int32).max)
    a_post = jnp.pad(a_post, pad_a, constant_values=-1)
    y_pre = jnp.pad(y_pre, pad_y, constant_values=jnp.iinfo(jnp.int32).max)
    y_post = jnp.pad(y_post, pad_y, constant_values=-1)
    y_cnt = jnp.pad(y_cnt, pad_y)

    out = pl.pallas_call(
        _intersect_kernel,
        grid=(B, Lap // lab, Lyp // lyb),
        in_specs=[
            pl.BlockSpec((1, lab), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, lab), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, lyb), lambda b, i, j: (b, j)),
            pl.BlockSpec((1, lyb), lambda b, i, j: (b, j)),
            pl.BlockSpec((1, lyb), lambda b, i, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, lab), lambda b, i, j: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, Lap), jnp.float32),
        interpret=interpret,
    )(a_pre, a_post, y_pre, y_post, y_cnt)
    return out[:, :La].astype(jnp.int32)
