"""Pallas TPU kernel: batched N-list intersection fused with support
reduction (the paper's Example 2 + the support count that follows it).

For a batch of candidate itemsets, merges the candidate's N-list ``Y``
(codes of its base item with current counts) into the extension item's
N-list ``A``: ``out[b, i] = Σ_j y_cnt[b, j] · [a_pre[b, i] < y_pre[b, j]]
· [a_post[b, i] > y_post[b, j]]``, and — fused in the same pass —
``support[b] = Σ_i out[b, i]``. Producing the support inside the kernel
removes the second full HBM read of the merged state that a post-kernel
``sum(axis=1)`` costs per mining wave.

Hardware adaptation (GPU/CPU -> TPU): the paper's linear merge — and even
the searchsorted form used on host — is a gather/branch pattern TPUs
execute poorly. Because each ``y`` has at most one ancestor in ``A``
(antichain property, see nlist.py), the merge is *equivalent* to a dense
subsume-mask contraction, which is a matmul: build the boolean mask in
VMEM with two broadcast compares and contract against ``y_cnt`` on the
MXU. O(La·Ly) arithmetic beats O(Ly·log La) gathers on a systolic array
by a wide margin at N-list sizes (≤ few thousand codes).

Fused-output tiling: the grid is (B/bb, La/la, Ly/ly), Ly-major (the last
grid axis iterates fastest), with ``bb`` candidates per program. Each
program builds the (bb, la, ly) subsume mask and issues one *stacked*
MXU contraction — (bb·la, ly) × (ly, bb) — instead of ``bb`` separate
(la, ly) × (ly, 1) matvecs; the candidate-diagonal block of the result is
the (bb, la) merged-count tile. The merged tile accumulates across the Ly
grid axis (revisited output block, consecutive in traversal order); the
(bb, 1) support tile additionally accumulates across the La axis, so both
outputs leave one ``pallas_call``.

Counts are fp32 in-kernel: exact for values < 2^24. Itemset supports are
bounded by the per-shard row count, which ``HPrepostMiner.prepare``
guards against that bound before any wave is dispatched.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _intersect_kernel(
    a_pre_ref, a_post_ref, y_pre_ref, y_post_ref, y_cnt_ref, out_ref, sup_ref
):
    lab_i = pl.program_id(1)
    lyb_j = pl.program_id(2)

    @pl.when(lyb_j == 0)
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when((lab_i == 0) & (lyb_j == 0))
    def _init_sup():
        sup_ref[...] = jnp.zeros_like(sup_ref)

    a_pre = a_pre_ref[...]  # (bb, la)
    a_post = a_post_ref[...]  # (bb, la)
    y_pre = y_pre_ref[...]  # (bb, ly)
    y_post = y_post_ref[...]  # (bb, ly)
    y_cnt = y_cnt_ref[...].astype(jnp.float32)  # (bb, ly)
    bb, la = a_pre.shape
    ly = y_pre.shape[1]

    # subsume mask (bb, la, ly): A[b, i] is an ancestor of Y[b, j]
    mask = (a_pre[:, :, None] < y_pre[:, None, :]) & (
        a_post[:, :, None] > y_post[:, None, :]
    )
    # stacked contraction (bb·la, ly) × (ly, bb): one MXU matmul per program;
    # r[b, i, c] = Σ_j mask[b, i, j] · y_cnt[c, j] — only the candidate
    # diagonal c == b is wanted, and with bb ≤ the MXU's 128 output columns
    # the cross terms ride along for free where a matvec would idle them.
    r = jax.lax.dot_general(
        mask.astype(jnp.float32).reshape(bb * la, ly),
        y_cnt,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(bb, la, bb)
    eye = (
        jax.lax.broadcasted_iota(jnp.int32, (bb, bb), 0)
        == jax.lax.broadcasted_iota(jnp.int32, (bb, bb), 1)
    ).astype(jnp.float32)
    part = jnp.sum(r * eye[:, None, :], axis=2)  # (bb, la)
    out_ref[...] += part
    sup_ref[...] += part.sum(axis=1, keepdims=True)


def _intersect_es_kernel(
    stop_ref, rem_ref, a_pre_ref, a_post_ref, y_pre_ref, y_post_ref, y_cnt_ref,
    out_ref, sup_ref,
):
    """Early-stopping variant (arXiv:1901.07773 brought on-grid): each
    program re-derives per-candidate liveness from the accumulating support
    and the inclusive A-count suffix mass of the remaining row tiles, and
    masks dead candidates out of every later tile.

    The bound is anti-monotone over the grid's Ly-major traversal: a dead
    candidate's contributions are zeroed, which freezes its support, while
    ``rem`` only shrinks with the tile index — so the liveness predicate is
    stable within a tile and monotone across tiles, and no scratch state is
    needed. With ``stop <= 0`` every candidate stays alive and the
    arithmetic (a multiply by 1.0) matches the exact kernel bit-for-bit.

    Soundness of the bound: Y-nodes below one A-slot form an antichain in
    that slot's subtree (same-item PP codes), so a tile's merged mass never
    exceeds its A-count mass — support-so-far plus remaining A-mass is a
    true upper bound on the final support.
    """
    lab_i = pl.program_id(1)
    lyb_j = pl.program_id(2)

    @pl.when(lyb_j == 0)
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when((lab_i == 0) & (lyb_j == 0))
    def _init_sup():
        sup_ref[...] = jnp.zeros_like(sup_ref)

    # (bb, 1): final support <= support so far + A-count mass of tiles i..
    alive = (sup_ref[...] + rem_ref[...]) >= stop_ref[0, 0]

    @pl.when(jnp.any(alive))
    def _compute():
        a_pre = a_pre_ref[...]  # (bb, la)
        a_post = a_post_ref[...]
        y_pre = y_pre_ref[...]  # (bb, ly)
        y_post = y_post_ref[...]
        y_cnt = y_cnt_ref[...].astype(jnp.float32)
        bb, la = a_pre.shape
        ly = y_pre.shape[1]
        mask = (a_pre[:, :, None] < y_pre[:, None, :]) & (
            a_post[:, :, None] > y_post[:, None, :]
        )
        r = jax.lax.dot_general(
            mask.astype(jnp.float32).reshape(bb * la, ly),
            y_cnt,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(bb, la, bb)
        eye = (
            jax.lax.broadcasted_iota(jnp.int32, (bb, bb), 0)
            == jax.lax.broadcasted_iota(jnp.int32, (bb, bb), 1)
        ).astype(jnp.float32)
        part = jnp.sum(r * eye[:, None, :], axis=2)  # (bb, la)
        part = part * alive.astype(jnp.float32)  # dead lanes contribute 0
        out_ref[...] += part
        sup_ref[...] += part.sum(axis=1, keepdims=True)


@functools.partial(
    jax.jit, static_argnames=("la_block", "ly_block", "batch_block", "interpret")
)
def nlist_intersect_pallas_es(
    a_pre: jnp.ndarray,
    a_post: jnp.ndarray,
    a_cnt: jnp.ndarray,
    y_pre: jnp.ndarray,
    y_post: jnp.ndarray,
    y_cnt: jnp.ndarray,
    min_count,
    *,
    la_block: int = 512,
    ly_block: int = 512,
    batch_block: int = 8,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Masked early-stop launch: same contract as ``nlist_intersect_pallas``
    plus ``a_cnt`` (A's original node counts, for the bound masses) and a
    dynamic ``min_count`` threshold. Candidates whose final support reaches
    ``min_count`` return exactly the exact kernel's values; provably-doomed
    candidates may return partial merged rows (exact through the tile where
    they died, zero after) and a frozen partial support — always strictly
    below ``min_count``, so thresholding downstream is unaffected.
    ``min_count <= 0`` disables masking and is bit-identical to the exact
    kernel. ``ref.nlist_intersect_masked_ref`` models these semantics."""
    B, La = a_pre.shape
    _, Ly = y_pre.shape
    bb = max(1, min(batch_block, B))
    lab = min(la_block, La)
    lyb = min(ly_block, Ly)
    Bp = (B + bb - 1) // bb * bb
    Lap = (La + lab - 1) // lab * lab
    Lyp = (Ly + lyb - 1) // lyb * lyb
    pad_a = ((0, Bp - B), (0, Lap - La))
    pad_y = ((0, Bp - B), (0, Lyp - Ly))
    a_pre = jnp.pad(a_pre, pad_a, constant_values=jnp.iinfo(jnp.int32).max)
    a_post = jnp.pad(a_post, pad_a, constant_values=-1)
    a_cnt = jnp.pad(a_cnt, pad_a)  # PAD slots carry zero mass
    y_pre = jnp.pad(y_pre, pad_y, constant_values=jnp.iinfo(jnp.int32).max)
    y_post = jnp.pad(y_post, pad_y, constant_values=-1)
    y_cnt = jnp.pad(y_cnt, pad_y)

    nt = Lap // lab
    mass = a_cnt.astype(jnp.float32).reshape(Bp, nt, lab).sum(axis=2)
    rem = jnp.cumsum(mass[:, ::-1], axis=1)[:, ::-1]  # inclusive suffix (Bp, nt)
    stop = jnp.full((1, 1), min_count, jnp.float32)

    out, sup = pl.pallas_call(
        _intersect_es_kernel,
        grid=(Bp // bb, Lap // lab, Lyp // lyb),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, i, j: (0, 0)),
            pl.BlockSpec((bb, 1), lambda b, i, j: (b, i)),
            pl.BlockSpec((bb, lab), lambda b, i, j: (b, i)),
            pl.BlockSpec((bb, lab), lambda b, i, j: (b, i)),
            pl.BlockSpec((bb, lyb), lambda b, i, j: (b, j)),
            pl.BlockSpec((bb, lyb), lambda b, i, j: (b, j)),
            pl.BlockSpec((bb, lyb), lambda b, i, j: (b, j)),
        ],
        out_specs=[
            pl.BlockSpec((bb, lab), lambda b, i, j: (b, i)),
            pl.BlockSpec((bb, 1), lambda b, i, j: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, Lap), jnp.float32),
            jax.ShapeDtypeStruct((Bp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(stop, rem, a_pre, a_post, y_pre, y_post, y_cnt)
    return out[:B, :La].astype(jnp.int32), sup[:B, 0].astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("la_block", "ly_block", "batch_block", "interpret")
)
def nlist_intersect_pallas(
    a_pre: jnp.ndarray,
    a_post: jnp.ndarray,
    y_pre: jnp.ndarray,
    y_post: jnp.ndarray,
    y_cnt: jnp.ndarray,
    *,
    la_block: int = 512,
    ly_block: int = 512,
    batch_block: int = 8,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """All inputs (B, La) / (B, Ly) int32; returns ``(merged, supports)``:
    merged counts (B, La) int32 plus their row sums (B,) int32, both from
    the one fused ``pallas_call``.

    Padding convention (nlist.pad_nlist): pre = INT32_MAX, post = -1,
    cnt = 0. Padded A slots never pass ``a_pre < y_pre``; padded Y slots
    carry zero count — no extra masks needed, and the same sentinels pad
    the batch axis up to a ``batch_block`` multiple.

    Accumulation is fp32 (exact < 2^24): callers must keep every possible
    count — bounded by the shard's transaction count — below that.
    """
    B, La = a_pre.shape
    _, Ly = y_pre.shape
    bb = max(1, min(batch_block, B))
    lab = min(la_block, La)
    lyb = min(ly_block, Ly)
    Bp = (B + bb - 1) // bb * bb
    Lap = (La + lab - 1) // lab * lab
    Lyp = (Ly + lyb - 1) // lyb * lyb
    pad_a = ((0, Bp - B), (0, Lap - La))
    pad_y = ((0, Bp - B), (0, Lyp - Ly))
    a_pre = jnp.pad(a_pre, pad_a, constant_values=jnp.iinfo(jnp.int32).max)
    a_post = jnp.pad(a_post, pad_a, constant_values=-1)
    y_pre = jnp.pad(y_pre, pad_y, constant_values=jnp.iinfo(jnp.int32).max)
    y_post = jnp.pad(y_post, pad_y, constant_values=-1)
    y_cnt = jnp.pad(y_cnt, pad_y)

    out, sup = pl.pallas_call(
        _intersect_kernel,
        grid=(Bp // bb, Lap // lab, Lyp // lyb),
        in_specs=[
            pl.BlockSpec((bb, lab), lambda b, i, j: (b, i)),
            pl.BlockSpec((bb, lab), lambda b, i, j: (b, i)),
            pl.BlockSpec((bb, lyb), lambda b, i, j: (b, j)),
            pl.BlockSpec((bb, lyb), lambda b, i, j: (b, j)),
            pl.BlockSpec((bb, lyb), lambda b, i, j: (b, j)),
        ],
        out_specs=[
            pl.BlockSpec((bb, lab), lambda b, i, j: (b, i)),
            pl.BlockSpec((bb, 1), lambda b, i, j: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, Lap), jnp.float32),
            jax.ShapeDtypeStruct((Bp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(a_pre, a_post, y_pre, y_post, y_cnt)
    return out[:B, :La].astype(jnp.int32), sup[:B, 0].astype(jnp.int32)
