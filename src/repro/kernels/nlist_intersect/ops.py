"""Public op: nlist_intersect — Pallas (mask-matmul, fused support) on TPU,
searchsorted jnp elsewhere. Both return ``(merged, supports)``: merged counts
aligned with A's code slots plus their per-candidate row sums, so the mining
waves never re-read the merged state just to reduce it.

fp32 exactness bound: the Pallas path accumulates counts in fp32, which is
exact only below 2^24. Every count the kernel can produce is bounded by the
shard's transaction count, so callers must keep per-shard row counts below
2^24 (``HPrepostMiner.prepare`` raises before dispatching otherwise); the
jnp path is integer-exact and has no such bound.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.nlist_intersect.kernel import nlist_intersect_pallas
from repro.kernels.nlist_intersect.ref import nlist_intersect_fused_ref

# values >= 2^24 are not exactly representable in fp32: the Pallas kernel
# must never see a possible count at or above this
FP32_EXACT_MAX = 1 << 24


def nlist_intersect(
    a_pre: jnp.ndarray,
    a_post: jnp.ndarray,
    y_pre: jnp.ndarray,
    y_post: jnp.ndarray,
    y_cnt: jnp.ndarray,
    *,
    backend: str = "auto",
    la_block: int = 512,
    ly_block: int = 512,
    batch_block: int = 8,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    use_pallas = backend == "pallas" or (
        backend == "auto" and jax.default_backend() == "tpu"
    )
    if use_pallas:
        return nlist_intersect_pallas(
            a_pre, a_post, y_pre, y_post, y_cnt,
            la_block=la_block, ly_block=ly_block, batch_block=batch_block,
            interpret=interpret,
        )
    return nlist_intersect_fused_ref(a_pre, a_post, y_pre, y_post, y_cnt)
