"""Public op: nlist_intersect — Pallas (mask-matmul) on TPU, searchsorted jnp
elsewhere. Both return merged counts aligned with A's code slots."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.nlist_intersect.kernel import nlist_intersect_pallas
from repro.kernels.nlist_intersect.ref import nlist_intersect_ref


def nlist_intersect(
    a_pre: jnp.ndarray,
    a_post: jnp.ndarray,
    y_pre: jnp.ndarray,
    y_post: jnp.ndarray,
    y_cnt: jnp.ndarray,
    *,
    backend: str = "auto",
    interpret: bool = False,
) -> jnp.ndarray:
    use_pallas = backend == "pallas" or (
        backend == "auto" and jax.default_backend() == "tpu"
    )
    if use_pallas:
        return nlist_intersect_pallas(
            a_pre, a_post, y_pre, y_post, y_cnt, interpret=interpret
        )
    return nlist_intersect_ref(a_pre, a_post, y_pre, y_post, y_cnt)
