"""Public op: nlist_intersect — real backend dispatch over the registry in
``repro.mining.tune``. Pallas (mask-matmul, fused support, optionally masked
early-stop) on TPU/GPU or under the interpreter, searchsorted jnp elsewhere.
Both return ``(merged, supports)``: merged counts aligned with A's code slots
plus their per-candidate row sums, so the mining waves never re-read the
merged state just to reduce it.

Early stopping: with ``early_stop=True`` (plus ``a_cnt`` and a ``min_count``
threshold) the Pallas path runs the masked kernel, which abandons candidates
whose support upper bound falls below ``min_count`` mid-scan. The jnp path is
always exact — exact supports are a superset of the masked ones above the
threshold, so downstream thresholding is identical either way. Callers are
responsible for only enabling the in-kernel stop when the supports it sees
are final (single data shard, non-segmented); pass ``min_count <= 0`` to
disable masking without retracing.

fp32 exactness bound: the Pallas path accumulates counts in fp32, which is
exact only below 2^24. Every count the kernel can produce is bounded by the
shard's transaction count, so callers must keep per-shard row counts below
2^24 (``HPrepostMiner.prepare`` raises before dispatching otherwise); the
jnp path is integer-exact and has no such bound.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.nlist_intersect.kernel import (
    nlist_intersect_pallas,
    nlist_intersect_pallas_es,
)
from repro.kernels.nlist_intersect.ref import nlist_intersect_fused_ref

# values >= 2^24 are not exactly representable in fp32: the Pallas kernel
# must never see a possible count at or above this
FP32_EXACT_MAX = 1 << 24


def _resolve(backend: str) -> str:
    # repro.mining.tune owns the registry; imported lazily because the
    # mining package sits above the kernel packages in the layer diagram
    from repro.mining.tune import resolve_backend

    return resolve_backend(backend)


def nlist_intersect(
    a_pre: jnp.ndarray,
    a_post: jnp.ndarray,
    y_pre: jnp.ndarray,
    y_post: jnp.ndarray,
    y_cnt: jnp.ndarray,
    *,
    a_cnt: jnp.ndarray | None = None,
    backend: str = "auto",
    la_block: int = 512,
    ly_block: int = 512,
    batch_block: int = 8,
    interpret: bool = False,
    early_stop: bool = False,
    min_count=None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    resolved = _resolve(backend)
    if resolved.startswith("pallas"):
        interpret = interpret or resolved == "pallas-interpret"
        if early_stop and a_cnt is not None and min_count is not None:
            return nlist_intersect_pallas_es(
                a_pre, a_post, a_cnt, y_pre, y_post, y_cnt, min_count,
                la_block=la_block, ly_block=ly_block, batch_block=batch_block,
                interpret=interpret,
            )
        return nlist_intersect_pallas(
            a_pre, a_post, y_pre, y_post, y_cnt,
            la_block=la_block, ly_block=ly_block, batch_block=batch_block,
            interpret=interpret,
        )
    return nlist_intersect_fused_ref(a_pre, a_post, y_pre, y_post, y_cnt)
