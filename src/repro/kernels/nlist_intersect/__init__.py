from repro.kernels.nlist_intersect.ops import nlist_intersect

__all__ = ["nlist_intersect"]
