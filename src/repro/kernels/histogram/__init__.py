from repro.kernels.histogram.ops import item_histogram

__all__ = ["item_histogram"]
