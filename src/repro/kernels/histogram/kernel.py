"""Pallas TPU kernel: item-frequency histogram (the paper's Job-1 map).

Counts how many transactions contain each item over a block of rank/item-
encoded transactions ``(R, L)`` with PAD = -1. TPU adaptation of Hadoop's
word-count: instead of emitting (item, 1) pairs and shuffling, each grid
step compares its VMEM-resident row tile against a tile of bin ids and
reduces on-chip — a pure VPU compare + sum with no scatter (TPUs have no
fast random scatter; the dense compare is the native form).

Grid: (row_blocks, bin_blocks). The output bin tile is revisited across the
row-block dimension and accumulated in place (sequential TPU grid).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(rows_ref, weights_ref, out_ref, *, bin_block: int):
    ri = pl.program_id(0)

    @pl.when(ri == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    rows = rows_ref[...]  # (rb, L) int32
    w = weights_ref[...]  # (rb, 1) int32
    bi = pl.program_id(1)
    bins = bi * bin_block + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bin_block), 2)
    # (rb, L, bin_block) one-hot compare; PAD (-1) never equals a bin id
    onehot = (rows[:, :, None] == bins).astype(jnp.int32)
    contrib = (onehot.sum(axis=1) * w).sum(axis=0)  # (bin_block,)
    out_ref[...] += contrib[None, :]


@functools.partial(jax.jit, static_argnames=("n_bins", "row_block", "bin_block", "interpret"))
def histogram_pallas(
    rows: jnp.ndarray,
    weights: jnp.ndarray,
    *,
    n_bins: int,
    row_block: int = 256,
    bin_block: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Weighted transaction-count histogram. rows (R, L) int32, PAD=-1."""
    R, L = rows.shape
    rb = min(row_block, max(R, 1))
    bb = min(bin_block, max(n_bins, 1))
    Rp = (R + rb - 1) // rb * rb
    Bp = (n_bins + bb - 1) // bb * bb
    rows = jnp.pad(rows, ((0, Rp - R), (0, 0)), constant_values=-1)
    weights = jnp.pad(weights.astype(jnp.int32), (0, Rp - R)).reshape(Rp, 1)

    out = pl.pallas_call(
        functools.partial(_hist_kernel, bin_block=bb),
        grid=(Rp // rb, Bp // bb),
        in_specs=[
            pl.BlockSpec((rb, L), lambda ri, bi: (ri, 0)),
            pl.BlockSpec((rb, 1), lambda ri, bi: (ri, 0)),
        ],
        out_specs=pl.BlockSpec((1, bb), lambda ri, bi: (0, bi)),
        out_shape=jax.ShapeDtypeStruct((1, Bp), jnp.int32),
        interpret=interpret,
    )(rows, weights)
    return out[0, :n_bins]
