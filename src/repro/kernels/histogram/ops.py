"""Public op: item_histogram — dispatches Pallas on TPU/GPU (via the backend
registry in ``repro.mining.tune``), jnp elsewhere. ``pallas-interpret``
deliberately routes here to the exact jnp path: the interpreter exists to
exercise the wave-loop intersect kernel, not the prep scans."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.histogram.kernel import histogram_pallas
from repro.kernels.histogram.ref import histogram_ref


def item_histogram(
    rows: jnp.ndarray,
    weights: jnp.ndarray | None = None,
    *,
    n_bins: int,
    backend: str = "auto",
    interpret: bool = False,
) -> jnp.ndarray:
    """Weighted count of transactions containing each item id in [0, n_bins)."""
    if weights is None:
        weights = jnp.ones(rows.shape[0], jnp.int32)
    from repro.mining.tune import resolve_backend

    use_pallas = resolve_backend(backend) in ("pallas-tpu", "pallas-gpu")
    if use_pallas and n_bins <= 65536:
        return histogram_pallas(rows, weights, n_bins=n_bins, interpret=interpret)
    if n_bins > 8192:
        # large-universe path: scatter-add (one-hot tiles would be O(R·L·K))
        flat = rows.reshape(-1)
        w = jnp.broadcast_to(weights[:, None].astype(jnp.int32), rows.shape).reshape(-1)
        w = jnp.where(flat >= 0, w, 0)
        return jnp.zeros(n_bins, jnp.int32).at[jnp.clip(flat, 0, n_bins - 1)].add(w)
    return histogram_ref(rows, weights, n_bins=n_bins)
