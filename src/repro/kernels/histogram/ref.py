"""Pure-jnp oracle for the histogram kernel."""
import jax.numpy as jnp


def histogram_ref(rows: jnp.ndarray, weights: jnp.ndarray, *, n_bins: int) -> jnp.ndarray:
    onehot = (rows[:, :, None] == jnp.arange(n_bins)[None, None, :]).astype(jnp.int32)
    return (onehot.sum(axis=1) * weights[:, None].astype(jnp.int32)).sum(axis=0)
