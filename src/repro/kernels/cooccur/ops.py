"""Public op: cooccurrence_matrix — Pallas on TPU, jnp elsewhere.

Rows are processed in < 2^24-weight chunks so fp32 accumulation stays exact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.cooccur.kernel import cooccur_pallas
from repro.kernels.cooccur.ref import cooccur_ref


def cooccurrence_matrix(
    rows: jnp.ndarray,
    weights: jnp.ndarray | None = None,
    *,
    n_items: int,
    backend: str = "auto",
    interpret: bool = False,
) -> jnp.ndarray:
    if weights is None:
        weights = jnp.ones(rows.shape[0], jnp.int32)
    # registry dispatch (repro.mining.tune); like item_histogram, the
    # interpret backend stays on the exact jnp path — it targets the wave
    # kernel, and interpreting an O(R·K^2) scan buys no coverage
    from repro.mining.tune import resolve_backend

    use_pallas = resolve_backend(backend) in ("pallas-tpu", "pallas-gpu")
    if use_pallas:
        return cooccur_pallas(rows, weights, n_items=n_items, interpret=interpret)
    return cooccur_ref(rows, weights, n_items=n_items)
