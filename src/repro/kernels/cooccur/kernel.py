"""Pallas TPU kernel: weighted pair co-occurrence (the paper's F2 scan).

``C[i, j] = Σ_rows w · [i ∈ row] · [j ∈ row]`` over rank-encoded rows.
The paper derives frequent 2-itemsets by walking the PPC-tree; the
co-occurrence Gram matrix computes the identical quantity as ``Xᵀ·diag(w)·X``
on the one-hot row matrix — an MXU-native matmul. The kernel materializes
one-hot tiles in VMEM from the compact ``(rb, L)`` row encoding (HBM traffic
stays O(R·L), not O(R·K)) and contracts them on the MXU.

Grid: (ki, kj, row_blocks); the (ki, kj) output tile accumulates across the
row-block dimension. Counts accumulate in fp32 — exact for row blocks
< 2^24; the wrapper chunks rows to stay within that bound.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cooc_kernel(rows_ref, w_ref, out_ref, *, k_block: int):
    rblk = pl.program_id(2)

    @pl.when(rblk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    rows = rows_ref[...]  # (rb, L)
    w = w_ref[...].astype(jnp.float32)  # (rb, 1)
    ki = pl.program_id(0)
    kj = pl.program_id(1)
    bins_i = ki * k_block + jax.lax.broadcasted_iota(jnp.int32, (1, 1, k_block), 2)
    bins_j = kj * k_block + jax.lax.broadcasted_iota(jnp.int32, (1, 1, k_block), 2)
    xi = (rows[:, :, None] == bins_i).astype(jnp.float32).sum(axis=1)  # (rb, kb)
    xj = (rows[:, :, None] == bins_j).astype(jnp.float32).sum(axis=1)  # (rb, kb)
    out_ref[...] += jax.lax.dot_general(
        xi * w, xj, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit, static_argnames=("n_items", "row_block", "k_block", "interpret")
)
def cooccur_pallas(
    rows: jnp.ndarray,
    weights: jnp.ndarray,
    *,
    n_items: int,
    row_block: int = 256,
    k_block: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """(K, K) weighted co-occurrence counts (full symmetric, diag = support)."""
    R, L = rows.shape
    rb = min(row_block, max(R, 1))
    kb = min(k_block, max(n_items, 1))
    Rp = (R + rb - 1) // rb * rb
    Kp = (n_items + kb - 1) // kb * kb
    rows = jnp.pad(rows, ((0, Rp - R), (0, 0)), constant_values=-1)
    weights = jnp.pad(weights.astype(jnp.int32), (0, Rp - R)).reshape(Rp, 1)

    out = pl.pallas_call(
        functools.partial(_cooc_kernel, k_block=kb),
        grid=(Kp // kb, Kp // kb, Rp // rb),
        in_specs=[
            pl.BlockSpec((rb, L), lambda ki, kj, ri: (ri, 0)),
            pl.BlockSpec((rb, 1), lambda ki, kj, ri: (ri, 0)),
        ],
        out_specs=pl.BlockSpec((kb, kb), lambda ki, kj, ri: (ki, kj)),
        out_shape=jax.ShapeDtypeStruct((Kp, Kp), jnp.float32),
        interpret=interpret,
    )(rows, weights)
    return out[:n_items, :n_items].astype(jnp.int32)
