from repro.kernels.cooccur.ops import cooccurrence_matrix

__all__ = ["cooccurrence_matrix"]
