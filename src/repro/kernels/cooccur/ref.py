"""Pure-jnp oracle for the co-occurrence kernel."""
import jax.numpy as jnp


def cooccur_ref(rows: jnp.ndarray, weights: jnp.ndarray, *, n_items: int) -> jnp.ndarray:
    X = (rows[:, :, None] == jnp.arange(n_items)[None, None, :]).astype(jnp.float32).sum(axis=1)
    C = (X * weights[:, None].astype(jnp.float32)).T @ X
    return C.astype(jnp.int32)
