"""Atomic directory snapshots: tmp + fsync + rename, plus retention GC.

Shared by the training checkpoint writer (``checkpoint/ckpt.py``) and the
mining PreparedDB snapshot store (``mining/service/store.py``): both write
a directory of arrays + a manifest that must never be observed half-done,
and both prune old entries under a retention policy (count-based for
checkpoints, byte-budgeted for snapshots).

The atomicity contract: ``write_dir_atomic`` fills a unique
``<final>.tmp<pid>-<nonce>`` sibling and renames it into place only after
every file has been fsync'd — a crash mid-write leaves at worst a tmp
directory that listings ignore (filter with ``is_tmp``), and two
processes publishing the same entry concurrently each write their own tmp
instead of clobbering the other's (the rename loser gets an ``OSError``;
for content-addressed entries the winner's copy is equivalent).
"""
from __future__ import annotations

import os
import shutil
import time
import uuid
from typing import Callable, Sequence

import numpy as np

TMP_SUFFIX = ".tmp"


def is_tmp(path: str) -> bool:
    """Whether ``path`` is an in-progress/crashed tmp dir of this module."""
    return TMP_SUFFIX in os.path.basename(path)


def fsync_write(path: str, data: bytes) -> None:
    """Write ``data`` to ``path`` and fsync before returning."""
    with open(path, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())


def save_array(path: str, arr: np.ndarray) -> None:
    """``np.save`` + fsync (one array per file, the checkpoint layout)."""
    with open(path, "wb") as f:
        np.save(f, arr)
        f.flush()
        os.fsync(f.fileno())


def replace_file_atomic(path: str, data: bytes) -> None:
    """Atomically replace the single file ``path`` with ``data``.

    The file-granularity sibling of ``write_dir_atomic``: write + fsync a
    unique tmp next to the target, then ``os.replace`` (atomic within a
    filesystem) — a reader at ``path`` sees the old bytes or the new
    bytes, never a prefix. Used for manifests that index directory
    entries (e.g. the coordinator's append-log manifest), where a torn
    write would orphan or duplicate entries on replay."""
    tmp = f"{path}{TMP_SUFFIX}{os.getpid()}-{uuid.uuid4().hex[:8]}"
    try:
        fsync_write(tmp, data)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def write_dir_atomic(final: str, writer: Callable[[str], None]) -> None:
    """Populate directory ``final`` atomically.

    ``writer(tmp)`` fills a per-call unique sibling tmp directory; only
    after it returns is any existing ``final`` replaced by a rename. A
    failing writer leaves ``final`` untouched. Losing a concurrent
    publish race for the same ``final`` (another process renamed between
    our rmtree and rename) raises ``OSError`` after cleaning up the tmp.
    """
    tmp = f"{final}{TMP_SUFFIX}{os.getpid()}-{uuid.uuid4().hex[:8]}"
    os.makedirs(tmp)
    try:
        writer(tmp)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def reap_stale_tmp(root: str, ttl_s: float = 3600.0) -> list[str]:
    """Remove tmp directories under ``root`` whose mtime is older than
    ``ttl_s`` — the residue of writers that crashed mid-``write_dir_atomic``
    (unique tmp names mean nothing else ever reclaims them). A live
    writer's tmp keeps a fresh mtime (files are still being created in
    it), so any sane TTL never touches one. Returns the removed paths."""
    removed: list[str] = []
    now = time.time()
    try:
        names = os.listdir(root)
    except OSError:
        return removed
    for name in names:
        path = os.path.join(root, name)
        if not is_tmp(name) or not os.path.isdir(path):
            continue
        try:
            stale = now - os.path.getmtime(path) > ttl_s
        except OSError:
            continue
        if stale:
            shutil.rmtree(path, ignore_errors=True)
            removed.append(path)
    return removed


def dir_bytes(path: str) -> int:
    """Total size of the files under ``path`` (0 if it vanished)."""
    total = 0
    for root, _, files in os.walk(path):
        for name in files:
            try:
                total += os.path.getsize(os.path.join(root, name))
            except OSError:
                pass
    return total


def prune_oldest(
    dirs: Sequence[str],
    *,
    keep: int | None = None,
    byte_budget: int | None = None,
) -> list[str]:
    """Remove entries from the front of ``dirs`` until the retention policy
    holds; returns the removed paths.

    The caller passes ``dirs`` least-valuable-first (checkpoints: ascending
    step; snapshots: ascending mtime). ``keep`` bounds the entry count,
    ``byte_budget`` the total on-disk size — either alone or both together.
    Like the engine's LRU, a byte budget may remove every entry when even
    the newest alone exceeds it.
    """
    removed: list[str] = []
    sizes = [dir_bytes(d) for d in dirs] if byte_budget is not None else None
    total = sum(sizes) if sizes else 0
    for i, d in enumerate(dirs):
        over_keep = keep is not None and len(dirs) - len(removed) > keep
        over_bytes = byte_budget is not None and total > byte_budget
        if not (over_keep or over_bytes):
            break
        shutil.rmtree(d, ignore_errors=True)
        removed.append(d)
        if sizes is not None:
            total -= sizes[i]
    return removed
