"""Layout-free checkpointing: manifest + per-array .npy, atomic, async.

Design points for the 1000-node posture:
  - *Atomicity*: writes go to ``step_<n>.tmp`` and are renamed into place
    only after every array and the manifest have been fsync'd — a crash
    mid-save never corrupts the latest checkpoint.
  - *Elasticity*: arrays are stored unsharded (gathered to host), so a
    checkpoint taken on one mesh restores onto any other mesh/device count
    (``restore`` just re-device_puts with the new shardings). ZeRO moments
    re-shard the same way.
  - *Async*: ``save_async`` snapshots to host then writes on a thread, so
    the step loop is blocked only for the device->host copy.
  - *Retention*: ``keep`` most recent checkpoints are retained.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any

import jax
import numpy as np

from repro.checkpoint.atomic import fsync_write, prune_oldest, save_array, write_dir_atomic


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ io
    def _write(self, step: int, host_tree: dict[str, np.ndarray], extra: dict):
        final = os.path.join(self.dir, f"step_{step:09d}")
        manifest = {"step": step, "arrays": {}, "extra": extra}

        def writer(tmp):
            for i, (name, arr) in enumerate(host_tree.items()):
                fname = f"a{i:06d}.npy"
                save_array(os.path.join(tmp, fname), arr)
                manifest["arrays"][name] = {"file": fname, "dtype": str(arr.dtype), "shape": list(arr.shape)}
            fsync_write(os.path.join(tmp, "manifest.json"), json.dumps(manifest).encode())

        write_dir_atomic(final, writer)
        self._gc()

    def _gc(self):
        from repro.checkpoint.atomic import reap_stale_tmp

        reap_stale_tmp(self.dir)  # residue of writers killed mid-save
        if self.keep <= 0:  # match the old slicing semantics: retain all
            return
        prune_oldest(
            [os.path.join(self.dir, f"step_{s:09d}") for s in self.list_steps()],
            keep=self.keep,
        )

    # ----------------------------------------------------------------- api
    def list_steps(self) -> list[int]:
        from repro.checkpoint.atomic import is_tmp

        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not is_tmp(d):
                out.append(int(d[5:]))
        return sorted(out)

    def save(self, step: int, state, extra: dict | None = None, block: bool = True):
        flat = _flatten(state)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        if block:
            self._write(step, host, extra or {})
        else:
            self.wait()
            self._thread = threading.Thread(target=self._write, args=(step, host, extra or {}))
            self._thread.start()

    def save_async(self, step: int, state, extra: dict | None = None):
        self.save(step, state, extra, block=False)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def latest_step(self) -> int | None:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None, shardings=None):
        """Restore (state, extra). ``shardings``: optional matching pytree of
        NamedShardings to place arrays onto a (possibly different) mesh."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat_sh = _flatten(shardings) if shardings is not None else {}
        flat = {}
        for name, meta in manifest["arrays"].items():
            arr = np.load(os.path.join(path, meta["file"]))
            sh = flat_sh.get(name)
            flat[name] = jax.device_put(arr, sh) if sh is not None else arr
        return _unflatten(flat), manifest["extra"]
