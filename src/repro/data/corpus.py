"""Synthetic LM corpus + the bridge to the paper's miner.

``token_stream``: seeded Zipf-ish token sequences with injected frequent
n-gram "phrases" — gives the language-model trainer data and gives the
frequent-itemset miner real structure to find (the injected phrases come
back out as high-support itemsets; tested).

``ngram_transactions``: sliding windows of the corpus as transactions —
the data-pipeline integration point for HPrepost (corpus pattern mining).
"""
from __future__ import annotations

import numpy as np


def token_stream(
    n_tokens: int,
    vocab: int,
    *,
    seed: int = 0,
    n_phrases: int = 8,
    phrase_len: int = 4,
    phrase_rate: float = 0.15,
) -> np.ndarray:
    rng = np.random.default_rng(seed)
    # Zipf-ish unigram distribution over the vocab
    base = rng.zipf(1.3, size=int(n_tokens * 1.5)) % vocab
    phrases = rng.integers(0, vocab, size=(n_phrases, phrase_len))
    out = np.empty(n_tokens + phrase_len, np.int32)
    i = 0
    j = 0
    while i < n_tokens:
        if rng.random() < phrase_rate:
            p = phrases[rng.integers(n_phrases)]
            out[i : i + phrase_len] = p
            i += phrase_len
        else:
            out[i] = base[j]
            i += 1
            j += 1
    return out[:n_tokens]


def batches(tokens: np.ndarray, batch: int, seq: int, *, seed: int = 0):
    """Yield {"tokens": (batch, seq+1)} windows forever (seeded)."""
    rng = np.random.default_rng(seed)
    n = len(tokens) - seq - 1
    while True:
        starts = rng.integers(0, n, size=batch)
        yield {"tokens": np.stack([tokens[s : s + seq + 1] for s in starts]).astype(np.int32)}


def ngram_transactions(tokens: np.ndarray, window: int = 8, stride: int = 4) -> np.ndarray:
    """Sliding windows as transactions (duplicate items collapse)."""
    n = (len(tokens) - window) // stride
    idx = np.arange(window)[None, :] + stride * np.arange(n)[:, None]
    rows = tokens[idx].astype(np.int32)
    rows.sort(axis=1)
    dup = np.zeros_like(rows, bool)
    dup[:, 1:] = rows[:, 1:] == rows[:, :-1]
    rows[dup] = -1
    rows.sort(axis=1)  # PAD (-1) slots end up in front; encoding handles both
    return rows
