"""Synthetic transaction datasets (FIMI surrogates + IBM-Quest-style).

The paper evaluates on Chess / Mushroom / Pumsb / Kosarak from
http://fimi.ua.ac.be/data/. This container is offline, so we generate
surrogates matched on the paper's Table-3 characteristics (#items,
#transactions, avg length) and on the qualitative density profile
(dense grid-like rows for chess/mushroom/pumsb; sparse power-law for
kosarak). The substitution is recorded in EXPERIMENTS.md.

Generators are seeded and deterministic.
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np

from repro.core.encoding import pad_transactions


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_items: int
    n_tx: int
    avg_len: int
    kind: str  # "dense" | "sparse"
    max_len: int


# Scaled-down surrogates (same shape, ~1/4 the rows) so CPU benches finish;
# the full-size variants are available via scale=1.0.
FIMI_SURROGATES = {
    "chess": DatasetSpec("chess", 75, 3196, 37, "dense", 37),
    "mushroom": DatasetSpec("mushroom", 119, 8124, 23, "dense", 23),
    "pumsb": DatasetSpec("pumsb", 7117, 49046, 74, "dense", 74),
    "kosarak": DatasetSpec("kosarak", 41270, 990002, 8, "sparse", 48),
}


def generate_dense(
    spec: DatasetSpec, rng: np.random.Generator, n_tx: int, n_templates: int = 4, mutate: float = 0.25
) -> np.ndarray:
    """Chess/pumsb-like data: ``avg_len`` attribute slots, each holding one
    value of a small per-slot alphabet. Rows are noisy copies of a few
    *templates*, giving the strong item correlation (and the itemset-count
    explosion at low min-sup) the real FIMI dense datasets show."""
    n_slots = spec.avg_len
    vals_per_slot = max(2, spec.n_items // n_slots)
    templates = rng.integers(0, vals_per_slot, size=(n_templates, n_slots))
    which = rng.integers(0, n_templates, size=n_tx)
    rows = templates[which]
    flip = rng.random((n_tx, n_slots)) < mutate
    rows = np.where(flip, rng.integers(0, vals_per_slot, size=(n_tx, n_slots)), rows)
    base = (np.arange(n_slots) * vals_per_slot)[None, :]
    return (base + rows).astype(np.int32)  # fixed length: no PAD needed


def generate_sparse(spec: DatasetSpec, rng: np.random.Generator, n_tx: int) -> np.ndarray:
    """Kosarak-like: power-law item popularity, geometric row lengths."""
    lens = np.minimum(rng.geometric(1.0 / spec.avg_len, size=n_tx), spec.max_len)
    # Zipf item ids clipped to the universe
    total = int(lens.sum())
    items = rng.zipf(1.35, size=total * 2)
    items = items[items <= spec.n_items][:total].astype(np.int64) - 1
    while len(items) < total:  # top-up in the unlikely short case
        extra = rng.zipf(1.35, size=total)
        extra = extra[extra <= spec.n_items]
        items = np.concatenate([items, extra.astype(np.int64) - 1])[:total]
    out = np.full((n_tx, spec.max_len), -1, np.int32)
    off = 0
    starts = np.concatenate([[0], np.cumsum(lens)])
    for r in range(n_tx):
        seg = np.unique(items[starts[r] : starts[r + 1]])
        out[r, : len(seg)] = seg
        off += lens[r]
    return out


def load(name: str, *, scale: float = 0.25, seed: int = 0) -> tuple[np.ndarray, int]:
    """Return ``(rows, n_items)`` for a FIMI surrogate at ``scale`` of its rows."""
    spec = FIMI_SURROGATES[name]
    # stable per-dataset seed: builtin hash() is salted per process, which
    # would make "the same dataset" differ between two CLI invocations
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % 2**16)
    n_tx = max(64, int(spec.n_tx * scale))
    if spec.kind == "dense":
        rows = generate_dense(spec, rng, n_tx)
    else:
        rows = generate_sparse(spec, rng, n_tx)
    return rows, spec.n_items


def random_db(rng: np.random.Generator, n_tx: int, n_items: int, max_len: int) -> np.ndarray:
    """Small random DB for property tests."""
    lens = rng.integers(0, max_len + 1, size=n_tx)
    tx = [rng.choice(n_items, size=l, replace=False) if l else [] for l in lens]
    return pad_transactions(tx, max_len=max(max_len, 1))
