"""Sharded input pipeline: host batching, prefetch, straggler-aware skip.

``Prefetcher`` runs the (host) batch generator on a thread and keeps a
bounded queue of device-put batches — compute/host-IO overlap. If the
``StragglerMonitor`` flags a step, ``skip_slow`` drops the queue head
(redistribution hook: on a real cluster the slow shard's range is handed
to a healthy host; here the skip policy + bookkeeping are what is tested).
"""
from __future__ import annotations

import queue
import threading

import jax
import numpy as np


class Prefetcher:
    def __init__(self, gen, depth: int = 2, sharding=None):
        self._gen = gen
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._sharding = sharding
        self._stop = False
        self._skipped = 0
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        for item in self._gen:
            if self._stop:
                return
            if self._sharding is not None:
                item = jax.tree.map(
                    lambda x, s=self._sharding: jax.device_put(x, s.get(None) if isinstance(s, dict) else s),
                    item,
                )
            self._q.put(item)

    def next(self):
        return self._q.get()

    def skip_slow(self, n: int = 1):
        """Straggler mitigation: drop ``n`` queued batches (they would have
        been produced by the slow shard) and account for them."""
        for _ in range(n):
            try:
                self._q.get_nowait()
                self._skipped += 1
            except queue.Empty:
                break

    @property
    def skipped(self) -> int:
        return self._skipped

    def close(self):
        self._stop = True
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
