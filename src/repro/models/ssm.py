"""State-space / recurrent blocks: Mamba2 (SSD), xLSTM (mLSTM + sLSTM).

All train/prefill paths use *chunked* forms: quadratic within a chunk
(MXU matmuls), linear across chunks via a ``lax.scan`` carrying the
recurrent state — the TPU-native shape of these architectures. Decode is
the O(1)/token recurrent update, which is what makes the ``long_500k``
cell feasible for the ssm/hybrid archs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec
from repro.models.layers import rmsnorm, rmsnorm_spec

CHUNK = 128  # mLSTM chunk
MAMBA_CHUNK = 64  # smaller: the (Q, Q, n_heads) within-chunk decay tensor
# dominates SSD working-set memory; 64 keeps it inside a v5e VMEM-friendly
# footprint at d_model=2560/80 heads (see EXPERIMENTS.md §Perf)


# =============================================================== Mamba2 (SSD)
def mamba2_specs(cfg) -> dict:
    d = cfg.d_model
    din = cfg.ssm_expand * d
    N = cfg.ssm_state
    nh = cfg.ssm_heads
    conv_ch = din + 2 * N
    return {
        "in_proj": ParamSpec((d, 2 * din + 2 * N + nh), ("embed", "d_inner")),
        "conv_w": ParamSpec((cfg.ssm_conv, conv_ch), (None, "d_inner")),
        "conv_b": ParamSpec((conv_ch,), ("d_inner",), init="zeros"),
        "A_log": ParamSpec((nh,), (None,), init="zeros"),
        "D": ParamSpec((nh,), (None,), init="ones"),
        "dt_bias": ParamSpec((nh,), (None,), init="zeros"),
        "norm": ParamSpec((din,), ("d_inner",), init="ones"),
        "out_proj": ParamSpec((din, d), ("d_inner", "embed")),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, state=None):
    """Depthwise causal conv. x (B, S, C), w (K, C). Returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    new_state = xp[:, -(K - 1) :, :] if K > 1 else state
    y = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    return jax.nn.silu(y + b[None, None, :]), new_state


def _split_zxbcdt(p, zxbcdt, cfg):
    d = cfg.d_model
    din = cfg.ssm_expand * d
    N = cfg.ssm_state
    nh = cfg.ssm_heads
    z = zxbcdt[..., :din]
    xBC = zxbcdt[..., din : 2 * din + 2 * N]
    dt = zxbcdt[..., 2 * din + 2 * N :]
    return z, xBC, dt, din, N, nh


def mamba2(p: dict, x: jnp.ndarray, cfg, state: dict | None = None, single_step=False):
    """x (B, S, d) -> (y (B, S, d), new_state {ssm (B,nh,hd,N), conv})."""
    B, S, d = x.shape
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xBC, dt_raw, din, N, nh = _split_zxbcdt(p, zxbcdt, cfg)
    hd = cfg.ssm_head_dim

    conv_state = state["conv"] if state is not None else None
    xBC, new_conv = _causal_conv(xBC, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype), conv_state)
    xin = xBC[..., :din].reshape(B, S, nh, hd)
    Bc = xBC[..., din : din + N].astype(jnp.float32)
    Cc = xBC[..., din + N :].astype(jnp.float32)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # (B,S,nh)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))  # (nh,)
    dA = dt * a[None, None, :]  # (B,S,nh) log-decay per step

    h0 = state["ssm"] if state is not None else jnp.zeros((B, nh, hd, N), jnp.float32)

    if single_step:
        # recurrent update: h = h*exp(dA) + dt * x ⊗ B ; y = h·C
        xf = xin[:, 0].astype(jnp.float32)  # (B,nh,hd)
        h1 = h0 * jnp.exp(dA[:, 0])[:, :, None, None] + (
            (dt[:, 0])[:, :, None, None] * xf[:, :, :, None] * Bc[:, 0][:, None, None, :]
        )
        y = jnp.einsum("bhdn,bn->bhd", h1, Cc[:, 0])[:, None]  # (B,1,nh,hd)
        hlast = h1
    else:
        Q = min(MAMBA_CHUNK, S)
        assert S % Q == 0, (S, Q)
        nc = S // Q
        xc = xin.reshape(B, nc, Q, nh, hd).astype(jnp.float32)
        Bcc = Bc.reshape(B, nc, Q, N)
        Ccc = Cc.reshape(B, nc, Q, N)
        dtc = dt.reshape(B, nc, Q, nh)
        dAc = dA.reshape(B, nc, Q, nh)
        cum = jnp.cumsum(dAc, axis=2)  # (B,nc,Q,nh)

        # within-chunk (quadratic, MXU): y_diag[t] = Σ_{j<=t} e^{cum_t-cum_j} dt_j (C_t·B_j) x_j
        decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q,Q,nh)
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        # mask in log space: exp of a masked (large positive) decay would
        # overflow and poison gradients through the where
        w = jnp.exp(jnp.where(mask[None, None, :, :, None], decay, -1e30))
        scores = jnp.einsum("bcin,bcjn->bcij", Ccc, Bcc)  # (B,nc,Q,Q)
        wdt = w * dtc[:, :, None, :, :]  # (B,nc,Q,Q,nh)
        y_diag = jnp.einsum("bcij,bcijh,bcjhd->bcihd", scores, wdt, xc)

        # chunk states: S_c = Σ_j e^{cum_Q-cum_j} dt_j B_j ⊗ x_j  (B,nc,nh,hd,N)
        sdecay = jnp.exp(cum[:, :, -1:, :] - cum) * dtc  # (B,nc,Q,nh)
        S_c = jnp.einsum("bcjh,bcjn,bcjhd->bchdn", sdecay, Bcc, xc)

        # inter-chunk scan: H_{c} = H_{c-1} * e^{sum_c} + S_c
        seg = cum[:, :, -1, :]  # (B,nc,nh)

        def step(h, inp):
            s_c, g = inp  # (B,nh,hd,N), (B,nh)
            h_new = h * jnp.exp(g)[:, :, None, None] + s_c
            return h_new, h  # emit state *entering* the chunk

        hlast, h_in = jax.lax.scan(step, h0, (S_c.transpose(1, 0, 2, 3, 4), seg.transpose(1, 0, 2)))
        h_in = h_in.transpose(1, 0, 2, 3, 4)  # (B,nc,nh,hd,N)

        # cross-chunk: y_off[t] = e^{cum_t} C_t · H_in
        y_off = jnp.einsum("bcin,bchdn,bcih->bcihd", Ccc, h_in, jnp.exp(cum))
        y = (y_diag + y_off).reshape(B, S, nh, hd)

    y = y + xin.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, din).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, {"ssm": hlast, "conv": new_conv}


def mamba2_state_specs(cfg, batch: int, lead: tuple = (), lead_axes: tuple = ()) -> dict:
    din = cfg.ssm_expand * cfg.d_model
    N = cfg.ssm_state
    nh = cfg.ssm_heads
    conv_ch = din + 2 * N
    return {
        "ssm": ParamSpec(lead + (batch, nh, cfg.ssm_head_dim, N), lead_axes + ("batch", None, None, None), dtype=jnp.float32, init="zeros"),
        "conv": ParamSpec(lead + (batch, cfg.ssm_conv - 1, conv_ch), lead_axes + ("batch", None, "d_inner"), init="zeros"),
    }


# =============================================================== xLSTM blocks
def mlstm_specs(cfg) -> dict:
    d = cfg.d_model
    din = 2 * d  # projection factor 2 (paper)
    nh = cfg.n_heads
    hd = din // nh
    return {
        "norm_in": rmsnorm_spec(d),
        "up": ParamSpec((d, 2 * din), ("embed", "d_inner")),
        "conv_w": ParamSpec((cfg.ssm_conv, din), (None, "d_inner")),
        "conv_b": ParamSpec((din,), ("d_inner",), init="zeros"),
        "wq": ParamSpec((din, nh, hd), ("d_inner", "heads", None)),
        "wk": ParamSpec((din, nh, hd), ("d_inner", "heads", None)),
        "wv": ParamSpec((din, nh, hd), ("d_inner", "heads", None)),
        "w_if": ParamSpec((din, 2 * nh), ("d_inner", None)),  # input/forget gates
        "b_if": ParamSpec((2 * nh,), (None,), init="zeros"),
        "norm_h": ParamSpec((din,), ("d_inner",), init="ones"),
        "down": ParamSpec((din, d), ("d_inner", "embed")),
    }


def mlstm(p: dict, x: jnp.ndarray, cfg, state: dict | None = None, single_step=False):
    """Stabilized matrix-LSTM, chunked parallel form. x (B,S,d)."""
    B, S, d = x.shape
    din = 2 * d
    nh = cfg.n_heads
    hd = din // nh
    xn = rmsnorm(x, p["norm_in"], cfg.norm_eps)
    up = xn @ p["up"].astype(x.dtype)
    u, gate = up[..., :din], up[..., din:]
    conv_state = state["conv"] if state is not None else None
    c, new_conv = _causal_conv(u, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype), conv_state)

    q = jnp.einsum("bsd,dhk->bshk", c, p["wq"].astype(x.dtype)).astype(jnp.float32)
    k = jnp.einsum("bsd,dhk->bshk", c, p["wk"].astype(x.dtype)).astype(jnp.float32) * hd**-0.5
    v = jnp.einsum("bsd,dhk->bshk", u, p["wv"].astype(x.dtype)).astype(jnp.float32)
    ifg = (c @ p["w_if"].astype(x.dtype)).astype(jnp.float32) + p["b_if"].astype(jnp.float32)
    logi = ifg[..., :nh]  # (B,S,nh) log input gate (pre-exp)
    logf = jax.nn.log_sigmoid(ifg[..., nh:])  # (B,S,nh)

    if state is not None:
        C0, n0, m0 = state["C"], state["n"], state["m"]
    else:
        C0 = jnp.zeros((B, nh, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, nh, hd), jnp.float32)
        m0 = jnp.full((B, nh), -1e30, jnp.float32)

    if single_step:
        F = logf[:, 0]  # (B,nh)
        I = logi[:, 0]
        m1 = jnp.maximum(F + m0, I)
        fs = jnp.exp(F + m0 - m1)[:, :, None, None]
        is_ = jnp.exp(I - m1)[:, :, None, None]
        C1 = C0 * fs + is_ * jnp.einsum("bhk,bhv->bhkv", k[:, 0], v[:, 0])
        n1 = n0 * fs[..., 0] + is_[..., 0] * k[:, 0]
        num = jnp.einsum("bhkv,bhk->bhv", C1, q[:, 0])
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", n1, q[:, 0]))
        h = num / jnp.maximum(den, jnp.exp(-m1))[:, :, None]
        h = h[:, None]  # (B,1,nh,hd)
        new_state = {"C": C1, "n": n1, "m": m1, "conv": new_conv}
    else:
        Q = min(CHUNK, S)
        assert S % Q == 0
        nc = S // Q
        qc = q.reshape(B, nc, Q, nh, hd)
        kc = k.reshape(B, nc, Q, nh, hd)
        vc = v.reshape(B, nc, Q, nh, hd)
        ic = logi.reshape(B, nc, Q, nh)
        fc = logf.reshape(B, nc, Q, nh)
        Fcum = jnp.cumsum(fc, axis=2)  # (B,nc,Q,nh)

        def chunk_step(carry, inp):
            C0, n0, m0 = carry
            qb, kb, vb, ib, Fb = inp  # (B,Q,nh,*)
            # D_ij = F_i - F_j + i_j (j<=i), cross term m0 + F_i
            Dm = Fb[:, :, None, :] - Fb[:, None, :, :] + ib[:, None, :, :]
            mask = jnp.tril(jnp.ones((Q, Q), bool))
            Dm = jnp.where(mask[None, :, :, None], Dm, -1e30)
            m_intra = Dm.max(axis=2)  # (B,Q,nh)
            m_i = jnp.maximum(m_intra, m0[:, None, :] + Fb)
            w = jnp.exp(Dm - m_i[:, :, None, :])  # (B,Q,Q,nh)
            s = jnp.einsum("bihk,bjhk->bijh", qb, kb)  # (B,Q,Q,nh)
            cross = jnp.exp(Fb + m0[:, None, :] - m_i)  # (B,Q,nh)
            num = jnp.einsum("bijh,bijh,bjhv->bihv", s, w, vb) + cross[..., None] * jnp.einsum(
                "bhkv,bihk->bihv", C0, qb
            )
            den = jnp.einsum("bijh,bjhk,bihk->bih", w, kb, qb) + cross * jnp.einsum(
                "bhk,bihk->bih", n0, qb
            )
            h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]
            # state to next chunk
            FQ = Fb[:, -1, :]  # (B,nh)
            m1 = jnp.maximum(m0 + FQ, (FQ[:, None, :] - Fb + ib).max(axis=1))
            sdec = jnp.exp(FQ[:, None, :] - Fb + ib - m1[:, None, :])  # (B,Q,nh)
            C1 = C0 * jnp.exp(m0 + FQ - m1)[:, :, None, None] + jnp.einsum(
                "bjh,bjhk,bjhv->bhkv", sdec, kb, vb
            )
            n1 = n0 * jnp.exp(m0 + FQ - m1)[:, :, None] + jnp.einsum("bjh,bjhk->bhk", sdec, kb)
            return (C1, n1, m1), h

        xs = tuple(t.transpose(1, 0, 2, 3, 4) if t.ndim == 5 else t.transpose(1, 0, 2, 3)
                   for t in (qc, kc, vc, ic, Fcum))
        (C1, n1, m1), hs = jax.lax.scan(chunk_step, (C0, n0, m0), xs)
        h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, nh, hd)
        new_state = {"C": C1, "n": n1, "m": m1, "conv": new_conv}

    hflat = h.reshape(B, -1, din).astype(x.dtype)
    hflat = rmsnorm(hflat, p["norm_h"], cfg.norm_eps) * jax.nn.silu(gate)
    return x + hflat @ p["down"].astype(x.dtype), new_state


def mlstm_state_specs(cfg, batch: int, lead=(), lead_axes=()) -> dict:
    din = 2 * cfg.d_model
    nh = cfg.n_heads
    hd = din // nh
    f32 = jnp.float32
    return {
        "C": ParamSpec(lead + (batch, nh, hd, hd), lead_axes + ("batch", None, None, None), dtype=f32, init="zeros"),
        "n": ParamSpec(lead + (batch, nh, hd), lead_axes + ("batch", None, None), dtype=f32, init="zeros"),
        "m": ParamSpec(lead + (batch, nh), lead_axes + ("batch", None), dtype=f32, init="ones", scale=-1e30),
        "conv": ParamSpec(lead + (batch, cfg.ssm_conv - 1, din), lead_axes + ("batch", None, "d_inner"), init="zeros"),
    }


def slstm_specs(cfg) -> dict:
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    return {
        "norm_in": rmsnorm_spec(d),
        "wx": ParamSpec((d, 4, nh, hd), ("embed", None, "heads", None)),
        "r": ParamSpec((4, nh, hd, hd), (None, "heads", None, None), scale=0.1),
        "b": ParamSpec((4, nh, hd), (None, "heads", None), init="zeros"),
        "norm_h": rmsnorm_spec(d),
        "up": ParamSpec((d, 2 * d), ("embed", "ff")),
        "down": ParamSpec((2 * d, d), ("ff", "embed")),
    }


def slstm(p: dict, x: jnp.ndarray, cfg, state: dict | None = None, single_step=False):
    """Scalar-memory LSTM with exponential gating; sequential lax.scan."""
    B, S, d = x.shape
    nh = cfg.n_heads
    hd = d // nh
    xn = rmsnorm(x, p["norm_in"], cfg.norm_eps)

    if state is not None:
        c0, n0, m0, h0 = state["c"], state["n"], state["m"], state["h"]
    else:
        c0 = jnp.zeros((B, nh, hd), jnp.float32)
        n0 = jnp.ones((B, nh, hd), jnp.float32)
        m0 = jnp.zeros((B, nh, hd), jnp.float32)
        h0 = jnp.zeros((B, nh, hd), jnp.float32)

    r = p["r"].astype(jnp.float32)
    b = p["b"].astype(jnp.float32)
    wx = p["wx"].astype(x.dtype)

    def step(carry, xt):
        c, n, m, h = carry
        gx = jnp.einsum("bd,dghk->bghk", xt, wx).astype(jnp.float32)
        rec = jnp.einsum("bhk,ghkl->bghl", h, r)
        zt, it, ft, ot = [gx[:, g] + rec[:, g] + b[g][None] for g in range(4)]
        mt = jnp.maximum(ft + m, it)
        ip = jnp.exp(it - mt)
        fp = jnp.exp(ft + m - mt)
        ct = fp * c + ip * jnp.tanh(zt)
        nt = fp * n + ip
        ht = jax.nn.sigmoid(ot) * ct / jnp.maximum(nt, 1e-6)
        return (ct, nt, mt, ht), ht

    # Chunked evaluation: outer scan over S/Q chunks, inner Q steps
    # *unrolled* inside a checkpointed chunk body. A flat per-timestep scan
    # makes the (remat × scan-of-scan) backward materialize full-stack
    # pads/reduces per step — §Perf xlstm iterations 1-2 (146 s -> 3.5 s).
    # Q=64 balances unrolled-body compile time against chunk-boundary
    # residual traffic (Q=128 compiled 4× slower for the same terms).
    Q = S
    for cand in (64, 32):
        if S % cand == 0:
            Q = cand
            break

    def chunk(carry, xc):  # xc (Q, B, d)
        def inner(cr, xt):
            return step(cr, xt)
        new_carry, hs = jax.lax.scan(inner, carry, xc, unroll=True)
        return new_carry, hs

    xs = xn.transpose(1, 0, 2)
    if S > Q:
        xs = xs.reshape(S // Q, Q, B, d)
        (c1, n1, m1, h1), hs = jax.lax.scan(jax.checkpoint(chunk), (c0, n0, m0, h0), xs)
        hs = hs.reshape(S, B, nh, hd)
    else:
        (c1, n1, m1, h1), hs = jax.lax.scan(step, (c0, n0, m0, h0), xs)
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(x.dtype)
    h = rmsnorm(h, p["norm_h"], cfg.norm_eps)
    x = x + h
    # small FFN (up factor 2, gelu) as in the paper's post-sLSTM block
    u = x @ p["up"].astype(x.dtype)
    x = x + jax.nn.gelu(u) @ p["down"].astype(x.dtype)
    return x, {"c": c1, "n": n1, "m": m1, "h": h1}


def slstm_state_specs(cfg, batch: int, lead=(), lead_axes=()) -> dict:
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    f32 = jnp.float32
    ax = lead_axes + ("batch", None, None)
    return {
        "c": ParamSpec(lead + (batch, nh, hd), ax, dtype=f32, init="zeros"),
        "n": ParamSpec(lead + (batch, nh, hd), ax, dtype=f32, init="ones"),
        "m": ParamSpec(lead + (batch, nh, hd), ax, dtype=f32, init="zeros"),
        "h": ParamSpec(lead + (batch, nh, hd), ax, dtype=f32, init="zeros"),
    }
