"""Architecture registry: config -> model implementation + input specs.

``input_specs(cfg, shape)`` builds the ParamSpec trees for a shape cell's
*inputs* (batch + cache); the dry-run turns them into ShapeDtypeStructs
(zero allocation), smoke tests materialize tiny real arrays from the
reduced configs.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec
from repro.models.encdec import EncDecModel
from repro.models.ssm_models import XLSTMModel, ZambaModel
from repro.models.transformer import DecoderLM

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq=524288, global_batch=1),
}


def build_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return DecoderLM(cfg)
    if cfg.family == "ssm":
        return XLSTMModel(cfg)
    if cfg.family == "hybrid":
        return ZambaModel(cfg)
    if cfg.family == "encdec":
        return EncDecModel(cfg)
    raise ValueError(cfg.family)


def applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for a (arch, shape) cell."""
    s = SHAPES[shape_name]
    if s["kind"] == "decode" and not cfg.supports_decode:
        return False, "encoder-only arch: no decode step"
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: 0.5M-token dense KV pass skipped per assignment"
    return True, ""


def batch_specs(cfg: ModelConfig, shape_name: str, seq=None, batch=None) -> dict:
    """ParamSpec tree for the input batch of a shape cell."""
    s = SHAPES[shape_name]
    S = seq or s["seq"]
    B = batch or s["global_batch"]
    kind = s["kind"]
    i32 = jnp.int32
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)

    def tok(shape):
        return ParamSpec(shape, ("batch", None), dtype=i32, init="zeros")

    if kind == "train":
        out = {"tokens": tok((B, S + 1))}
        if cfg.family == "vlm":
            P = cfg.frontend_tokens
            out = {
                "tokens": tok((B, S - P + 1)),
                "patches": ParamSpec((B, P, d), ("batch", None, None), dtype=dt),
            }
        if cfg.family == "encdec":
            out["frames"] = ParamSpec((B, max(S // 4, 1), d), ("batch", None, None), dtype=dt)
        return out
    if kind == "prefill":
        out = {"tokens": tok((B, S))}
        if cfg.family == "vlm":
            P = cfg.frontend_tokens
            out = {
                "tokens": tok((B, S - P)),
                "patches": ParamSpec((B, P, d), ("batch", None, None), dtype=dt),
            }
        if cfg.family == "encdec":
            out["frames"] = ParamSpec((B, max(S // 4, 1), d), ("batch", None, None), dtype=dt)
        return out
    # decode: one token against a cache of length S
    return {
        "token": tok((B, 1)),
        "pos": ParamSpec((), (), dtype=i32, init="zeros"),
    }


def cache_specs_for(cfg: ModelConfig, shape_name: str, seq=None, batch=None):
    s = SHAPES[shape_name]
    if s["kind"] == "train":
        return None
    S = seq or s["seq"]
    B = batch or s["global_batch"]
    model = build_model(cfg)
    if cfg.family == "encdec":
        return model.cache_specs(B, S, mem_len=max(S // 4, 1))
    return model.cache_specs(B, S)


def step_fn(cfg: ModelConfig, shape_name: str):
    """The function a cell lowers: loss (train) or prefill/decode (serve)."""
    model = build_model(cfg)
    kind = SHAPES[shape_name]["kind"]
    if kind == "train":
        return lambda params, batch: model.loss(params, batch)
    if kind == "prefill":
        return lambda params, batch, cache: model.prefill(params, batch, cache)
    return lambda params, batch, cache: model.decode(params, batch, cache)


def materialize_batch(cfg: ModelConfig, shape_name: str, seq: int, batch: int, key):
    """Small real batch for smoke tests (reduced configs)."""
    specs = batch_specs(cfg, shape_name, seq=seq, batch=batch)
    rng = np.random.default_rng(0)
    out = {}
    for k, sp in specs.items():
        if sp.dtype == jnp.int32 and k in ("tokens", "token"):
            out[k] = jnp.asarray(rng.integers(0, cfg.vocab_size, size=sp.shape), jnp.int32)
        elif k == "pos":
            out[k] = jnp.asarray(seq - 1, jnp.int32)
        else:
            out[k] = jnp.asarray(rng.normal(size=sp.shape), jnp.float32).astype(jnp.dtype(cfg.dtype))
    return out
