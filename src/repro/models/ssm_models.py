"""xLSTM (ssm family) and Zamba2 (hybrid family) model drivers.

xLSTM: groups of (slstm_every - 1) mLSTM blocks + 1 sLSTM block, scanned.
Zamba2: groups of ``attn_every`` Mamba2 blocks followed by one *shared*
(weight-tied) full-attention block — the shared weights live outside the
scan; each invocation keeps its own KV cache (stacked over groups).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as ll
from repro.models import ssm
from repro.models.common import ParamSpec
from repro.models.transformer import _stack_specs


class XLSTMModel:
    def __init__(self, cfg):
        self.cfg = cfg
        assert cfg.n_layers % cfg.slstm_every == 0
        self.n_groups = cfg.n_layers // cfg.slstm_every
        self.m_per_group = cfg.slstm_every - 1

    def param_specs(self):
        cfg = self.cfg
        group = {
            "mlstm": _stack_specs(ssm.mlstm_specs(cfg), self.m_per_group),
            "slstm": ssm.slstm_specs(cfg),
        }
        return {
            "embed": ll.embed_specs(cfg),
            "groups": _stack_specs(group, self.n_groups),
        }

    def cache_specs(self, batch: int, seq: int):
        g, m = self.n_groups, self.m_per_group
        return {
            "mlstm": ssm.mlstm_state_specs(self.cfg, batch, lead=(g, m), lead_axes=("layers", "layers")),
            "slstm": ssm.slstm_state_specs(self.cfg, batch, lead=(g,), lead_axes=("layers",)),
        }

    def _group(self, gp, x, gc, single_step):
        cfg = self.cfg

        def mbody(carry, xs):
            lp, lc = xs
            y, st = ssm.mlstm(lp, carry, cfg, state=lc, single_step=single_step)
            return y, st

        x, m_states = jax.lax.scan(mbody, x, (gp["mlstm"], gc["mlstm"] if gc else None))
        x, s_state = ssm.slstm(gp["slstm"], x, cfg, state=gc["slstm"] if gc else None, single_step=single_step)
        return x, {"mlstm": m_states, "slstm": s_state}

    def backbone(self, params, x, cache=None, train=False, single_step=False):
        def body(carry, xs):
            gp, gc = xs
            return self._group(gp, carry, gc, single_step)

        fn = jax.checkpoint(body) if train else body
        if cache is None:
            zero = jax.tree.map(
                lambda s: jnp.zeros(s.shape[1:], s.dtype),
                self.cache_specs(x.shape[0], 0),
                is_leaf=lambda t: isinstance(t, ParamSpec),
            )
            # materialize fresh zero states (m-stabilizers start at -inf-ish)
            zero["mlstm"]["m"] = jnp.full_like(zero["mlstm"]["m"], -1e30)
            zero["slstm"]["n"] = jnp.ones_like(zero["slstm"]["n"])
            cache_xs = jax.tree.map(
                lambda z: jnp.broadcast_to(z[None], (self.n_groups,) + z.shape), zero
            )
        else:
            cache_xs = cache
        x, new_cache = jax.lax.scan(fn, x, (params["groups"], cache_xs))
        return x, new_cache

    def loss(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        inputs, targets = tokens[:, :-1], tokens[:, 1:]
        x = ll.embed(params["embed"], inputs, jnp.dtype(cfg.dtype))
        x, _ = self.backbone(params, x, train=True)
        logits = ll.unembed(params["embed"], x, cfg)
        mask = batch.get("loss_mask", jnp.ones_like(targets, jnp.float32))
        return ll.softmax_xent(logits, targets, mask)

    def prefill(self, params, batch, cache):
        x = ll.embed(params["embed"], batch["tokens"], jnp.dtype(self.cfg.dtype))
        x, new_cache = self.backbone(params, x, cache=cache)
        return ll.unembed(params["embed"], x[:, -1:], self.cfg), new_cache

    def decode(self, params, batch, cache):
        x = ll.embed(params["embed"], batch["token"], jnp.dtype(self.cfg.dtype))
        x, new_cache = self.backbone(params, x, cache=cache, single_step=True)
        return ll.unembed(params["embed"], x, self.cfg), new_cache


class ZambaModel:
    def __init__(self, cfg):
        self.cfg = cfg
        assert cfg.n_layers % cfg.attn_every == 0
        self.n_groups = cfg.n_layers // cfg.attn_every
        self.m_per_group = cfg.attn_every

    def param_specs(self):
        cfg = self.cfg
        group = {"mamba": _stack_specs(ssm.mamba2_specs(cfg), self.m_per_group)}
        shared = {
            "ln": ll.rmsnorm_spec(cfg.d_model),
            "attn": ll.attention_specs(cfg),
            "ln2": ll.rmsnorm_spec(cfg.d_model),
            "mlp": ll.mlp_specs(cfg),
        }
        return {
            "embed": ll.embed_specs(cfg),
            "groups": _stack_specs(group, self.n_groups),
            "shared_attn": shared,
        }

    def cache_specs(self, batch: int, seq: int):
        g, m = self.n_groups, self.m_per_group
        return {
            "mamba": ssm.mamba2_state_specs(self.cfg, batch, lead=(g, m), lead_axes=("layers", "layers")),
            "kv": ll.cache_specs(self.cfg, batch, seq, layers=g),
        }

    def backbone(self, params, x, q_pos, cache=None, train=False, single_step=False):
        cfg = self.cfg
        shared = params["shared_attn"]

        def body(carry, xs):
            x = carry
            gp, gc = xs

            def mbody(h, mxs):
                lp, lc = mxs
                y, st = ssm.mamba2(lp, h, cfg, state=lc, single_step=single_step)
                return h + y, st

            x, m_states = jax.lax.scan(mbody, x, (gp["mamba"], gc["mamba"] if gc else None))
            # shared (weight-tied) attention block, own KV per invocation
            h, new_kv = ll.attention(
                shared["attn"], ll.rmsnorm(x, shared["ln"], cfg.norm_eps), cfg, q_pos,
                cache=gc["kv"] if gc else None,
            )
            x = x + h
            x = x + ll.mlp(shared["mlp"], ll.rmsnorm(x, shared["ln2"], cfg.norm_eps))
            return x, {"mamba": m_states, "kv": new_kv}

        fn = jax.checkpoint(body) if train else body
        if cache is None:
            B = x.shape[0]
            zero_m = jax.tree.map(
                lambda s: jnp.zeros((self.n_groups,) + s.shape, s.dtype),
                ssm.mamba2_state_specs(cfg, B, lead=(self.m_per_group,), lead_axes=("layers",)),
                is_leaf=lambda t: isinstance(t, ParamSpec),
            )
            cache_xs = {"mamba": zero_m, "kv": None}
            x, states = jax.lax.scan(
                lambda c, xs: fn(c, (xs[0], {"mamba": xs[1]["mamba"], "kv": None})),
                x,
                (params["groups"], cache_xs),
            )
            return x, None
        x, new_cache = jax.lax.scan(fn, x, (params["groups"], cache))
        return x, new_cache

    def loss(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        inputs, targets = tokens[:, :-1], tokens[:, 1:]
        x = ll.embed(params["embed"], inputs, jnp.dtype(cfg.dtype))
        B, S = x.shape[:2]
        q_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x, _ = self.backbone(params, x, q_pos, train=True)
        logits = ll.unembed(params["embed"], x, cfg)
        mask = batch.get("loss_mask", jnp.ones_like(targets, jnp.float32))
        return ll.softmax_xent(logits, targets, mask)

    def prefill(self, params, batch, cache):
        cfg = self.cfg
        x = ll.embed(params["embed"], batch["tokens"], jnp.dtype(cfg.dtype))
        B, S = x.shape[:2]
        q_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x, new_cache = self.backbone(params, x, q_pos, cache=cache)
        return ll.unembed(params["embed"], x[:, -1:], cfg), new_cache

    def decode(self, params, batch, cache):
        cfg = self.cfg
        x = ll.embed(params["embed"], batch["token"], jnp.dtype(cfg.dtype))
        B = x.shape[0]
        q_pos = jnp.broadcast_to(batch["pos"].astype(jnp.int32).reshape(1, 1), (B, 1))
        x, new_cache = self.backbone(params, x, q_pos, cache=cache, single_step=True)
        return ll.unembed(params["embed"], x, cfg), new_cache
