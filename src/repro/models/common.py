"""Parameter-spec trees: one model definition drives init, sharding, dry-run.

A model is described as a pytree of ``ParamSpec`` leaves (shape + dtype +
logical axes). From that single description we derive:

  - materialized parameters for CPU smoke tests / real training (``init``),
  - ``jax.ShapeDtypeStruct`` stand-ins + ``NamedSharding`` for the
    allocation-free multi-pod dry-run (``abstract``),
  - in/out shardings for pjit (``shardings``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.rules import MeshRules


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple  # logical axis names, len == len(shape)
    dtype: Any = jnp.float32
    init: str = "normal"  # normal | zeros | ones
    scale: float | None = None  # None -> 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def n_params(tree) -> int:
    return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(tree, is_leaf=_is_spec))


def _is_spec(x):
    return isinstance(x, ParamSpec)


def init_params(tree, key: jax.Array, dtype_override=None):
    """Materialize a ParamSpec tree into real arrays (smoke tests/training)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for spec, k in zip(leaves, keys):
        dt = dtype_override or spec.dtype
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, dt))
        elif spec.init == "ones":
            out.append(jnp.full(spec.shape, spec.scale if spec.scale is not None else 1, dt))
        else:
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            scale = spec.scale if spec.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(k, spec.shape, jnp.float32) * scale).astype(dt))
    return jax.tree.unflatten(treedef, out)


def abstract_params(tree, rules: MeshRules, dtype_override=None):
    """ShapeDtypeStruct tree with shardings — zero allocation (dry-run path)."""

    def one(spec: ParamSpec):
        dt = dtype_override or spec.dtype
        return jax.ShapeDtypeStruct(
            spec.shape, dt, sharding=rules.sharding(spec.axes, spec.shape)
        )

    return jax.tree.map(one, tree, is_leaf=_is_spec)


def param_shardings(tree, rules: MeshRules):
    return jax.tree.map(
        lambda s: rules.sharding(s.axes, s.shape), tree, is_leaf=_is_spec
    )


def param_specs_pspec(tree, rules: MeshRules):
    """PartitionSpec tree (for use as jit in_shardings)."""
    return jax.tree.map(lambda s: rules.spec(s.axes, s.shape), tree, is_leaf=_is_spec)
