"""Transformer building blocks: RMSNorm, RoPE, GQA attention, SwiGLU MLP.

All functions are pure; parameters are dicts of arrays built from
``ParamSpec`` trees (see common.py). Attention is blockwise (flash-style):
exact softmax per q-block against full KV with a checkpointed block body,
so the S×S score matrix is never materialized and backward recomputes
per-block scores — the pure-JAX shape of the memory-efficient attention
XLA:TPU fuses well.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec

BIG_POS = 1 << 30  # kv_position sentinel for unfilled cache slots


# ---------------------------------------------------------------- norms/rope
def rmsnorm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), ("embed",), init="ones")


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    # mean-square reduces in f32, but the (B,S,d)-wide multiplies stay in the
    # input dtype: an f32 x-wide intermediate makes XLA sink the convert into
    # the layer-residual stack, storing per-layer activations in f32 (2× HBM;
    # EXPERIMENTS.md §Perf granite iteration 3).
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jax.lax.rsqrt(ms + eps).astype(x.dtype)
    return x * scale * w.astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x (B, S, H, hd), positions (B, S) -> rotated x."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[:, :, None].astype(jnp.float32) * freqs[None, None, :]  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention
def attention_specs(cfg, cross: bool = False) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    p = {
        "wq": ParamSpec((d, H, hd), ("embed", "heads", None)),
        "wk": ParamSpec((d, KV, hd), ("embed", "kv_heads", None)),
        "wv": ParamSpec((d, KV, hd), ("embed", "kv_heads", None)),
        "wo": ParamSpec((H, hd, d), ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamSpec((H, hd), ("heads", None), init="zeros")
        p["bk"] = ParamSpec((KV, hd), ("kv_heads", None), init="zeros")
        p["bv"] = ParamSpec((KV, hd), ("kv_heads", None), init="zeros")
    return p


def _pick_kv_block(skv: int) -> int:
    for b in (1024, 512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if skv % b == 0:
            return b
    return 1


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _flash(q, k, v, q_pos, kv_pos, causal: bool, kv_block: int):
    out, _ = _flash_fwd_impl(q, k, v, q_pos, kv_pos, causal, kv_block)
    return out


def _flash_fwd_impl(q, k, v, q_pos, kv_pos, causal, kv_block):
    """Online-softmax over KV blocks: the (Sq, Skv) score matrix never
    materializes (per-block (Sq, kvb) tiles only) — flash attention in
    pure JAX, with a custom VJP so the backward recomputes tiles instead
    of saving per-block scan carries (§Perf: attention was the dominant
    HBM term for every full-attention train/prefill cell)."""
    B, Sq, H, hd = q.shape
    scale = hd ** -0.5
    nb = k.shape[1] // kv_block
    qf = q.astype(jnp.float32)
    ks = k.reshape(B, nb, kv_block, H, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nb, kv_block, H, hd).transpose(1, 0, 2, 3, 4)
    ps = kv_pos.reshape(B, nb, kv_block).transpose(1, 0, 2)

    def step(carry, xs):
        m, l, acc = carry
        kb, vb, pb = xs
        s = jnp.einsum("bqhd,bshd->bqhs", qf, kb.astype(jnp.float32)) * scale
        mask = (pb[:, None, :] <= q_pos[:, :, None]) if causal else (pb[:, None, :] < BIG_POS)
        s = jnp.where(mask[:, :, None, :], s, -1e30)
        m2 = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m2)
        p = jnp.exp(s - m2[..., None])
        l2 = l * corr + p.sum(axis=-1)
        acc2 = acc * corr[..., None] + jnp.einsum("bqhs,bshd->bqhd", p, vb.astype(jnp.float32))
        return (m2, l2, acc2), None

    m0 = jnp.full((B, Sq, H), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Sq, H), jnp.float32)
    a0 = jnp.zeros((B, Sq, H, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (ks, vs, ps))
    l = jnp.maximum(l, 1e-30)
    out = (acc / l[..., None]).astype(q.dtype)
    lse = m + jnp.log(l)
    return out, lse


def _flash_fwd(q, k, v, q_pos, kv_pos, causal, kv_block):
    out, lse = _flash_fwd_impl(q, k, v, q_pos, kv_pos, causal, kv_block)
    return out, (q, k, v, q_pos, kv_pos, out, lse)


def _flash_bwd(causal, kv_block, res, do):
    q, k, v, q_pos, kv_pos, out, lse = res
    B, Sq, H, hd = q.shape
    scale = hd ** -0.5
    nb = k.shape[1] // kv_block
    qf = q.astype(jnp.float32)
    dof = do.astype(jnp.float32)
    delta = (dof * out.astype(jnp.float32)).sum(axis=-1)  # (B,Sq,H)
    ks = k.reshape(B, nb, kv_block, H, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nb, kv_block, H, hd).transpose(1, 0, 2, 3, 4)
    ps = kv_pos.reshape(B, nb, kv_block).transpose(1, 0, 2)

    def step(dq, xs):
        kb, vb, pb = xs
        s = jnp.einsum("bqhd,bshd->bqhs", qf, kb.astype(jnp.float32)) * scale
        mask = (pb[:, None, :] <= q_pos[:, :, None]) if causal else (pb[:, None, :] < BIG_POS)
        s = jnp.where(mask[:, :, None, :], s, -1e30)
        p = jnp.exp(s - lse[..., None])  # exact softmax via saved lse
        dp = jnp.einsum("bqhd,bshd->bqhs", dof, vb.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale
        dqb = jnp.einsum("bqhs,bshd->bqhd", ds, kb.astype(jnp.float32))
        dkb = jnp.einsum("bqhs,bqhd->bshd", ds, qf)
        dvb = jnp.einsum("bqhs,bqhd->bshd", p, dof)
        return dq + dqb, (dkb, dvb)

    dq0 = jnp.zeros((B, Sq, H, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(step, dq0, (ks, vs, ps))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, nb * kv_block, H, hd).astype(k.dtype)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, nb * kv_block, H, hd).astype(v.dtype)
    import numpy as _np

    zpos_q = _np.zeros(q_pos.shape, jax.dtypes.float0)
    zpos_kv = _np.zeros(kv_pos.shape, jax.dtypes.float0)
    return dq.astype(q.dtype), dk, dv, zpos_q, zpos_kv


_flash.defvjp(_flash_fwd, _flash_bwd)


def _attn_core(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Skv, KV, hd)
    v: jnp.ndarray,
    q_pos: jnp.ndarray,  # (B, Sq)
    kv_pos: jnp.ndarray,  # (B, Skv); unfilled slots = BIG_POS
    causal: bool,
    q_block: int = 256,
) -> jnp.ndarray:
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV

    if Sq == 1:
        # decode: one exact softmax over the (seq-sharded) cache, grouped-KV
        # form — repeating kv heads here would materialize g× the cache,
        # whereas the score tensor is tiny; memory-bound by the single cache
        # read, which *is* the decode roofline.
        scale = hd ** -0.5
        qg = q.reshape(B, 1, KV, g, hd)
        s = jnp.einsum("bqkgh,bskh->bqkgs", qg.astype(jnp.float32), k.astype(jnp.float32)) * scale
        mask = (kv_pos[:, None, :] <= q_pos[:, :, None]) if causal else (kv_pos[:, None, :] < BIG_POS)
        s = jnp.where(mask[:, :, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bqkgs,bskh->bqkgh", p, v.astype(jnp.float32))
        return out.reshape(B, 1, H, hd).astype(q.dtype)

    # train/prefill: score on the flat H dim — a (KV, g) reshape would leave
    # the head axis unshardable whenever kv_heads < |model| (GSPMD then
    # replicates every device's scores — 16× attention HBM on kv=8 archs).
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    return _flash(q, k, v, q_pos, kv_pos, causal, _pick_kv_block(k.shape[1]))


def attention(
    p: dict,
    x: jnp.ndarray,  # (B, Sq, d)
    cfg,
    q_pos: jnp.ndarray,
    *,
    kv_x: jnp.ndarray | None = None,  # cross-attention memory
    kv_pos: jnp.ndarray | None = None,
    cache: dict | None = None,  # {"k","v","pos"} decode/prefill cache
    use_rope: bool = True,
    causal: bool = True,
) -> tuple[jnp.ndarray, dict | None]:
    """Returns (out (B, Sq, d), updated cache or None)."""
    src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if kv_x is None:
        kpos = q_pos if kv_pos is None else kv_pos
    else:
        kpos = kv_pos
    if use_rope and kv_x is None:
        q = rope(q, q_pos, cfg.rope_theta)
        k = rope(k, kpos, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        # write this step's k/v at the slot(s) given by q_pos (decode: Sq==1)
        idx = q_pos[0, 0]  # uniform position across batch (serving layout)
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0))
        cpos = jax.lax.dynamic_update_slice(
            cache["pos"], jnp.broadcast_to(q_pos, cache["pos"][:, : q_pos.shape[1]].shape), (0, idx)
        )
        new_cache = {"k": ck, "v": cv, "pos": cpos}
        k, v, kpos = ck, cv, cpos

    out = _attn_core(q, k, v, q_pos, kpos, causal=causal)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, new_cache


def cache_specs(cfg, batch: int, seq: int, layers: int | None = None) -> dict:
    """KV-cache ParamSpec tree. Sequence axis is SP-sharded (flash-decode:
    per-shard partial softmax merged by XLA collectives)."""
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    L = cfg.n_layers if layers is None else layers
    lead = (L,) if L else ()
    lax = ("layers",) if L else ()
    return {
        "k": ParamSpec(lead + (batch, seq, KV, hd), lax + ("batch", "seq_kv", "kv_heads", None), init="zeros"),
        "v": ParamSpec(lead + (batch, seq, KV, hd), lax + ("batch", "seq_kv", "kv_heads", None), init="zeros"),
        "pos": ParamSpec(lead + (batch, seq), lax + ("batch", "seq_kv"), dtype=jnp.int32, init="ones", scale=float(BIG_POS)),
    }


# ---------------------------------------------------------------- MLP
def mlp_specs(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "wi": ParamSpec((d, f), ("embed", "ff")),
        "wg": ParamSpec((d, f), ("embed", "ff")),
        "wo": ParamSpec((f, d), ("ff", "embed")),
    }


def mlp(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ p["wg"].astype(x.dtype)) * (x @ p["wi"].astype(x.dtype))
    return h @ p["wo"].astype(x.dtype)


# ---------------------------------------------------------------- embeddings
def embed_specs(cfg) -> dict:
    return {
        "tok": ParamSpec((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"), scale=1.0),
        "norm_f": rmsnorm_spec(cfg.d_model),
        "head": ParamSpec((cfg.d_model, cfg.padded_vocab), ("embed", "vocab")),
    }


def embed(p: dict, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    return jnp.take(p["tok"], tokens, axis=0).astype(dtype)


def unembed(p: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    x = rmsnorm(x, p["norm_f"], cfg.norm_eps)
    return x @ p["head"].astype(x.dtype)  # (B, S, padded_vocab), vocab-sharded


def softmax_xent(logits: jnp.ndarray, targets: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token CE in fp32; ``mask`` zeroes padding/image positions."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    loss = (lse - gold) * mask
    return loss.sum() / jnp.maximum(mask.sum(), 1)
