"""Mixture-of-Experts FFN: top-k routing with expert parallelism.

TPU-native dispatch: tokens are routed by a stable sort on expert id
(gather), processed by the expert-sharded FFN batch, and combined by a
scatter-add — O(T·k·d) data movement instead of the O(T·E·C·d) one-hot
dispatch einsum of GShard. Capacity-bounded (tokens over capacity are
dropped, standard for capacity-factor routers); an auxiliary load-balance
loss (Switch-style) is returned alongside.

Two dispatch paths:

  - ``moe_ffn``        — plain jit/GSPMD path (single device, smoke tests).
  - ``_moe_sharded``   — shard_map path, chosen automatically when an
    ambient mesh with a ``model`` axis is set. Routing is computed
    *replicated* per data shard (deterministic, no comms); each model shard
    gathers only its own experts' capacity buffers locally and the combine
    ends in one ``psum`` over ``model`` — the same single all-reduce a
    row-parallel dense MLP pays. This replaced a global argsort dispatch
    whose cross-device sort made granite_moe train 238 s collective-bound
    (EXPERIMENTS.md §Perf hillclimb #2: 238 s -> ~0.1 s collective term).

Experts shard over ``model`` (phi3.5: 16e/16-way = 1 expert per shard;
granite: 32e = 2 per shard).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import get_abstract_mesh, shard_map
from repro.models.common import ParamSpec


def moe_specs(cfg) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamSpec((d, E), ("embed", None)),
        "wi": ParamSpec((E, d, f), ("experts", "embed", "ff")),
        "wg": ParamSpec((E, d, f), ("experts", "embed", "ff")),
        "wo": ParamSpec((E, f, d), ("experts", "ff", "embed")),
    }


def _ambient_moe_axes(cfg, batch: int):
    """(data_axes, model_axis) if the ambient mesh supports sharded dispatch."""
    am = get_abstract_mesh()
    if am is None or getattr(am, "empty", True):
        return None
    names = getattr(am, "axis_names", ())
    if "model" not in names:
        return None
    M = am.shape["model"]
    data_axes = tuple(a for a in ("pod", "data") if a in names)
    D = 1
    for a in data_axes:
        D *= am.shape[a]
    if cfg.n_experts % M or batch % max(D, 1):
        return None
    return data_axes, "model", D, M


def moe_ffn(p: dict, x: jnp.ndarray, cfg) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar). Dispatches to the
    shard_map path when an ambient (data, model) mesh is active."""
    ax = _ambient_moe_axes(cfg, x.shape[0])
    if ax is not None:
        return _moe_sharded(p, x, cfg, *ax)
    return _moe_dense(p, x, cfg)


def _moe_sharded(p, x, cfg, data_axes, model_ax, D, M):
    E, k = cfg.n_experts, cfg.experts_per_token
    e_per = E // M
    B, S, d = x.shape
    T_l = (B // max(D, 1)) * S
    cap = max(1, int(cfg.capacity_factor * T_l * k / E))

    def body(xb, router, wi, wg, wo):
        # xb (B_l, S, d); router (d, E) replicated; wi/wg/wo (E/M, ...) local
        B_l = xb.shape[0]
        xt = xb.reshape(B_l * S, d)
        logits = (xt @ router.astype(xt.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, eidx = jax.lax.top_k(probs, k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

        me = probs.mean(axis=0)
        ce = jnp.zeros(E, jnp.float32).at[eidx.reshape(-1)].add(1.0) / (B_l * S * k)
        aux = (me * ce).sum() * E
        aux = jax.lax.pmean(aux, data_axes) if data_axes else aux

        # keep only this model shard's experts, then local sort-dispatch.
        # All O(T·k) work stays on int32/f32 *index* arrays; the d-wide
        # tensors are touched only at slot granularity (E/M × cap rows) —
        # §Perf iteration 2: per-assignment-width buffers were 12.8× larger.
        my0 = jax.lax.axis_index(model_ax) * e_per
        flat_e = eidx.reshape(-1)
        flat_gate = gate.reshape(-1)
        src = jnp.repeat(jnp.arange(B_l * S), k)
        mine = (flat_e >= my0) & (flat_e < my0 + e_per)
        local_e = jnp.where(mine, flat_e - my0, e_per)  # foreign -> trash expert
        order = jnp.argsort(local_e, stable=True)
        e_sorted = local_e[order]
        starts = jnp.searchsorted(e_sorted, jnp.arange(e_per + 1))
        pos = jnp.arange(e_sorted.shape[0]) - starts[jnp.clip(e_sorted, 0, e_per)]
        keep = (e_sorted < e_per) & (pos < cap)
        slot = jnp.where(keep, e_sorted * cap + pos, e_per * cap)

        ns = e_per * cap
        tok_for_slot = jnp.zeros(ns + 1, jnp.int32).at[slot].set(src[order].astype(jnp.int32))
        gate_for_slot = (
            jnp.zeros(ns + 1, jnp.float32).at[slot].set(jnp.where(keep, flat_gate[order], 0.0))
        )[:ns]
        xin = xt[tok_for_slot[:ns]].reshape(e_per, cap, d)  # slot-granular gather

        def expert(we_i, we_g, we_o, h):
            a = jax.nn.silu(h @ we_g.astype(h.dtype)) * (h @ we_i.astype(h.dtype))
            return a @ we_o.astype(h.dtype)

        hout = jax.vmap(expert)(wi, wg, wo, xin)  # (E/M, cap, d)
        contrib = hout.reshape(ns, d) * gate_for_slot[:, None].astype(xb.dtype)
        out = jnp.zeros((B_l * S, d), xb.dtype).at[tok_for_slot[:ns]].add(contrib)
        out = jax.lax.psum(out, model_ax)  # merge expert shards (row-parallel)
        return out.reshape(B_l, S, d), aux

    dspec = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)
    out, aux = shard_map(
        body,
        in_specs=(P(dspec, None, None), P(), P("model"), P("model"), P("model")),
        out_specs=(P(dspec, None, None), P()),
    )(x, p["router"], p["wi"], p["wg"], p["wo"])
    return out, aux


def _moe_dense(p: dict, x: jnp.ndarray, cfg) -> tuple[jnp.ndarray, jnp.ndarray]:
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)  # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: fraction of tokens per expert × mean router prob
    me = probs.mean(axis=0)
    ce = jnp.zeros(E, jnp.float32).at[eidx.reshape(-1)].add(1.0) / (T * k)
    aux = (me * ce).sum() * E

    cap = int(cfg.capacity_factor * T * k / E)
    cap = max(cap, 1)

    flat_e = eidx.reshape(-1)  # (T*k,)
    flat_gate = gate.reshape(-1)
    src = jnp.repeat(jnp.arange(T), k)

    order = jnp.argsort(flat_e, stable=True)  # group by expert
    e_sorted = flat_e[order]
    starts = jnp.searchsorted(e_sorted, jnp.arange(E))
    pos = jnp.arange(T * k) - starts[e_sorted]  # slot within expert
    keep = pos < cap
    slot = jnp.where(keep, e_sorted * cap + pos, E * cap)  # overflow -> trash row

    xin = jnp.zeros((E * cap + 1, d), x.dtype).at[slot].set(xt[src[order]])
    xin = xin[: E * cap].reshape(E, cap, d)

    def expert(we_i, we_g, we_o, h):
        a = jax.nn.silu(h @ we_g.astype(h.dtype)) * (h @ we_i.astype(h.dtype))
        return a @ we_o.astype(h.dtype)

    hout = jax.vmap(expert)(p["wi"], p["wg"], p["wo"], xin)  # (E, cap, d)
    hflat = jnp.concatenate([hout.reshape(E * cap, d), jnp.zeros((1, d), x.dtype)])

    contrib = hflat[slot] * flat_gate[order][:, None].astype(x.dtype)
    out = jnp.zeros((T, d), x.dtype).at[src[order]].add(jnp.where(keep[:, None], contrib, 0))
    return out.reshape(B, S, d), aux
