"""Encoder-decoder model (SeamlessM4T-v2 backbone; audio frontend is a stub).

Encoder: bidirectional transformer over precomputed frame embeddings.
Decoder: causal self-attention + cross-attention over encoder memory.
Cross-attention K/V are computed once at prefill and cached (production
serving layout); decode steps touch only the self-attn cache + cached
cross K/V.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as ll
from repro.models.common import ParamSpec
from repro.models.transformer import _stack_specs


class EncDecModel:
    def __init__(self, cfg):
        self.cfg = cfg

    def param_specs(self):
        cfg = self.cfg
        d = cfg.d_model
        enc_layer = {
            "ln1": ll.rmsnorm_spec(d),
            "attn": ll.attention_specs(cfg),
            "ln2": ll.rmsnorm_spec(d),
            "mlp": ll.mlp_specs(cfg),
        }
        dec_layer = {
            "ln1": ll.rmsnorm_spec(d),
            "self_attn": ll.attention_specs(cfg),
            "lnx": ll.rmsnorm_spec(d),
            "cross_attn": ll.attention_specs(cfg),
            "ln2": ll.rmsnorm_spec(d),
            "mlp": ll.mlp_specs(cfg),
        }
        return {
            "embed": ll.embed_specs(cfg),
            "frontend_proj": {
                "w": ParamSpec((d, d), ("embed", None)),
                "b": ParamSpec((d,), (None,), init="zeros"),
            },
            "enc_norm": ll.rmsnorm_spec(d),
            "encoder": _stack_specs(enc_layer, cfg.encoder_layers),
            "decoder": _stack_specs(dec_layer, cfg.n_layers),
        }

    def cache_specs(self, batch: int, seq: int, mem_len: int | None = None):
        cfg = self.cfg
        mem = mem_len if mem_len is not None else max(seq // 4, 1)
        KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        L = cfg.n_layers
        return {
            "kv": ll.cache_specs(cfg, batch, seq),
            "ck": ParamSpec((L, batch, mem, KV, hd), ("layers", "batch", "seq_kv", "kv_heads", None), init="zeros"),
            "cv": ParamSpec((L, batch, mem, KV, hd), ("layers", "batch", "seq_kv", "kv_heads", None), init="zeros"),
        }

    # ----------------------------------------------------------------- enc
    def encode(self, params, frames):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = frames.astype(dt) @ params["frontend_proj"]["w"].astype(dt) + params["frontend_proj"]["b"].astype(dt)
        B, S = x.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

        def body(x, lp):
            h, _ = ll.attention(lp["attn"], ll.rmsnorm(x, lp["ln1"], cfg.norm_eps), cfg, pos, causal=False)
            x = x + h
            return x + ll.mlp(lp["mlp"], ll.rmsnorm(x, lp["ln2"], cfg.norm_eps)), None

        x, _ = jax.lax.scan(body, x, params["encoder"])
        return ll.rmsnorm(x, params["enc_norm"], cfg.norm_eps)

    def _cross_kv(self, lp, memory):
        k = jnp.einsum("bsd,dhk->bshk", memory, lp["cross_attn"]["wk"].astype(memory.dtype))
        v = jnp.einsum("bsd,dhk->bshk", memory, lp["cross_attn"]["wv"].astype(memory.dtype))
        return k, v

    # ----------------------------------------------------------------- dec
    def _dec_layer(self, lp, x, q_pos, mem_or_kv, kv_cache, train):
        cfg = self.cfg
        h, new_kv = ll.attention(
            lp["self_attn"], ll.rmsnorm(x, lp["ln1"], cfg.norm_eps), cfg, q_pos, cache=kv_cache
        )
        x = x + h
        xn = ll.rmsnorm(x, lp["lnx"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", xn, lp["cross_attn"]["wq"].astype(x.dtype))
        if isinstance(mem_or_kv, tuple):
            ck, cv = mem_or_kv
        else:
            ck, cv = self._cross_kv(lp, mem_or_kv)
        mem_pos = jnp.broadcast_to(
            jnp.arange(ck.shape[1], dtype=jnp.int32)[None], (ck.shape[0], ck.shape[1])
        )
        o = ll._attn_core(q, ck, cv, q_pos, mem_pos, causal=False)
        o = jnp.einsum("bshk,hkd->bsd", o, lp["cross_attn"]["wo"].astype(x.dtype))
        x = x + o
        return x + ll.mlp(lp["mlp"], ll.rmsnorm(x, lp["ln2"], cfg.norm_eps)), new_kv, (ck, cv)

    def decode_stack(self, params, x, q_pos, memory=None, cache=None, train=False):
        cfg = self.cfg

        def body(carry, xs):
            x = carry
            lp, lc = xs
            kv_cache = lc["kv"] if lc is not None else None
            mem = (lc["ck"], lc["cv"]) if (lc is not None and memory is None) else memory
            x, new_kv, (ck, cv) = self._dec_layer(lp, x, q_pos, mem, kv_cache, train)
            ys = {"kv": new_kv, "ck": ck, "cv": cv} if lc is not None else None
            return x, ys

        fn = jax.checkpoint(body) if train else body
        if cache is None:
            x, _ = jax.lax.scan(lambda c, lp: fn(c, (lp, None)), x, params["decoder"])
            return x, None
        x, new_cache = jax.lax.scan(fn, x, (params["decoder"], cache))
        return x, new_cache

    # ------------------------------------------------------------- task fns
    def loss(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        inputs, targets = tokens[:, :-1], tokens[:, 1:]
        memory = self.encode(params, batch["frames"])
        x = ll.embed(params["embed"], inputs, jnp.dtype(cfg.dtype))
        B, S = x.shape[:2]
        q_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x, _ = self.decode_stack(params, x, q_pos, memory=memory, train=True)
        logits = ll.unembed(params["embed"], x, cfg)
        mask = batch.get("loss_mask", jnp.ones_like(targets, jnp.float32))
        return ll.softmax_xent(logits, targets, mask)

    def prefill(self, params, batch, cache):
        cfg = self.cfg
        memory = self.encode(params, batch["frames"])
        x = ll.embed(params["embed"], batch["tokens"], jnp.dtype(cfg.dtype))
        B, S = x.shape[:2]
        q_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x, new_cache = self.decode_stack(params, x, q_pos, memory=memory, cache=cache)
        return ll.unembed(params["embed"], x[:, -1:], cfg), new_cache

    def decode(self, params, batch, cache):
        cfg = self.cfg
        x = ll.embed(params["embed"], batch["token"], jnp.dtype(cfg.dtype))
        B = x.shape[0]
        q_pos = jnp.broadcast_to(batch["pos"].astype(jnp.int32).reshape(1, 1), (B, 1))
        x, new_cache = self.decode_stack(params, x, q_pos, memory=None, cache=cache)
        return ll.unembed(params["embed"], x, cfg), new_cache
