"""Decoder-only LM covering the dense / moe / vlm families.

Layers run under ``lax.scan`` (stacked params: leading (L,) dim) with
per-layer ``jax.checkpoint`` in training — compile time and live-activation
memory stay O(1) in depth. The VLM variant prepends connector-projected
patch embeddings (frontend stub per the assignment).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as ll
from repro.models.common import ParamSpec
from repro.models.moe import moe_ffn, moe_specs


def _stack_specs(spec_tree: dict, n: int) -> dict:
    """Give every leaf a leading (n,) 'layers' axis."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.dtype, s.init, s.scale),
        spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


class DecoderLM:
    def __init__(self, cfg):
        self.cfg = cfg

    # ---------------------------------------------------------------- specs
    def layer_specs(self) -> dict:
        cfg = self.cfg
        d = cfg.d_model
        p = {
            "ln1": ll.rmsnorm_spec(d),
            "attn": ll.attention_specs(cfg),
            "ln2": ll.rmsnorm_spec(d),
        }
        if cfg.n_experts:
            p["moe"] = moe_specs(cfg)
        else:
            p["mlp"] = ll.mlp_specs(cfg)
        return p

    def param_specs(self) -> dict:
        cfg = self.cfg
        p = {
            "embed": ll.embed_specs(cfg),
            "layers": _stack_specs(self.layer_specs(), cfg.n_layers),
        }
        if cfg.frontend == "vision":
            p["connector"] = {
                "w": ParamSpec((cfg.d_model, cfg.d_model), ("embed", None)),
                "b": ParamSpec((cfg.d_model,), (None,), init="zeros"),
            }
        return p

    def cache_specs(self, batch: int, seq: int) -> dict:
        return {"kv": ll.cache_specs(self.cfg, batch, seq)}

    # -------------------------------------------------------------- forward
    def _layer(self, p, x, q_pos, cache, train: bool):
        cfg = self.cfg
        h, new_cache = ll.attention(
            p["attn"], ll.rmsnorm(x, p["ln1"], cfg.norm_eps), cfg, q_pos, cache=cache
        )
        x = x + h
        hn = ll.rmsnorm(x, p["ln2"], cfg.norm_eps)
        if cfg.n_experts:
            h, aux = moe_ffn(p["moe"], hn, cfg)
        else:
            h, aux = ll.mlp(p["mlp"], hn), jnp.float32(0)
        return x + h, new_cache, aux

    def backbone(self, params, x, q_pos, cache=None, train=False):
        cfg = self.cfg

        def body(carry, xs):
            x, aux = carry
            lp, lc = xs
            x, new_c, a = self._layer(lp, x, q_pos, lc, train)
            return (x, aux + a), new_c

        fn = jax.checkpoint(body) if train else body
        lc = cache["kv"] if cache is not None else None
        if lc is None:
            lc_xs = None
            (x, aux), _ = jax.lax.scan(lambda c, lp: fn(c, (lp, None)), (x, jnp.float32(0)), params["layers"])
            new_cache = None
        else:
            (x, aux), new_kv = jax.lax.scan(fn, (x, jnp.float32(0)), (params["layers"], lc))
            new_cache = {"kv": new_kv}
        return x, aux, new_cache

    def logits(self, params, x):
        return ll.unembed(params["embed"], x, self.cfg)

    def embed_inputs(self, params, tokens, patches=None):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = ll.embed(params["embed"], tokens, dt)
        if patches is not None:
            px = patches.astype(dt) @ params["connector"]["w"].astype(dt) + params["connector"]["b"].astype(dt)
            x = jnp.concatenate([px, x], axis=1)
        return x

    # ------------------------------------------------------------ task fns
    def loss(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        inputs, targets = tokens[:, :-1], tokens[:, 1:]
        patches = batch.get("patches")
        x = self.embed_inputs(params, inputs, patches)
        B, S = x.shape[0], x.shape[1]
        q_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x, aux, _ = self.backbone(params, x, q_pos, train=True)
        if patches is not None:
            x = x[:, patches.shape[1] :]
        logits = self.logits(params, x)
        mask = batch.get("loss_mask", jnp.ones_like(targets, jnp.float32))
        return ll.softmax_xent(logits, targets, mask) + 0.01 * aux

    def prefill(self, params, batch, cache):
        tokens = batch["tokens"]
        patches = batch.get("patches")
        x = self.embed_inputs(params, tokens, patches)
        B, S = x.shape[0], x.shape[1]
        q_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x, _, new_cache = self.backbone(params, x, q_pos, cache=cache)
        return self.logits(params, x[:, -1:]), new_cache

    def decode(self, params, batch, cache):
        token, pos = batch["token"], batch["pos"]  # (B,1), scalar int32
        x = self.embed_inputs(params, token)
        B = x.shape[0]
        q_pos = jnp.broadcast_to(pos.astype(jnp.int32).reshape(1, 1), (B, 1))
        x, _, new_cache = self.backbone(params, x, q_pos, cache=cache)
        return self.logits(params, x), new_cache
