"""train_step factory: loss -> grads -> (optional compression) -> AdamW.

The returned step is a single jitted function whose input/output shardings
implement DP (+pod) × TP × ZeRO-1; remat happens per layer inside the model
(scan + jax.checkpoint). Gradient compression (error-feedback int8/top-k)
simulates the slow-axis reduction numerics and is covered by tests.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.training import compress as gc
from repro.training.optim import OptConfig, adamw_update, init_opt_state, moment_specs
from repro.models.common import param_shardings
from repro.sharding.rules import MeshRules


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    compression: str | None = None  # None | int8 | topk
    topk_frac: float = 0.05


def make_train_state(model, key, train_cfg: TrainConfig, rules: MeshRules | None = None):
    from repro.models.common import init_params

    params = init_params(model.param_specs(), key)
    state = {
        "params": params,
        "opt": init_opt_state(params),
        "rng": jax.random.PRNGKey(0),
    }
    if train_cfg.compression:
        state["residuals"] = gc.init_residuals(params)
    return state


def make_train_step(model, train_cfg: TrainConfig, rules: MeshRules | None = None):
    mom_shardings = None
    if rules is not None:
        mspecs = moment_specs(model.param_specs(), rules)
        mom_shardings = param_shardings(mspecs, rules)

    def train_step(state, batch):
        loss, grads = jax.value_and_grad(model.loss)(state["params"], batch)
        rng, sub = jax.random.split(state["rng"])
        if train_cfg.compression:
            grads, new_res = gc.compress_with_feedback(
                grads, state["residuals"], sub, train_cfg.compression, train_cfg.topk_frac
            )
        new_params, new_opt, metrics = adamw_update(
            train_cfg.opt, state["params"], grads, state["opt"], mom_shardings
        )
        new_state = {"params": new_params, "opt": new_opt, "rng": rng}
        if train_cfg.compression:
            new_state["residuals"] = new_res
        metrics = dict(metrics, loss=loss)
        return new_state, metrics

    return train_step
