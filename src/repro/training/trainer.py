"""Training loop: step function + checkpointing + fault handling.

The loop is deliberately framework-grade: async checkpoints every
``ckpt_every`` steps, restart-from-latest on (injected or real) failures,
straggler flagging with a data-pipeline skip hook, and metric logging.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator

import jax
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager
from repro.fault.failures import FailureInjector, StragglerMonitor, run_with_restarts
from repro.training.step import TrainConfig, make_train_state, make_train_step


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    log_every: int = 10
    ckpt_dir: str = "/tmp/repro_ckpt"
    max_restarts: int = 5
    straggler_threshold: float = 3.0


class Trainer:
    def __init__(
        self,
        model,
        train_cfg: TrainConfig,
        loop_cfg: LoopConfig,
        batches: Callable[[], Iterator[dict]],
        rules=None,
        failure_injector: FailureInjector | None = None,
    ):
        self.model = model
        self.train_cfg = train_cfg
        self.loop = loop_cfg
        self.batches = batches
        self.rules = rules
        self.injector = failure_injector
        self.ckpt = CheckpointManager(loop_cfg.ckpt_dir)
        self.monitor = StragglerMonitor(loop_cfg.straggler_threshold)
        self.history: list[dict] = []
        self._step_fn = jax.jit(make_train_step(model, train_cfg, rules))

    def _fresh_state(self):
        return make_train_state(self.model, jax.random.PRNGKey(42), self.train_cfg, self.rules)

    def _run_once(self, start_step: int) -> int:
        if start_step > 0:
            state, extra = self.ckpt.restore()
            state["opt"]["step"] = jax.numpy.asarray(state["opt"]["step"])
        else:
            state = self._fresh_state()
        gen = self.batches()
        # fast-forward the (seeded) generator so data order is reproducible
        for _ in range(start_step):
            next(gen)
        step = start_step
        while step < self.loop.total_steps:
            batch = next(gen)
            if self.injector is not None:
                self.injector.maybe_fail(step)
            t0 = time.perf_counter()
            state, metrics = self._step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            if self.monitor.record(step, dt):
                pass  # mitigation hook: pipeline.skip_slow() on a real cluster
            if step % self.loop.log_every == 0 or step == self.loop.total_steps - 1:
                self.history.append({"step": step, "loss": loss, "dt": dt})
            step += 1
            if step % self.loop.ckpt_every == 0 or step == self.loop.total_steps:
                self.ckpt.save(step - 1, state, extra={"loss": loss}, block=False)
        self.ckpt.wait()
        return step

    def train(self) -> int:
        final = run_with_restarts(
            self._run_once, self.ckpt.latest_step, max_restarts=self.loop.max_restarts
        )
        self.ckpt.wait()
        return final
