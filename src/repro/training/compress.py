"""Gradient compression for the slow (inter-pod) reduction axis.

Two schemes, both with error feedback (the residual of this step's
compression is added to next step's gradient, so compression error does not
accumulate — Karimireddy et al. 2019):

  - int8 quantization with per-tensor scale and stochastic rounding,
  - top-k magnitude sparsification.

``compressed_psum`` is the shard_map building block for a real multi-pod
run: quantize -> integer psum over the pod axis -> dequantize; intra-pod
reductions stay exact. On a single host the same code paths are exercised
by the tests with fake devices.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def int8_compress(g: jnp.ndarray, key: jax.Array) -> tuple[jnp.ndarray, jnp.ndarray]:
    """-> (int8 values, scale). Stochastic rounding keeps E[deq] = g."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    x = g / scale
    noise = jax.random.uniform(key, g.shape, jnp.float32) - 0.5
    q = jnp.clip(jnp.round(x + noise), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def topk_compress(g: jnp.ndarray, frac: float) -> jnp.ndarray:
    """Keep the top-``frac`` fraction by magnitude (dense mask form)."""
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0.0)


def compress_with_feedback(
    grads, residuals, key: jax.Array, scheme: str = "int8", topk_frac: float = 0.05
):
    """grads+residual -> (compressed-then-decompressed grads, new residuals).

    The returned grads are what the slow-axis reduction would deliver; the
    residual tree holds the per-tensor compression error for feedback.
    """
    leaves, td = jax.tree.flatten(grads)
    res = jax.tree.leaves(residuals)
    keys = jax.random.split(key, len(leaves))
    out, new_res = [], []
    for g, r, k in zip(leaves, res, keys):
        x = g.astype(jnp.float32) + r
        if scheme == "int8":
            q, s = int8_compress(x, k)
            y = int8_decompress(q, s)
        elif scheme == "topk":
            y = topk_compress(x, topk_frac)
        else:
            raise ValueError(scheme)
        out.append(y.astype(g.dtype))
        new_res.append(x - y)
    return jax.tree.unflatten(td, out), jax.tree.unflatten(td, new_res)


def compressed_psum(x: jnp.ndarray, axis: str, key: jax.Array) -> jnp.ndarray:
    """shard_map building block: int8-quantized psum over ``axis``."""
    q, scale = int8_compress(x.astype(jnp.float32), key)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis)
    # scales differ per shard: psum the dequantized contribution weight
    return qsum.astype(jnp.float32) * jax.lax.pmax(scale, axis)


def init_residuals(grads_or_params):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_or_params)
