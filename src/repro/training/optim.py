"""AdamW with declarative ZeRO-1 sharding + LR schedules.

ZeRO-1: the optimizer moments carry an *extra* ``data``-axis shard on their
first divisible dimension (on top of the param's TP sharding). XLA then
reduce-scatters gradients into the moment update and all-gathers the param
delta — the ZeRO communication schedule, derived purely from output
shardings instead of hand-written collectives (and hierarchical over
``pod × data`` on the multi-pod mesh).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.common import ParamSpec, _is_spec
from repro.sharding.rules import MeshRules


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def zero_axes(spec: ParamSpec, data_extent: int) -> tuple:
    """Moment logical axes: param axes + 'batch' (=data) on the first
    unsharded dim divisible by the data extent (ZeRO-1 partitioning)."""
    axes = list(spec.axes)
    for i, (ax, size) in enumerate(zip(axes, spec.shape)):
        if ax is None and data_extent > 1 and size % data_extent == 0:
            axes[i] = "batch"
            break
    return tuple(axes)


def moment_specs(param_specs, rules: MeshRules | None) -> Any:
    """ParamSpec tree for m/v with ZeRO-1 axes."""
    extent = 1
    if rules is not None:
        for a in ("pod", "data"):
            extent *= rules.mesh.shape.get(a, 1)

    def one(s: ParamSpec) -> ParamSpec:
        axes = zero_axes(s, extent) if rules is not None else s.axes
        return ParamSpec(s.shape, axes, jnp.float32, init="zeros")

    return jax.tree.map(one, param_specs, is_leaf=_is_spec)


def init_opt_state(params, param_specs=None, rules=None):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(cfg: OptConfig, params, grads, opt_state, moment_shardings=None):
    """One AdamW step; moments optionally pinned to ZeRO shardings."""
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))
    b1, b2 = cfg.betas

    def upd(p, g, m, v, msh=None):
        g = g.astype(jnp.float32) * scale
        m1 = b1 * m + (1 - b1) * g
        v1 = b2 * v + (1 - b2) * g * g
        if msh is not None:
            m1 = jax.lax.with_sharding_constraint(m1, msh)
            v1 = jax.lax.with_sharding_constraint(v1, msh)
        mh = m1 / (1 - b1**step.astype(jnp.float32))
        vh = v1 / (1 - b2**step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m1, v1

    flat_p, td = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    flat_s = jax.tree.leaves(moment_shardings) if moment_shardings is not None else [None] * len(flat_p)
    out_p, out_m, out_v = [], [], []
    for p, g, m, v, s in zip(flat_p, flat_g, flat_m, flat_v, flat_s):
        np_, nm, nv = upd(p, g, m, v, s)
        out_p.append(np_)
        out_m.append(nm)
        out_v.append(nv)
    new_params = jax.tree.unflatten(td, out_p)
    new_state = {"m": jax.tree.unflatten(td, out_m), "v": jax.tree.unflatten(td, out_v), "step": step}
    metrics = {"lr": lr, "grad_norm": gn}
    return new_params, new_state, metrics
