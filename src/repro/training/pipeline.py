"""GPipe-style pipeline parallelism over a ``pipe`` mesh axis.

Each pipeline stage owns a contiguous slice of layers (params sharded over
``pipe`` on the stacked-layer axis). A microbatched forward runs the classic
GPipe schedule: at tick t, stage s processes microbatch t-s; activations move
stage-to-stage with ``jax.lax.ppermute`` (the point-to-point hop the TPU ICI
torus serves directly). ``n_micro >= n_stages`` microbatches keep the bubble
at the standard (S-1)/(M+S-1) fraction.

This composes with the DP/TP sharding of everything *inside* a stage — the
multi-pod dry-run uses DP×TP(+pod) as the primary layout, and this module is
the PP alternative exercised on host meshes (tests/test_pipeline.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import pcast, shard_map


def gpipe_forward(
    layer_fn,
    stacked_params,
    x: jnp.ndarray,  # (n_micro, micro_batch, ...) microbatched input
    *,
    mesh,
    axis: str = "pipe",
):
    """Run ``layer_fn(params_slice, h)`` through S pipeline stages.

    ``stacked_params``: pytree with leading (n_layers,) axes, n_layers % S == 0;
    stage s owns layers [s·L/S, (s+1)·L/S). Returns (n_micro, micro_batch, ...)
    outputs. Implemented as a shard_map over ``axis`` with a ppermute ring.
    """
    S = mesh.shape[axis]
    n_micro = x.shape[0]
    assert n_micro % 1 == 0 and n_micro >= S, (n_micro, S)

    def stage_body(params_local, xs_local):
        # params_local: leaves with leading (L/S,) — this stage's layers
        # xs_local: (n_micro, micro, ...) full microbatch queue (replicated)
        sid = jax.lax.axis_index(axis)

        def run_stage(h):
            def body(c, lp):
                return layer_fn(lp, c), None
            out, _ = jax.lax.scan(body, h, params_local)
            return out

        n_ticks = n_micro + S - 1
        # initial carries must already be device-varying for the scan
        buf = pcast(jnp.zeros_like(xs_local[0]), (axis,), to="varying")
        outs = pcast(jnp.zeros_like(xs_local), (axis,), to="varying")

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t from the queue; others use the
            # activation that arrived from the previous stage
            mb = jnp.clip(t, 0, n_micro - 1)
            h_in = jnp.where(sid == 0, xs_local[mb], buf)
            h_out = run_stage(h_in)
            # last stage emits microbatch t - (S-1) (branch-free select:
            # lax.cond branches would disagree on varying-manual-axes types)
            emit = t - (S - 1)
            valid_emit = (emit >= 0) & (emit < n_micro) & (sid == S - 1)
            upd = jax.lax.dynamic_update_slice(
                outs, h_out[None].astype(outs.dtype),
                (jnp.clip(emit, 0, n_micro - 1),) + (0,) * (outs.ndim - 1),
            )
            outs = jnp.where(valid_emit, upd, outs)
            # hand the activation to the next stage (ring permute)
            nxt = jax.lax.ppermute(h_out, axis, [(i, (i + 1) % S) for i in range(S)])
            return (nxt, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(n_ticks))
        # only the last stage holds real outputs; broadcast via masked psum
        outs = jax.lax.psum(jnp.where(sid == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    pspec = jax.tree.map(lambda _: P(axis), stacked_params)
    return shard_map(
        stage_body,
        mesh=mesh,
        in_specs=(pspec, P()),
        out_specs=P(),
    )(stacked_params, x)
