"""Logical-axis -> mesh-axis sharding rules, divisibility-aware.

Every tensor in the framework is annotated with *logical* axis names
("batch", "heads", "ff", "experts", "vocab", ...). A ``MeshRules`` bound to
a mesh resolves them to ``PartitionSpec``s, silently falling back to
replication when the dimension size does not divide the mesh axis extent
(e.g. xlstm's 4 heads on a 16-way model axis, or seamless' 256206 vocab
before padding). This is the single policy point for TP/DP/EP/SP layout.
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> preferred mesh axes (tried in order; tuple entries combine)
DEFAULT_RULES: dict[str, tuple] = {
    "batch": (("pod", "data"), ("data",)),  # DP over pod+data when present
    "heads": (("model",),),  # TP: attention q-heads
    "kv_heads": (("model",),),  # TP: kv heads (replicated if indivisible)
    "ff": (("model",),),  # TP: MLP hidden
    "experts": (("model",),),  # EP: MoE experts
    "vocab": (("model",),),  # TP: embedding/logits vocab shard
    "seq_kv": (("model",),),  # SP: decode KV-cache sequence shard
    "d_inner": (("model",),),  # TP: SSM inner channels
    "embed": (),
    "layers": (),
    "seq": (),
    None: (),
}


@dataclasses.dataclass(frozen=True)
class MeshRules:
    mesh: Mesh
    rules: dict | None = None

    def _axes_for(self, logical: str | None, dim_size: int) -> tuple[str, ...] | None:
        table = self.rules or DEFAULT_RULES
        for cand in table.get(logical, ()):
            cand = tuple(a for a in cand if a in self.mesh.shape)
            if not cand:
                continue
            extent = 1
            for a in cand:
                extent *= self.mesh.shape[a]
            if extent > 1 and dim_size % extent == 0:
                return cand
        return None

    def spec(self, logical_axes: tuple, shape: tuple) -> P:
        """PartitionSpec for a tensor given its logical axes and shape."""
        assert len(logical_axes) == len(shape), (logical_axes, shape)
        used: set[str] = set()
        out = []
        for name, size in zip(logical_axes, shape):
            axes = self._axes_for(name, size)
            if axes and not (set(axes) & used):
                out.append(axes if len(axes) > 1 else axes[0])
                used.update(axes)
            else:
                out.append(None)
        return P(*out)

    def sharding(self, logical_axes: tuple, shape: tuple) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))


def logical_to_spec(mesh: Mesh, logical_axes: tuple, shape: tuple) -> P:
    return MeshRules(mesh).spec(logical_axes, shape)
