from repro.sharding.rules import MeshRules, logical_to_spec

__all__ = ["MeshRules", "logical_to_spec"]
