"""Serving engine: batched prefill + decode with a static KV cache.

The production layout (what the decode/long dry-run cells lower):
  - cache batch over ``data`` (+``pod``), cache *sequence* over ``model``
    (SP): each model shard holds a contiguous KV stripe and computes a
    partial attention; XLA merges the sharded softmax with the collective
    pair flash-decoding uses. Head sharding is used instead whenever
    kv_heads divides the model axis and seq does not.
  - requests are greedily packed into fixed-size batches (static shapes —
    no recompilation per request mix).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import init_params
from repro.models.registry import build_model, cache_specs_for


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (plen,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)


class Engine:
    def __init__(self, cfg, params, batch_size: int, max_seq: int):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.B = batch_size
        self.S = max_seq
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode)

    def _fresh_cache(self):
        specs = cache_specs_for(self.cfg, "decode_32k", seq=self.S, batch=self.B)
        return init_params(specs, jax.random.PRNGKey(0))

    def generate(self, requests: list[Request], greedy: bool = True) -> list[Request]:
        """Serve a wave of requests (padded to the static batch)."""
        assert len(requests) <= self.B
        plen = max(len(r.prompt) for r in requests)
        toks = np.zeros((self.B, plen), np.int32)
        for i, r in enumerate(requests):
            toks[i, plen - len(r.prompt) :] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros((self.B, max(plen // 4, 1), self.cfg.d_model), jnp.dtype(self.cfg.dtype))
        cache = self._fresh_cache()
        logits, cache = self._prefill(self.params, batch, cache)
        pos = plen
        max_new = max(r.max_new for r in requests)
        for _ in range(max_new):
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            for i, r in enumerate(requests):
                if len(r.out) < r.max_new:
                    r.out.append(int(nxt[i]))
            if pos >= self.S - 1:
                break
            dec = {"token": nxt[:, None], "pos": jnp.asarray(pos, jnp.int32)}
            logits, cache = self._decode(self.params, dec, cache)
            pos += 1
        return requests
