"""N-list structure and vectorized intersection (the paper's §3.2 / Example 2).

An N-list is the sequence of PP-codes ``({pre, post}: count)`` of the nodes
registering an item, pre-order ascending. The paper intersects two N-lists by
a linear merge with the ancestor test ``x.pre < y.pre and x.post > y.post``.

TPU adaptation: all nodes registering one item form an **antichain** (no two
are on the same root path, since items are unique along a path), so their
subtree intervals are disjoint in pre-order. Hence code ``y`` has *at most
one* ancestor in list ``A``, and it can only be ``A[searchsorted(A.pre,
y.pre) - 1]`` — the linear merge becomes a data-parallel gather:

    idx   = searchsorted(A.pre, y.pre) - 1        # candidate ancestor
    hit   = idx >= 0  and  A.post[idx] > y.post   # subsume test
    out   = segment_sum(y.count * hit, idx, La)   # merged counts on A's codes
    sup   = out.sum()

This is O(|Y| log |A|) independent parallel lanes instead of a sequential
merge — the form the Pallas kernel (kernels/nlist_intersect) implements with
VMEM-resident tiles.

The merged N-list of ``P ∪ {q}`` always lives on ``q``'s code slots, so an
itemset's N-list is represented as *(base item q, counts aligned with
NL(q))* — static shapes, perfect for jit/shard_map.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import jax

INF = np.iinfo(np.int32).max


def intersect_np(
    a_pre: np.ndarray,
    a_post: np.ndarray,
    y_pre: np.ndarray,
    y_post: np.ndarray,
    y_cnt: np.ndarray,
) -> np.ndarray:
    """Counts of the merged N-list, aligned with A's codes. Host path."""
    la = len(a_pre)
    if la == 0 or len(y_pre) == 0:
        return np.zeros(la, np.int64)
    idx = np.searchsorted(a_pre, y_pre, side="left") - 1
    ok = (idx >= 0) & (a_post[np.clip(idx, 0, la - 1)] > y_post)
    return np.bincount(idx[ok], weights=y_cnt[ok].astype(np.float64), minlength=la).astype(np.int64)


def intersect_jnp(a_pre, a_post, y_pre, y_post, y_cnt):
    """Jit-able intersection on padded buffers.

    Padded slots: ``pre = INF, post = -1, cnt = 0`` — they sort last, never
    pass the subsume test and contribute zero count, so no masks are needed.
    """
    la = a_pre.shape[0]
    idx = jnp.searchsorted(a_pre, y_pre, side="left") - 1
    cidx = jnp.clip(idx, 0, la - 1)
    ok = (idx >= 0) & (a_post[cidx] > y_post)
    contrib = jnp.where(ok, y_cnt, 0)
    return jax.ops.segment_sum(contrib, cidx, num_segments=la)


batched_intersect_jnp = jax.vmap(intersect_jnp)  # over a leading candidate axis


def pad_nlist(nl: np.ndarray, width: int) -> np.ndarray:
    """(n,3) (pre,post,cnt) -> (width,3) with INF/-1/0 padding."""
    out = np.empty((width, 3), np.int64)
    out[:, 0] = INF
    out[:, 1] = -1
    out[:, 2] = 0
    n = min(len(nl), width)
    out[:n] = nl[:n]
    return out


def pack_nlists(nlists: list[np.ndarray], width: int | None = None) -> np.ndarray:
    """Stack per-item N-lists into (K, width, 3) with padding (device-ready)."""
    width = width or max((len(x) for x in nlists), default=1)
    width = max(width, 1)
    return np.stack([pad_nlist(x, width) for x in nlists])
