"""Sort-based PPC-tree construction (the paper's Job-2 reduce, TPU-native).

The Hadoop reducer builds the PPC-tree by pointer insertion (``insert_tree``)
and then walks it twice to assign pre-/post-order ranks. Pointer tries do not
vectorize, so we construct the *identical* tree algebraically:

1. Lexicographically sort the rank-encoded transactions. In a prefix tree
   built from sorted rows, every trie node corresponds to a *distinct row
   prefix*, and the rows sharing that prefix are contiguous.
2. A node of depth ``d+1`` starts at row ``i`` iff column ``d`` is valid and
   the length-``d+1`` prefix differs from row ``i-1`` (vectorized cumulative
   OR of per-column inequality).
3. Flattening the boundary mask row-major enumerates nodes sorted by
   ``(start_row, depth)`` — which *is* pre-order (DFS of sorted rows).
4. ``subtree_size`` via ``searchsorted`` on the (non-decreasing) node start
   rows, and the closed form ``post = pre + size - 1 - depth`` replaces the
   post-order traversal.
5. ``count`` = windowed sum of row weights over the node's row range.

The result is bit-identical to the pointer-built tree (property-tested
against ``_build_ppc_pointer`` below) but is all sorts/scans/gathers — the
shape of computation TPUs execute well, and the same code runs inside
``shard_map`` for the distributed miner (each shard owns its block's tree,
exactly like one Hadoop reducer).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.encoding import PAD


@dataclasses.dataclass
class PPCTree:
    """Flat PPC-tree: one row per node, pre-order sorted."""

    item: np.ndarray  # (N,) F-list rank registered by the node
    count: np.ndarray  # (N,) transactions through the node
    pre: np.ndarray  # (N,) pre-order rank == arange(N)
    post: np.ndarray  # (N,) post-order rank
    depth: np.ndarray  # (N,) 0-indexed depth (top-level nodes = 0)
    n_nodes: int

    def nlists(self, k: int) -> list[np.ndarray]:
        """Per-item N-lists: (len_i, 3) arrays of (pre, post, count), pre-asc.

        Nodes registering one item are an antichain (items are unique along
        any root path), so each list's pre-order intervals are disjoint —
        the property the vectorized intersection relies on.
        """
        order = np.argsort(self.item, kind="stable")  # stable keeps pre-order
        out: list[np.ndarray] = []
        bounds = np.searchsorted(self.item[order], np.arange(k + 1))
        packed = np.stack([self.pre, self.post, self.count], axis=1)
        for i in range(k):
            out.append(packed[order[bounds[i] : bounds[i + 1]]])
        return out


def build_ppc(rows: np.ndarray, weights: np.ndarray | None = None) -> PPCTree:
    """Host/numpy sort-based construction. ``rows`` rank-encoded, PAD=-1."""
    rows = np.asarray(rows, np.int32)
    R, L = rows.shape
    w = np.ones(R, np.int64) if weights is None else np.asarray(weights, np.int64)
    if R == 0:
        z = np.zeros(0, np.int64)
        return PPCTree(z, z, z, z, z, 0)

    order = np.lexsort(tuple(rows[:, c] for c in range(L - 1, -1, -1)))
    srows = rows[order]
    sw = w[order]

    valid = srows != PAD
    neq = np.ones_like(valid)
    neq[1:] = srows[1:] != srows[:-1]
    chg = np.logical_or.accumulate(neq, axis=1)  # prefix(d+1) differs from prev row
    newgrp = valid & chg

    # next row (strictly after i) where prefix of this depth changes
    idx = np.where(chg, np.arange(R)[:, None], R)
    nxt = np.minimum.accumulate(idx[::-1], axis=0)[::-1]
    nxt = np.vstack([nxt[1:], np.full((1, L), R, np.int64)])  # strict successor

    pos = np.flatnonzero(newgrp.ravel())  # row-major == (start_row, depth) == pre-order
    start = pos // L
    depth = pos % L
    end = nxt[start, depth]  # exclusive row end of the node's range

    wsum = np.concatenate([[0], np.cumsum(sw)])
    count = wsum[end] - wsum[start]
    item = srows[start, depth].astype(np.int64)

    n = len(pos)
    pre = np.arange(n, dtype=np.int64)
    size = np.searchsorted(start, end, side="left") - pre  # subtree is pre-order contiguous
    post = pre + size - 1 - depth
    return PPCTree(item=item, count=count, pre=pre, post=post, depth=depth.astype(np.int64), n_nodes=n)


def build_ppc_jnp(rows: jnp.ndarray, weights: jnp.ndarray, max_nodes: int, n_items: int = 0):
    """Jit-able construction with static output size ``max_nodes``.

    Returns ``(item, count, pre, post, valid_mask)`` padded to ``max_nodes``
    (invalid slots: item = -1, count = 0, pre = big). Used by HPrepost inside
    ``shard_map``; on a shard of R rows × L cols, ``max_nodes`` ≤ R·L.

    ``n_items``: when the rank alphabet is known and small, pairs of columns
    are packed into single int32 sort keys (lexicographically equivalent) —
    halves the lexsort key count, which dominates compile+run time at L≈74.
    """
    R, L = rows.shape
    if 0 < n_items <= 30_000 and L > 8:
        base = n_items + 2
        shifted = rows + 1  # PAD -> 0 keeps order
        if L % 2:
            shifted = jnp.pad(shifted, ((0, 0), (0, 1)))
        packed = shifted[:, 0::2] * base + shifted[:, 1::2]
        keys = tuple(packed[:, c] for c in range(packed.shape[1] - 1, -1, -1))
    else:
        keys = tuple(rows[:, c] for c in range(L - 1, -1, -1))
    order = jnp.lexsort(keys)
    srows = rows[order]
    sw = weights[order]

    valid = srows != PAD
    neq = jnp.concatenate([jnp.ones((1, L), bool), srows[1:] != srows[:-1]], axis=0)
    chg = jax.lax.cummax(neq.astype(jnp.int32), axis=1).astype(bool)
    newgrp = valid & chg

    idx = jnp.where(chg, jnp.arange(R)[:, None], R)
    nxt = jax.lax.cummin(idx, axis=0, reverse=True)
    nxt = jnp.concatenate([nxt[1:], jnp.full((1, L), R, idx.dtype)], axis=0)

    flat = newgrp.ravel()
    # stable "nonzero with static size": sort flat positions, valid first
    keys = jnp.where(flat, jnp.arange(R * L), R * L)
    pos = jnp.sort(keys)[:max_nodes]
    node_valid = pos < R * L
    pos = jnp.where(node_valid, pos, 0)
    start = pos // L
    depth = pos % L
    end = nxt[start, depth]

    wsum = jnp.concatenate([jnp.zeros(1, sw.dtype), jnp.cumsum(sw)])
    count = jnp.where(node_valid, wsum[end] - wsum[start], 0)
    item = jnp.where(node_valid, srows[start, depth], -1)

    pre = jnp.arange(max_nodes)
    # invalid slots must sort AFTER every valid start for searchsorted
    start_key = jnp.where(node_valid, start, R)
    size = jnp.searchsorted(start_key, end, side="left") - pre
    post = jnp.where(node_valid, pre + size - 1 - depth, jnp.iinfo(jnp.int32).max)
    pre = jnp.where(node_valid, pre, jnp.iinfo(jnp.int32).max)
    return item, count, pre, post, node_valid


# --------------------------------------------------------------------------
# Pointer-based oracle (the paper's literal insert_tree) — tests only.
# --------------------------------------------------------------------------


def _build_ppc_pointer(rows: np.ndarray, weights: np.ndarray | None = None) -> PPCTree:
    """Literal Algorithm-1 ``insert_tree`` + two traversals. O(R·L) pointers."""
    R, L = rows.shape
    w = np.ones(R, np.int64) if weights is None else np.asarray(weights, np.int64)
    root: dict = {"item": None, "count": 0, "children": {}}
    for r in range(R):
        node = root
        for c in range(L):
            it = int(rows[r, c])
            if it == PAD:
                break
            child = node["children"].get(it)
            if child is None:
                child = {"item": it, "count": 0, "children": {}}
                node["children"][it] = child
            child["count"] += int(w[r])
            node = child

    items, counts, pres, posts, depths = [], [], [], [], []
    pre_ctr = [0]
    post_ctr = [0]

    def visit(node, depth):
        my = len(items)
        items.append(node["item"])
        counts.append(node["count"])
        depths.append(depth)
        pres.append(pre_ctr[0])
        posts.append(-1)
        pre_ctr[0] += 1
        for it in sorted(node["children"]):  # children in item order == sorted-row DFS
            visit(node["children"][it], depth + 1)
        posts[my] = post_ctr[0]
        post_ctr[0] += 1

    for it in sorted(root["children"]):
        visit(root["children"][it], 0)
    return PPCTree(
        item=np.array(items, np.int64),
        count=np.array(counts, np.int64),
        pre=np.array(pres, np.int64),
        post=np.array(posts, np.int64),
        depth=np.array(depths, np.int64),
        n_nodes=len(items),
    )
