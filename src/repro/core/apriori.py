"""Vertical-bitmap Apriori baseline (beyond-paper comparator).

Candidate supports are AND + popcount over packed transaction bitmaps —
a vectorized stand-in for the classic Apriori family the paper groups its
related work into. Used in benchmarks to show where the N-list approach wins.
"""
from __future__ import annotations

import numpy as np

from repro.core import encoding as enc

_POP = np.unpackbits(np.arange(256, dtype=np.uint8)[:, None], axis=1).sum(1).astype(np.int64)


def _popcount(x: np.ndarray) -> np.ndarray:
    if hasattr(np, "bitwise_count"):
        return np.bitwise_count(x).astype(np.int64)
    return _POP[x]


def mine_apriori(rows: np.ndarray, n_items: int, min_count: int,
                 max_itemsets: int = 2_000_000, max_k: int | None = None):
    """Frequent itemsets via packed vertical bitmaps. Returns dict ids->sup."""
    supports = enc.item_support(rows, n_items)
    fl = enc.build_flist(supports, min_count)
    ranked = enc.rank_encode(rows, fl)
    R = len(ranked)
    K = fl.k
    out: dict[tuple[int, ...], int] = {}
    if K == 0:
        return out, {"peak_bytes": 0}

    # (K, ceil(R/8)) packed bitmap: bit r set iff row r contains rank k
    dense = np.zeros((K, R), np.uint8)
    r, c = np.nonzero(ranked != enc.PAD)
    dense[ranked[r, c], r] = 1
    bitmap = np.packbits(dense, axis=1)
    peak = bitmap.nbytes

    for k in range(K):
        out[(int(fl.items[k]),)] = int(fl.supports[k])

    # frontier: list of (ranks tuple, packed bitmap row)
    frontier = [((k,), bitmap[k]) for k in range(K)]
    while frontier and len(out) < max_itemsets:
        nxt = []
        for ranks, bits in frontier:
            base = ranks[0]
            if base == 0 or (max_k is not None and len(ranks) >= max_k):
                continue
            cand = bitmap[:base] & bits[None, :]
            sups = _popcount(cand).sum(axis=1)
            for q in np.flatnonzero(sups >= min_count):
                nr = (int(q),) + ranks
                ids = tuple(sorted(int(fl.items[x]) for x in nr))
                out[ids] = int(sups[q])
                nxt.append((nr, cand[q]))
        peak = max(peak, bitmap.nbytes + sum(b.nbytes for _, b in nxt))
        frontier = nxt
    return out, {"peak_bytes": peak + rows.nbytes}
