"""Transaction encoding for N-list mining.

The paper's Job-1/Job-2 "map" side: item support counting, F-list construction
(frequent 1-itemsets sorted by descending support) and re-encoding of every
transaction into dense F-list *ranks* (0 = most frequent item), filtered of
infrequent items and sorted in F-list order.

Transactions are held as a padded int32 matrix ``(n_rows, max_len)`` with
``PAD = -1``. Both a numpy host path (reference, used by the single-shard
miner) and a jit-able jnp path (used inside ``shard_map`` by HPrepost) are
provided; they are property-tested against each other.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

PAD = -1
# Sentinel used while sorting ranks inside a row; larger than any valid rank.
_BIG = np.iinfo(np.int32).max // 2


def pad_transactions(tx: Sequence[Sequence[int]], max_len: int | None = None) -> np.ndarray:
    """Pack ragged transactions into a ``(R, L)`` int32 matrix, PAD = -1.

    Duplicate items within a transaction are dropped (itemsets are sets).
    Transactions longer than ``max_len`` are truncated (documented surrogate
    behaviour for heavy-tail datasets).
    """
    dedup = [sorted(set(int(i) for i in t)) for t in tx]
    L = max_len or max((len(t) for t in dedup), default=1)
    L = max(L, 1)
    out = np.full((len(dedup), L), PAD, dtype=np.int32)
    for r, t in enumerate(dedup):
        t = t[:L]
        out[r, : len(t)] = t
    return out


def item_support(rows: np.ndarray, n_items: int, weights: np.ndarray | None = None) -> np.ndarray:
    """Job-1 word count (host path): support of every item id."""
    flat = rows.ravel()
    w = (
        np.ones(rows.shape, np.int64)
        if weights is None
        else np.broadcast_to(weights[:, None], rows.shape)
    ).ravel()
    mask = flat != PAD
    return np.bincount(flat[mask], weights=w[mask], minlength=n_items).astype(np.int64)


def item_support_jnp(rows: jnp.ndarray, n_items: int) -> jnp.ndarray:
    """Job-1 word count, jit-able (one-hot matmul — see kernels/histogram)."""
    onehot = jax.nn.one_hot(jnp.where(rows == PAD, n_items, rows), n_items + 1, dtype=jnp.int32)
    return onehot.sum(axis=(0, 1))[:n_items]


@dataclasses.dataclass(frozen=True)
class FList:
    """Frequent-1-itemset list: original item ids sorted by descending support."""

    items: np.ndarray  # (K,) original item ids, support-descending
    supports: np.ndarray  # (K,) support of each, aligned with items
    n_items: int  # size of the original item universe
    min_count: int

    @property
    def k(self) -> int:
        return len(self.items)

    def rank_lut(self) -> np.ndarray:
        """item id -> F-list rank; infrequent items map to _BIG."""
        lut = np.full(self.n_items + 1, _BIG, dtype=np.int32)
        lut[self.items] = np.arange(self.k, dtype=np.int32)
        return lut


def build_flist(supports: np.ndarray, min_count: int) -> FList:
    """Keep items with support >= min_count, sort descending (ties: item asc)."""
    supports = np.asarray(supports, np.int64)
    n_items = len(supports)
    keep = np.flatnonzero(supports >= min_count)
    # stable sort on -support -> ties broken by item id ascending
    order = keep[np.argsort(-supports[keep], kind="stable")]
    return FList(
        items=order.astype(np.int32),
        supports=supports[order],
        n_items=n_items,
        min_count=int(min_count),
    )


def rank_encode(rows: np.ndarray, flist: FList) -> np.ndarray:
    """Job-2 map (host path): re-encode rows to ranks, drop infrequent, sort.

    Output rows hold F-list ranks ascending (most frequent first), PAD = -1.
    """
    lut = flist.rank_lut()
    ranked = np.where(rows == PAD, _BIG, lut[np.clip(rows, 0, flist.n_items)])
    ranked.sort(axis=1)
    return np.where(ranked >= _BIG, PAD, ranked).astype(np.int32)


def rank_encode_jnp(rows: jnp.ndarray, rank_lut: jnp.ndarray, n_items: int) -> jnp.ndarray:
    """Job-2 map, jit-able. ``rank_lut`` from ``FList.rank_lut()``."""
    ranked = jnp.where(rows == PAD, _BIG, rank_lut[jnp.clip(rows, 0, n_items)])
    ranked = jnp.sort(ranked, axis=1)
    return jnp.where(ranked >= _BIG, PAD, ranked).astype(jnp.int32)


def dedup_rows(rows: np.ndarray, weights: np.ndarray | None = None) -> tuple[np.ndarray, np.ndarray]:
    """Merge identical (ranked) transactions into (unique_rows, weights).

    The PPC-tree does this implicitly (shared paths); doing it eagerly keeps
    every later sort/scan proportional to *distinct* paths, which is the same
    compression the paper's tree achieves.
    """
    w = np.ones(len(rows), np.int64) if weights is None else np.asarray(weights, np.int64)
    uniq, inv = np.unique(rows, axis=0, return_inverse=True)
    wsum = np.bincount(inv, weights=w, minlength=len(uniq)).astype(np.int64)
    # drop the all-PAD row (empty transaction) if present
    nonempty = ~(uniq == PAD).all(axis=1)
    return uniq[nonempty], wsum[nonempty]
