"""FP-growth baseline (Han et al. 2000) — the paper's main comparator.

Classic recursive conditional-tree miner over a pointer FP-tree with header
links. Kept deliberately faithful to the original algorithm (host pointers,
recursion) so the runtime/memory comparison against the vectorized
PrePost/HPrepost path mirrors the paper's Figs 3-10 setup.
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core import encoding as enc


class _Node:
    __slots__ = ("item", "count", "parent", "children", "link")

    def __init__(self, item, count, parent, children, link=None):
        self.item = item
        self.count = count
        self.parent = parent
        self.children = children
        self.link = link


class _FPTree:
    def __init__(self):
        self.root = _Node(-1, 0, None, {})
        self.header: dict[int, _Node] = {}
        self.n_nodes = 1

    def insert(self, path, count):
        node = self.root
        for it in path:
            child = node.children.get(it)
            if child is None:
                child = _Node(it, 0, node, {})
                node.children[it] = child
                child.link = self.header.get(it)
                self.header[it] = child
                self.n_nodes += 1
            child.count += count
            node = child


def _mine(tree: _FPTree, suffix: tuple, min_count: int, out: dict, item_sup: dict,
          stats: dict, max_itemsets: int, max_k: int | None = None):
    # items ascending support so conditional trees stay small
    for it in sorted(item_sup, key=lambda i: item_sup[i]):
        if len(out) >= max_itemsets:
            return
        newset = (it,) + suffix
        out[newset] = item_sup[it]
        if max_k is not None and len(newset) >= max_k:
            continue
        # build conditional pattern base
        cond = _FPTree()
        cond_sup: dict[int, int] = {}
        node = tree.header.get(it)
        paths = []
        while node is not None:
            path = []
            p = node.parent
            while p is not None and p.item != -1:
                path.append(p.item)
                p = p.parent
            path.reverse()
            if path:
                paths.append((path, node.count))
                for x in path:
                    cond_sup[x] = cond_sup.get(x, 0) + node.count
            node = node.link
        cond_sup = {x: s for x, s in cond_sup.items() if s >= min_count}
        for path, cnt in paths:
            fpath = [x for x in path if x in cond_sup]
            if fpath:
                cond.insert(fpath, cnt)
        stats["peak_nodes"] = max(stats["peak_nodes"], stats["live_nodes"] + cond.n_nodes)
        stats["live_nodes"] += cond.n_nodes
        if cond_sup:
            _mine(cond, newset, min_count, out, cond_sup, stats, max_itemsets, max_k)
        stats["live_nodes"] -= cond.n_nodes


def mine_fpgrowth(rows: np.ndarray, n_items: int, min_count: int,
                  max_itemsets: int = 2_000_000, max_k: int | None = None):
    """Returns (itemsets dict in original ids, stats with peak node estimate)."""
    supports = enc.item_support(rows, n_items)
    fl = enc.build_flist(supports, min_count)
    ranked = enc.rank_encode(rows, fl)
    urows, w = enc.dedup_rows(ranked)

    tree = _FPTree()
    for r in range(len(urows)):
        path = [int(x) for x in urows[r] if x != enc.PAD]
        if path:
            tree.insert(path, int(w[r]))

    item_sup = {int(r): int(fl.supports[r]) for r in range(fl.k)}
    out_ranks: dict[tuple, int] = {}
    stats = {"live_nodes": tree.n_nodes, "peak_nodes": tree.n_nodes}
    old_limit = sys.getrecursionlimit()
    sys.setrecursionlimit(max(old_limit, 10000))
    try:
        _mine(tree, (), min_count, out_ranks, item_sup, stats, max_itemsets, max_k)
    finally:
        sys.setrecursionlimit(old_limit)

    out = {
        tuple(sorted(int(fl.items[r]) for r in ranks)): sup
        for ranks, sup in out_ranks.items()
    }
    # rough per-node footprint of the pointer tree (paper measures JVM heap)
    stats["peak_bytes"] = stats["peak_nodes"] * 120 + urows.nbytes
    return out, stats
