"""Core of the paper's contribution: N-list frequent-itemset mining.

Public API — mine through the unified front-door:

  - ``repro.mining`` (re-exported here): ``MineSpec`` (one typed request:
    algorithm, min_sup/min_count, max_k, backend, pattern family),
    ``mine()`` / ``MiningEngine`` (one-shot vs. resident session), and the
    ``register_miner`` registry covering hprepost, prepost, prepost+,
    fpgrowth, apriori, and the brute-force oracle. Every miner returns the
    same enriched ``MineResult`` (itemsets, exact total count, peak bytes,
    wall time, per-stage timings).

Building blocks (stable, importable directly):

  - encoding: transaction padding, F-list, rank encoding
  - ppc: sort-based PPC-tree (TPU-native construction)
  - nlist: N-list intersection (vectorized subsume test)
  - prepost: single-shard PrePost/PrePost+ miner
  - hprepost: distributed MapReduce miner (shard_map)
  - fpgrowth / apriori / oracle: comparators
  - patterns: closed / maximal / top-rank-k post-passes
"""
from repro.core.encoding import PAD, FList, build_flist, item_support, pad_transactions, rank_encode
from repro.core.ppc import PPCTree, build_ppc
from repro.core.prepost import mine_prepost

_MINING_EXPORTS = (
    "MineSpec",
    "MineResult",
    "MiningEngine",
    "mine",
    "get_miner",
    "list_miners",
    "register_miner",
)

__all__ = [
    "PAD",
    "FList",
    "build_flist",
    "item_support",
    "pad_transactions",
    "rank_encode",
    "PPCTree",
    "build_ppc",
    "mine_prepost",
    *_MINING_EXPORTS,
]


def __getattr__(name):
    # Lazy re-export of the repro.mining surface (PEP 562) — keeps
    # core importable without pulling the miner registry in, and avoids a
    # package-init cycle (repro.mining's adapters import repro.core.*).
    if name in _MINING_EXPORTS:
        import repro.mining as _mining

        return getattr(_mining, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
