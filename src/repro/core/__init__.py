"""Core of the paper's contribution: N-list frequent-itemset mining.

Public API:
  - encoding: transaction padding, F-list, rank encoding
  - ppc: sort-based PPC-tree (TPU-native construction)
  - nlist: N-list intersection (vectorized subsume test)
  - prepost: single-shard PrePost/PrePost+ miner
  - hprepost: distributed MapReduce miner (shard_map)
  - fpgrowth / apriori / oracle: comparators
"""
from repro.core.encoding import PAD, FList, build_flist, item_support, pad_transactions, rank_encode
from repro.core.ppc import PPCTree, build_ppc
from repro.core.prepost import MineResult, mine_prepost

__all__ = [
    "PAD",
    "FList",
    "build_flist",
    "item_support",
    "pad_transactions",
    "rank_encode",
    "PPCTree",
    "build_ppc",
    "MineResult",
    "mine_prepost",
]
