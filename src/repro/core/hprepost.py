"""HPrepost: the paper's MapReduce miner as sharded JAX (the contribution).

The Hadoop pipeline maps onto a ``(data, model)`` device mesh:

  Job 1 (word count)      -> per-shard histogram kernel + ``psum`` over `data`
  Job 2 map (F-list sort) -> per-shard ``rank_encode_jnp`` (no communication)
  Job 2 reduce (PPC-tree) -> per-shard sort-based ``build_ppc_jnp``: every
                             data shard owns the PPC-tree/N-lists of its block,
                             exactly one Hadoop reducer's state
  F2 scan                 -> per-shard co-occurrence matmul + ``psum``
  k>2 mining waves        -> batched N-list intersections; *candidate* axis
                             sharded over `model` (the PFP/MRPrepost "group
                             partitioning"), per-candidate supports ``psum``-ed
                             over `data` (supports are additive across DB
                             blocks); the parent-state gather between waves is
                             the MapReduce shuffle, expressed as a sharded
                             ``take`` that XLA lowers to collectives.

Mining state per (data-shard, candidate): the merged N-list counts aligned
with the candidate's base-item code slots — static ``(D, C, W)`` buffers, so
every wave is one jitted, fully sharded call. All jitted functions are built
once per miner (static shapes bucketed to powers of two) so repeated mines
hit the jit cache.

The host drives the level loop (as the Hadoop job driver does); device code
never materializes the global database or any global tree.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import encoding as enc
from repro.fault import failures
from repro.mining.telemetry import trace
from repro.core.ppc import build_ppc_jnp
from repro.core.prepost import PrepostResult
from repro.kernels.cooccur.ops import cooccurrence_matrix
from repro.kernels.histogram.ops import item_histogram
from repro.kernels.nlist_intersect.ops import nlist_intersect

INF32 = np.iinfo(np.int32).max

# Version tag of the PreparedDB host payload (``to_host``/``from_host``).
# Bump on any layout change so stale on-disk snapshots are rejected, not
# misread.
PREPARED_SCHEMA = 1


@dataclasses.dataclass(frozen=True)
class HPrepostConfig:
    max_k: int | None = None
    nlist_width: int | None = None  # static W; None = auto (next pow2 of max)
    candidate_unit: int = 256  # candidate buffers: pow2 multiples of this
    la_block: int = 512  # intersect kernel: A-codes per tile
    ly_block: int = 512  # intersect kernel: Y-codes per tile
    batch_block: int = 8  # intersect kernel: candidates per grid program
    partition_candidates: bool = True  # mode B (PFP groups over `model`)
    locality_dispatch: bool = True  # children placed on their parent's shard:
    # the inter-wave shuffle becomes a shard-local gather (zero collectives),
    # at the cost of per-shard padding under skew (§Perf FIM iteration)
    pipeline_waves: bool = True  # dispatch wave l+1 before blocking on wave
    # l's supports: host candidate generation overlaps device execution; the
    # one-wave speculation is sound because support is anti-monotone
    backend: str = "auto"  # a repro.mining.tune registry name (auto | pallas
    # | jnp | pallas-tpu | pallas-gpu | pallas-interpret)
    max_f1: int = 4096  # guard on |F-list| (F2 matrix is K^2)
    max_itemsets: int = 2_000_000
    early_stop: bool = True  # early-stopping intersections (arXiv:1901.07773):
    # host-side Apriori-closure pruning of doomed candidates before they ship,
    # plus in-kernel bound masking on Pallas backends when supports are final
    # (single data shard, non-segmented). False = the exact legacy path,
    # bit-for-bit.
    tune: bool = False  # resolve block knobs through the persisted KernelTuner
    # instead of the static la/ly/batch_block fields

    # knobs that pick *how* waves execute but never change what ``prepare``
    # builds — stripped (normalized to defaults) from prep cache and
    # snapshot keys so a retune or backend switch reuses warm preps
    EXECUTION_ONLY = ("la_block", "ly_block", "batch_block", "backend",
                      "early_stop", "tune")

    def prep_key(self) -> "HPrepostConfig":
        """This config with execution-only knobs normalized away — the
        identity ``PreparedDB`` caches and snapshots key on."""
        defaults = {f: getattr(HPrepostConfig, f) for f in self.EXECUTION_ONLY}
        return dataclasses.replace(self, **defaults)


@dataclasses.dataclass
class PreparedDB:
    """Threshold-floor prepared database: every stage that depends only on
    the *loosest* threshold of a sweep (Job 1 histogram/F-list, Job 2
    PPC-tree build, N-list pack, F2 scan), device-resident.

    ``mine_prepared`` serves any ``min_count >= min_count_floor`` from it:
    the floor F-list is a superset of every tighter F-list, and N-list
    intersections count exact database supports regardless of which extra
    items sit in the tree, so tighter thresholds only *filter* — they never
    need a rebuild.
    """

    fl: enc.FList  # built at min_count_floor (superset of tighter F-lists)
    n_items: int
    n_rows: int  # unpadded R0 the thresholds resolve against
    min_count_floor: int  # loosest threshold this prep can serve
    width: int  # static N-list width W (0 when F1-only)
    packed: Any  # (D, K, W, 3) device N-lists, or None when F1-only
    singleton_state: Any  # packed[..., 2] — wave-2 bootstrap, or None
    C: np.ndarray  # (K, K) upper-triangular F2 co-occurrence counts
    prep_bytes: int  # per-shard footprint: sharded rows + F-list + packed
    rows_flist_bytes: int  # the threshold-independent part of prep_bytes
    stage_times: dict[str, float]  # job1_flist / job2_ppc_pack / f2_scan
    f1_only: bool = False  # True when built with need_waves=False
    n_shards: int = 1  # data-shard count (D) this prep was laid out for
    # False when the F-list order was imposed externally (``prepare(...,
    # flist=...)`` — the streaming path's shared global item order) instead
    # of derived support-descending from this database. Such preps are
    # segment building blocks for ``mine_prepared_segments``; the prefix
    # arithmetic ``mine_prepared`` leans on does not hold for them.
    support_ordered: bool = True

    def to_host(self) -> dict:
        """Gather the prep to a host payload (plain numpy + scalars) for
        cross-process persistence. ``packed`` keeps its ``(D, K, W, 3)``
        per-shard layout — each leading slice is one reducer's PPC-tree
        state, so the payload restores onto any mesh with the same data-
        shard count (``from_host`` enforces that)."""
        out = {
            "schema": PREPARED_SCHEMA,
            "n_items": int(self.n_items),
            "n_rows": int(self.n_rows),
            "min_count_floor": int(self.min_count_floor),
            "width": int(self.width),
            "f1_only": bool(self.f1_only),
            "support_ordered": bool(self.support_ordered),
            "n_shards": int(self.n_shards),
            "prep_bytes": int(self.prep_bytes),
            "rows_flist_bytes": int(self.rows_flist_bytes),
            "fl_min_count": int(self.fl.min_count),
            "fl_items": np.asarray(self.fl.items),
            "fl_supports": np.asarray(self.fl.supports),
            "C": np.asarray(self.C),
        }
        if self.packed is not None:
            out["packed"] = np.asarray(jax.device_get(self.packed))
        return out

    @classmethod
    def from_host(cls, payload: dict, miner: "HPrepostMiner") -> "PreparedDB":
        """Re-shard a ``to_host`` payload onto ``miner``'s mesh.

        Raises ``ValueError`` when the payload cannot serve on this mesh
        (schema skew, data-shard count mismatch, or shape/dtype corruption
        that slipped past the store's digests) — callers treat that as a
        snapshot miss and re-prepare. Prep stage times come back zeroed:
        a warm start pays no prep, and results must say so."""
        try:
            if int(payload["schema"]) != PREPARED_SCHEMA:
                raise ValueError(f"PreparedDB snapshot schema {payload['schema']!r} "
                                 f"!= {PREPARED_SCHEMA}")
            n_shards = int(payload["n_shards"])
            if n_shards != miner.D:
                raise ValueError(
                    f"snapshot was prepared for {n_shards} data shard(s) but the "
                    f"mesh has D={miner.D}; per-shard PPC state does not re-shard "
                    f"— re-prepare on this mesh"
                )
            fl = enc.FList(
                items=np.asarray(payload["fl_items"], np.int32),
                supports=np.asarray(payload["fl_supports"], np.int64),
                n_items=int(payload["n_items"]),
                min_count=int(payload["fl_min_count"]),
            )
            width = int(payload["width"])
            f1_only = bool(payload["f1_only"])
            C = np.asarray(payload["C"], np.int64)
            if C.shape != (fl.k, fl.k):
                raise ValueError(f"snapshot C has shape {C.shape}, expected {(fl.k, fl.k)}")
            packed = singleton = None
            if not f1_only and fl.k > 0:
                ph = np.asarray(payload["packed"], np.int32)
                want = (n_shards, fl.k, width, 3)
                if ph.shape != want:
                    raise ValueError(f"snapshot packed has shape {ph.shape}, expected {want}")
                packed = miner._shard(ph, P(miner._da, None, None, None))
                singleton = packed[:, :, :, 2]
        except (KeyError, TypeError, OverflowError) as e:
            raise ValueError(f"malformed PreparedDB snapshot payload: {e!r}") from e
        return cls(
            fl=fl,
            n_items=int(payload["n_items"]),
            n_rows=int(payload["n_rows"]),
            min_count_floor=int(payload["min_count_floor"]),
            width=width,
            packed=packed,
            singleton_state=singleton,
            C=C,
            prep_bytes=int(payload["prep_bytes"]),
            rows_flist_bytes=int(payload["rows_flist_bytes"]),
            stage_times={"job1_flist": 0.0, "job2_ppc_pack": 0.0, "f2_scan": 0.0},
            f1_only=f1_only,
            n_shards=n_shards,
            # pre-PR5 snapshots carry no key: they were all support-ordered
            support_ordered=bool(payload.get("support_ordered", True)),
        )

    def bytes_at(self, min_count: int, n_shards: int) -> int:
        """Per-shard prep footprint attributable to one threshold: rows +
        F-list + the N-list prefix of ranks frequent at ``min_count`` (the
        floor F-list is support-descending, so that prefix is exactly what
        an independent mine at this threshold would pack). Keeps the
        paper's memory-vs-min_sup figures threshold-dependent instead of
        flat at the sweep's loosest value."""
        packed_part = 0
        if self.packed is not None:
            packed_part = int(self.k_active(min_count) * self.width * 3 * 4 // max(n_shards, 1))
        return self.rows_flist_bytes + packed_part

    def k_active(self, min_count: int) -> int:
        """|F1| at ``min_count`` — a prefix length of the floor F-list."""
        return int(np.count_nonzero(np.asarray(self.fl.supports) >= min_count))


@dataclasses.dataclass
class SegmentHandle:
    """One segment's device state, ready for cross-segment wave execution.

    ``packed``/``singleton`` are the segment's N-list buffers with one extra
    all-invalid *sentinel* rank row appended (``extend_with_sentinel``);
    ``g2l`` maps every global stream rank to the segment's local rank, with
    ranks absent from the segment mapped to the sentinel. The kernel's
    padding semantics (``pre=INF, post=-1, cnt=0`` never subsumes and
    contributes zero) make a sentinel gather an exact empty N-list, so a
    candidate touching an item the segment never saw reports support 0
    there — precisely its contribution to the global (additive) support.
    """

    packed: Any  # (D, K_s + 1, W_s, 3) device N-lists incl. sentinel row
    singleton: Any  # packed[..., 2] — the segment's level-2 bootstrap
    g2l: np.ndarray  # (K_global,) int32: stream rank -> local rank | K_s


class LocalSegmentExecutor:
    """Runs planned waves over in-process segment handles — the execution
    half of ``mine_prepared_segments``, split from the planning loop so a
    coordinator can swap in a remote executor (workers over RPC) without
    touching the planner.

    Contract (shared with ``repro.mining.distributed``'s remote executor):

      - ``n_segments``: how many transaction partitions answer waves; 0
        short-circuits the wave loop (F1-only result).
      - ``begin()``: reset per-query state to the level-2 singleton
        bootstrap.
      - ``dispatch(level, parent_arr, base_idx, q_idx, use_local,
        stop_count=0)``: launch one planned wave over every segment;
        returns an opaque token. Must not block on device results
        (pipelining). ``stop_count`` is the in-kernel early-stop
        threshold — segmented supports are partial until the cross-
        segment reduce, so the planner always passes 0 here (masking
        against the global threshold would be unsound); host-side
        pruning carries the early-stop win instead.
      - ``collect(token)``: block, and return the per-candidate supports
        summed over this executor's segments as an int64 host vector —
        the paper's reduce step for this partition set. With ``weights``
        the reduce is instead the float64 weighted sum ``Σ w_s · sup_s``
        (time-decayed supports: the per-segment integer supports stay
        exact on device; damping happens only in this host reduce).
      - ``weights``: optional per-segment float weights, or None for the
        exact integer reduce — the planner reads this attribute to decide
        integer vs float threshold semantics.
      - ``state_bytes``: footprint of the in-flight merged-N-list states
        after the latest dispatch/collect (peak accounting).
    """

    def __init__(self, miner: "HPrepostMiner", handles: "list[SegmentHandle]",
                 weights=None):
        self.miner = miner
        self.handles = list(handles)
        if weights is not None:
            weights = np.asarray(weights, np.float64)
            if len(weights) != len(self.handles):
                raise ValueError(
                    f"{len(weights)} segment weights for {len(self.handles)} handles"
                )
        self.weights = weights
        self._prev: list | None = None
        self.state_bytes = 0

    @property
    def n_segments(self) -> int:
        return len(self.handles)

    def begin(self) -> None:
        self._prev = [h.singleton for h in self.handles]
        self.state_bytes = 0

    def dispatch(self, level, parent_arr, base_idx, q_idx, use_local,
                 stop_count=0):
        m = self.miner
        failures.fire("mine.wave")
        wave_fn = m._wave_local if use_local else m._wave
        new_states, parts = [], []
        for h, prev in zip(self.handles, self._prev):
            # level-2 parents are singleton ranks (per-segment rows);
            # later levels gather by global slot, shared by layout
            p_arr = h.g2l[parent_arr] if level == 2 else parent_arr
            plan = m._kernel_plan(len(parent_arr), h.packed.shape[2])
            new_s, sup_s = wave_fn(
                h.packed,
                prev,
                m._shard(p_arr, m._cand_spec),
                m._shard(h.g2l[base_idx], m._cand_spec),
                m._shard(h.g2l[q_idx], m._cand_spec),
                np.int32(stop_count),
                la_block=plan.la_block,
                ly_block=plan.ly_block,
                batch_block=plan.batch_block,
                backend=plan.backend,
                early_stop=plan.early_stop,
            )
            new_states.append(new_s)
            parts.append(sup_s)
        m.stage_counters["waves"] += 1
        m.stage_counters["seg_waves"] = (
            m.stage_counters.get("seg_waves", 0) + len(self.handles)
        )
        self._prev = new_states
        self.state_bytes = sum(
            int(s.size * 4 // max(m.D * m._Mb, 1)) for s in new_states
        )
        return parts

    def collect(self, parts) -> np.ndarray:
        arrs = jax.device_get(parts)
        stacked = np.stack(arrs, axis=0)
        if self.weights is not None:
            return np.tensordot(self.weights, stacked.astype(np.float64), axes=1)
        return np.sum(stacked, axis=0, dtype=np.int64)


def _pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class HPrepostMiner:
    """Distributed N-list miner bound to a mesh.

    ``data_axis`` may name multiple mesh axes (e.g. ``("pod", "data")``) —
    DB blocks shard over all of them; ``model_axis`` shards the candidate
    space (mode B). ``model_axis=None`` degrades to pure mode A.
    """

    def __init__(
        self,
        mesh: jax.sharding.Mesh,
        data_axis: str | tuple[str, ...] = "data",
        model_axis: str | None = "model",
        config: HPrepostConfig = HPrepostConfig(),
    ):
        self.mesh = mesh
        self.data_axis = (data_axis,) if isinstance(data_axis, str) else tuple(data_axis)
        self.model_axis = model_axis
        self.cfg = config
        self.D = int(np.prod([mesh.shape[a] for a in self.data_axis]))
        self.M = int(mesh.shape[model_axis]) if model_axis else 1
        self._cand_spec = (
            P(self.model_axis)
            if (self.cfg.partition_candidates and self.model_axis)
            else P()
        )
        self.last_stage_times: dict[str, float] = {}
        # how many times each device stage ran over this miner's lifetime —
        # the engine's shared-prep planning is asserted against these
        self.stage_counters: dict[str, int] = {
            "job1": 0, "job2": 0, "pack": 0, "f2": 0, "waves": 0
        }
        # KernelPlan resolution: the owning frontend/engine attaches a
        # ``KernelTuner`` here; with ``cfg.tune`` off (or no tuner) plans
        # come straight from the config knobs. Memoized per wave shape.
        self.tuner = None
        self._plan_cache: dict[tuple[int, int], Any] = {}
        self._build_jits()

    def _kernel_plan(self, n_cands: int, width: int):
        """Resolve the execution plan (concrete backend + block knobs) for a
        wave of ``n_cands`` candidates over ``width``-slot N-lists."""
        from repro.mining import tune

        key = (tune._bucket(n_cands, 8, 512), tune._bucket(width, 8, 1024))
        plan = self._plan_cache.get(key)
        if plan is None:
            cfg = self.cfg
            if cfg.tune and self.tuner is not None:
                plan = self.tuner.plan_for(
                    backend=cfg.backend, B=n_cands, W=width,
                    early_stop=cfg.early_stop,
                    defaults=(cfg.la_block, cfg.ly_block, cfg.batch_block),
                )
            else:
                plan = tune.static_plan(
                    cfg.backend, cfg.la_block, cfg.ly_block, cfg.batch_block,
                    cfg.early_stop,
                )
            self._plan_cache[key] = plan
        return plan

    @property
    def _da(self):
        return self.data_axis if len(self.data_axis) > 1 else self.data_axis[0]

    def _shard(self, arr: np.ndarray, spec: P) -> jax.Array:
        return jax.device_put(arr, NamedSharding(self.mesh, spec))

    # ------------------------------------------------------------------ jits
    def _build_jits(self):
        cfg = self.cfg
        mesh = self.mesh
        da = self._da
        cand_spec = self._cand_spec

        @functools.partial(jax.jit, static_argnames=("n_items",))
        def job1(rows, *, n_items):
            def body(block):
                h = item_histogram(block, n_bins=n_items, backend=cfg.backend)
                return jax.lax.psum(h, da)

            return shard_map(body, mesh=mesh, in_specs=P(da, None), out_specs=P())(rows)

        @functools.partial(jax.jit, static_argnames=("max_nodes", "k", "n_items"))
        def job2(rows, lut, *, max_nodes, k, n_items):
            def body(block, lut):
                ranked = enc.rank_encode_jnp(block, lut, n_items)
                w = jnp.ones(block.shape[0], jnp.int32)
                item, count, pre, post, valid = build_ppc_jnp(ranked, w, max_nodes, n_items=k)
                lens = jax.ops.segment_sum(
                    jnp.where(valid, 1, 0), jnp.where(valid, item, k), num_segments=k + 1
                )[:k]
                lens = jax.lax.pmax(lens, da)
                return ranked[None], item[None], count[None], pre[None], post[None], lens

            return shard_map(
                functools.partial(body, lut=lut),
                mesh=mesh,
                in_specs=P(da, None),
                out_specs=(P(da, None), P(da), P(da), P(da), P(da), P()),
            )(rows)

        @functools.partial(jax.jit, static_argnames=("k", "width"))
        def pack(item, count, pre, post, *, k, width):
            def body(item, count, pre, post):
                item, count, pre, post = item[0], count[0], pre[0], post[0]
                n = item.shape[0]
                # lexsort avoids int32 overflow of a combined item*n+pre key
                order = jnp.lexsort((jnp.minimum(pre, n), item))
                sitem = item[order]
                boundaries = jnp.searchsorted(sitem, jnp.arange(k + 1))
                slot = jnp.arange(n) - boundaries[jnp.clip(sitem, 0, k)]
                valid = (sitem >= 0) & (slot < width)
                flat = jnp.where(valid, jnp.clip(sitem, 0, k - 1) * width + slot, k * width)
                packed = jnp.full((k * width + 1, 3), jnp.array([INF32, -1, 0]), jnp.int32)
                vals = jnp.stack(
                    [pre[order].astype(jnp.int32), post[order].astype(jnp.int32),
                     count[order].astype(jnp.int32)], axis=1)
                vals = jnp.where(valid[:, None], vals, jnp.array([INF32, -1, 0], jnp.int32))
                packed = packed.at[flat].set(vals, mode="drop")
                return packed[: k * width].reshape(1, k, width, 3)

            return shard_map(
                body, mesh=mesh, in_specs=(P(da),) * 4,
                out_specs=P(da, None, None, None),
            )(item, count, pre, post)

        @functools.partial(jax.jit, static_argnames=("k",))
        def jobf2(rows, *, k):
            def body(block):
                C = cooccurrence_matrix(block[0], n_items=k, backend=cfg.backend)
                return jax.lax.psum(C, da)

            return shard_map(body, mesh=mesh, in_specs=P(da, None), out_specs=P())(rows)

        # the resolved KernelPlan rides in as static kwargs: block knobs and
        # backend pick a lowering, not a value — retraces happen per plan,
        # exactly like the per-shape-bucket retraces the buffers already pay.
        # ``stop`` is the dynamic in-kernel early-stop threshold (0 = off; see
        # mine_prepared for when a nonzero threshold is sound).
        plan_static = ("la_block", "ly_block", "batch_block", "backend",
                       "early_stop")

        @functools.partial(jax.jit, static_argnames=plan_static)
        def wave(packed, prev_state, parent_idx, base_idx, q_idx, stop, *,
                 la_block, ly_block, batch_block, backend, early_stop):
            # MapReduce shuffle: route parent rows to their candidates
            # (paper-faithful MRPrepost-style partitioning — the take crosses
            # shards and XLA emits the shuffle collectives)
            state = jnp.take(prev_state, parent_idx, axis=1)
            state = jax.lax.with_sharding_constraint(
                state, NamedSharding(mesh, P(da, *cand_spec, None))
            )

            def body(packed, state, base_idx, q_idx, stop):
                packed, state = packed[0], state[0]  # (K, W, 3), (C_l, W)
                a = packed[q_idx]
                y = packed[base_idx]
                # fused kernel: per-shard partial supports fall out of the
                # intersection itself — only the scalar psum leaves the shard
                new, part = nlist_intersect(
                    a[:, :, 0], a[:, :, 1], y[:, :, 0], y[:, :, 1], state,
                    a_cnt=a[:, :, 2], backend=backend, la_block=la_block,
                    ly_block=ly_block, batch_block=batch_block,
                    early_stop=early_stop, min_count=stop,
                )
                sup = jax.lax.psum(part, da)
                return new[None], sup

            return shard_map(
                body, mesh=mesh,
                in_specs=(P(da, None, None, None), P(da, *cand_spec, None),
                          cand_spec, cand_spec, P()),
                out_specs=(P(da, *cand_spec, None), cand_spec),
            )(packed, state, base_idx, q_idx, stop)

        @functools.partial(jax.jit, static_argnames=plan_static)
        def wave_local(packed, prev_state, parent_local, base_idx, q_idx, stop,
                       *, la_block, ly_block, batch_block, backend, early_stop):
            # locality-aware dispatch (beyond-paper, §Perf FIM): children sit
            # on their parent's shard, so the parent gather is shard-local —
            # the shuffle disappears; only the support psum remains.
            def body(packed, prev, pidx, bidx, qidx, stop):
                packed, prev = packed[0], prev[0]  # (K, W, 3), (Cprev_l, W)
                state = prev[pidx]  # local rows only
                a = packed[qidx]
                y = packed[bidx]
                new, part = nlist_intersect(
                    a[:, :, 0], a[:, :, 1], y[:, :, 0], y[:, :, 1], state,
                    a_cnt=a[:, :, 2], backend=backend, la_block=la_block,
                    ly_block=ly_block, batch_block=batch_block,
                    early_stop=early_stop, min_count=stop,
                )
                sup = jax.lax.psum(part, da)
                return new[None], sup

            return shard_map(
                body, mesh=mesh,
                in_specs=(
                    P(da, None, None, None),
                    P(da, *cand_spec, None),
                    cand_spec,
                    cand_spec,
                    cand_spec,
                    P(),
                ),
                out_specs=(P(da, *cand_spec, None), cand_spec),
            )(packed, prev_state, parent_local, base_idx, q_idx, stop)

        self._job1, self._job2, self._pack, self._jobf2 = job1, job2, pack, jobf2
        self._wave, self._wave_local = wave, wave_local

    # ---------------------------------------------------------------- driver
    @property
    def _Mb(self) -> int:
        return max(self.M, 1) if (self.cfg.partition_candidates and self.model_axis) else 1

    def prepare(
        self, rows: np.ndarray, n_items: int, min_count_floor: int, *,
        need_waves: bool = True, flist: enc.FList | None = None,
    ) -> PreparedDB:
        """Run every threshold-floor stage once: Job 1 (histogram/F-list),
        Job 2 (PPC-tree), N-list pack, F2 scan. The result serves any
        ``mine_prepared`` at ``min_count >= min_count_floor``.

        ``need_waves=False`` stops after the F-list (for ``max_k == 1``
        traffic, where the tree/N-lists are never consulted).

        ``flist`` imposes an external item order instead of deriving it
        support-descending from this database — the streaming path's global
        stream order, which every segment must share so cross-segment
        N-list ancestor relations agree (PrePost correctness needs one
        consistent total order, not specifically the support order). Job 1
        is skipped then (the caller already counted the batch), and the
        result is marked ``support_ordered=False``: it can only be mined
        through ``mine_prepared_segments``."""
        cfg = self.cfg
        stages: dict[str, float] = {}
        t0 = time.perf_counter()
        R0, L = rows.shape
        Rp = (R0 + self.D - 1) // self.D * self.D
        # the Pallas intersect kernel accumulates counts in fp32 (exact only
        # below 2^24); every count it can produce is bounded by the shard's
        # transaction count, so refuse shards that could silently wrap. The
        # jnp path is integer-exact — only the Pallas dispatch is guarded.
        from repro.kernels.nlist_intersect.ops import FP32_EXACT_MAX
        from repro.mining.tune import is_pallas, resolve_backend

        if is_pallas(resolve_backend(cfg.backend)) and Rp // self.D >= FP32_EXACT_MAX:
            raise ValueError(
                f"per-shard row count {Rp // self.D} reaches the fp32 exact-"
                f"integer bound 2^24; shard the database over more devices "
                f"(D={self.D}) so N-list counts stay exactly representable"
            )
        rows_p = np.full((Rp, L), enc.PAD, np.int32)
        rows_p[:R0] = rows
        rows_sharded = self._shard(rows_p, P(self._da, None))

        if flist is None:
            supports = np.asarray(jax.device_get(self._job1(rows_sharded, n_items=n_items)))
            self.stage_counters["job1"] += 1
            fl = enc.build_flist(supports, min_count_floor)
        else:
            if flist.n_items != n_items:
                raise ValueError(
                    f"imposed flist covers {flist.n_items} items, database has {n_items}"
                )
            fl = flist
        stages["job1_flist"] = time.perf_counter() - t0
        K = fl.k
        if K > cfg.max_f1:
            raise ValueError(f"|F1|={K} exceeds max_f1={cfg.max_f1}; raise min_count or max_f1")

        rows_flist_bytes = int(rows_p.nbytes // max(self.D, 1))
        rows_flist_bytes += int(fl.items.nbytes + fl.supports.nbytes)
        prep_bytes = rows_flist_bytes
        stages["job2_ppc_pack"] = 0.0
        stages["f2_scan"] = 0.0
        packed = singleton = None
        C = np.zeros((K, K), np.int64)
        W = 0
        if K > 0 and need_waves:
            t0 = time.perf_counter()
            max_nodes = (Rp // self.D) * L
            ranked, item, count, pre, post, lens = self._job2(
                rows_sharded, jnp.asarray(fl.rank_lut()), max_nodes=max_nodes, k=K, n_items=n_items
            )
            self.stage_counters["job2"] += 1
            w_needed = int(np.asarray(jax.device_get(lens)).max(initial=1))
            W = cfg.nlist_width or _pow2(max(w_needed, 8))
            packed = self._pack(item, count, pre, post, k=K, width=W)
            self.stage_counters["pack"] += 1
            stages["job2_ppc_pack"] = time.perf_counter() - t0

            t0 = time.perf_counter()
            if K > 1:
                C = np.asarray(jax.device_get(self._jobf2(ranked, k=K)))
                self.stage_counters["f2"] += 1
            C = np.triu(C, 1)
            stages["f2_scan"] = time.perf_counter() - t0
            prep_bytes += int(packed.size * 4 // max(self.D, 1))
            # level-2 bootstrap: parents are singletons, prev_state = node
            # counts (replicated over `model`: the bootstrap take is
            # collective-free)
            singleton = packed[:, :, :, 2]

        return PreparedDB(
            fl=fl, n_items=n_items, n_rows=R0, min_count_floor=int(min_count_floor),
            width=W, packed=packed, singleton_state=singleton, C=C,
            prep_bytes=prep_bytes, rows_flist_bytes=rows_flist_bytes,
            stage_times=stages, f1_only=not need_waves, n_shards=self.D,
            support_ordered=flist is None,
        )

    def _pack_wave(self, ranks, parents, qarr, level: int, slots_per_shard: int):
        """Host slot assignment for one wave: candidate i -> device slot.

        Pure array ops — candidate counts reach 10^5+ per wave, and this
        runs on the serial host rail the pipelined waves overlap with.
        ``ranks`` is (C, k) ascending rank rows; ``parents`` the previous-
        wave slots; ``qarr`` the extension ranks.

        -> (parent_arr, base_idx, q_idx, slot_of, Cpad, wave_fn)."""
        cfg = self.cfg
        unit = cfg.candidate_unit
        Mb = self._Mb
        Cn = len(ranks)
        base = ranks[:, 1].astype(np.int32)
        if level == 2 or not cfg.locality_dispatch:
            Cs = unit * _pow2((Cn + unit * Mb - 1) // (unit * Mb))
            Cpad = Cs * Mb
            slot_of = np.arange(Cn, dtype=np.int64)  # candidate i -> slot i
            parent_arr = np.zeros(Cpad, np.int32)
            base_idx = np.zeros(Cpad, np.int32)
            q_idx = np.zeros(Cpad, np.int32)
            parent_arr[:Cn] = parents
            base_idx[:Cn] = base
            q_idx[:Cn] = qarr
            return parent_arr, base_idx, q_idx, slot_of, Cpad, self._wave

        # locality-aware: bucket children onto their parent's shard; the
        # stable argsort over bucket ids yields each candidate's rank within
        # its bucket without any per-candidate loop
        bucket = np.minimum(parents.astype(np.int64) // slots_per_shard, Mb - 1)
        counts = np.bincount(bucket, minlength=Mb)
        worst = int(counts.max())
        Cs = unit * _pow2((worst + unit - 1) // unit)
        Cpad = Cs * Mb
        order = np.argsort(bucket, kind="stable")
        starts = np.concatenate(([0], np.cumsum(counts)[:-1]))
        pos = np.empty(Cn, np.int64)
        pos[order] = np.arange(Cn) - starts[bucket[order]]
        slot_of = bucket * Cs + pos
        parent_arr = np.zeros(Cpad, np.int32)
        base_idx = np.zeros(Cpad, np.int32)
        q_idx = np.zeros(Cpad, np.int32)
        parent_arr[slot_of] = parents % slots_per_shard  # local row
        base_idx[slot_of] = base
        q_idx[slot_of] = qarr
        return parent_arr, base_idx, q_idx, slot_of, Cpad, self._wave_local

    @staticmethod
    def _extensions(ranks, slots, pair_packed, prefix_packed, k_items):
        """Candidate generation: extend each rank row with every rank
        ``q2 < ranks[0]`` whose pairs with all members are frequent.

        Vectorized over the whole wave: the per-candidate allowed set is the
        bitwise AND of the gathered bit-packed ``pair_ok`` rows of its
        members, masked by the packed strict-lower-triangle prefix row of
        its smallest rank — no per-candidate Python loop.

        -> (ranks', parents', q') with ranks' of width ``ranks.shape[1]+1``."""
        k = ranks.shape[1]
        if not len(ranks):
            return (np.empty((0, k + 1), np.int32), np.empty(0, np.int64),
                    np.empty(0, np.int32))
        allowed = np.bitwise_and.reduce(pair_packed[ranks], axis=1)  # (C, Kb)
        allowed &= prefix_packed[ranks[:, 0]]
        mask = np.unpackbits(allowed, axis=1, count=k_items).view(bool)
        cs, q2s = np.nonzero(mask)
        new_ranks = np.concatenate(
            [q2s[:, None].astype(np.int32), ranks[cs]], axis=1
        )
        return new_ranks, slots[cs], q2s.astype(np.int32)

    @staticmethod
    def _apriori_kept(d_ranks: np.ndarray, surv_ranks: np.ndarray):
        """Anti-monotone host bound, boolean form: a width-``l+1`` candidate
        can reach ``min_count`` only if *every* drop-one subset of width
        ``l`` survived the settled wave — the enumeration guarantees every
        frequent width-``l`` itemset is in ``surv_ranks``, so a missing
        subset proves the candidate doomed. Position 0 (the extension item)
        is the parent the caller already checked; pair subsets are implied
        by ``pair_ok`` — so this only bites from width 4 up, and returns
        None below that.

        Membership is vectorized by viewing C-contiguous int32 rank rows as
        fixed-width byte strings: at equal total width, numpy's trailing-
        NUL-stripping compare is still an exact row equality."""
        l1 = d_ranks.shape[1]
        if l1 < 4 or not len(d_ranks) or not len(surv_ranks):
            return None
        w = l1 - 1
        sv = np.ascontiguousarray(surv_ranks).view(f"S{4 * w}").ravel()
        kept = np.ones(len(d_ranks), bool)
        for pos in range(1, l1):
            sub = np.ascontiguousarray(
                np.concatenate([d_ranks[:, :pos], d_ranks[:, pos + 1:]], axis=1)
            )
            kept &= np.isin(sub.view(f"S{4 * w}").ravel(), sv)
            if not kept.any():
                break
        return kept

    def mine_prepared(
        self,
        prepared: PreparedDB,
        min_count: int,
        *,
        max_k: int | None | type(Ellipsis) = ...,
    ) -> PrepostResult:
        """The k>2 wave loop only, over a shared ``PreparedDB``. Any
        ``min_count >= prepared.min_count_floor`` is served exactly: floor
        structures are supersets, N-list supports are exact DB supports.

        With ``cfg.pipeline_waves`` the loop dispatches wave ``l+1`` before
        blocking on wave ``l``'s supports, so host candidate generation
        overlaps device execution. The one wave of speculation is sound:
        children of candidates that turn out infrequent report supports
        below ``min_count`` themselves (anti-monotonicity), so they can
        never be emitted; once the parent wave's supports arrive, the dead
        branches are pruned from further host enumeration.
        """
        cfg = self.cfg
        max_k = cfg.max_k if max_k is ... else max_k
        if not prepared.support_ordered:
            raise ValueError(
                "PreparedDB was built with an imposed (stream-order) F-list; "
                "its F-list is not a support-descending prefix structure — "
                "mine it through mine_prepared_segments"
            )
        if min_count < prepared.min_count_floor:
            raise ValueError(
                f"min_count={min_count} is looser than the PreparedDB floor "
                f"{prepared.min_count_floor}; re-prepare at the looser threshold"
            )
        fl = prepared.fl
        K = fl.k
        stages = self.last_stage_times = {
            "job1_flist": 0.0, "job2_ppc_pack": 0.0, "f2_scan": 0.0,
            "mining_waves": 0.0,
            # planning counters ride the stage dict into MineResult
            # stage_times_s: candidates shipped, and candidates the host
            # bound killed (dead parent / missing Apriori subset)
            "planned_candidates": 0.0,
            "host_pruned_parent": 0.0, "host_pruned_subset": 0.0,
        }
        itemsets: dict[tuple[int, ...], int] = {}
        k_act = prepared.k_active(min_count)
        items_arr = np.asarray(fl.items)
        for it, s in zip(
            items_arr[:k_act].tolist(), np.asarray(fl.supports)[:k_act].tolist()
        ):
            itemsets[(int(it),)] = int(s)
        # per-threshold views of the shared floor structures: the F-list
        # prefix and footprint an independent mine at min_count would build
        # (keeps sweep results threshold-dependent, not flat at the floor)
        flist_items = fl.items[:k_act]
        peak = prepared.bytes_at(min_count, self.D)
        if K == 0 or max_k == 1 or not itemsets:
            return PrepostResult(itemsets, flist_items, len(itemsets), len(itemsets), peak)
        if prepared.f1_only:
            raise ValueError(
                "PreparedDB was built with need_waves=False (F1 only); "
                "re-prepare with need_waves=True to mine k >= 2"
            )

        C = prepared.C
        pair_ok = (C + C.T) >= min_count
        # bit-packed planning tables for the vectorized _extensions:
        # pair_packed[r] is pair_ok's row r, prefix_packed[r] the strict
        # prefix mask {q2 : q2 < r} — both 8 ranks per byte
        pair_packed = np.packbits(pair_ok, axis=1)
        prefix_packed = np.packbits(np.tri(K, K, -1, dtype=bool), axis=1)
        packed = prepared.packed
        prev_state = prepared.singleton_state
        qs, ps = np.nonzero(C >= min_count)
        ranks = np.stack([qs, ps], axis=1).astype(np.int32)  # (C, 2) ascending
        parents = ps.astype(np.int64)  # level-2 parents: singleton rank slots
        qarr = qs.astype(np.int32)
        level = 2
        Mb = self._Mb
        slots_per_shard = 0  # of the *previous* wave (for locality bucketing)
        pending = None  # (ranks, slot_of, device supports) of the wave in flight
        # in-kernel early stop is only sound where the kernel sees *final*
        # supports: one data shard (no cross-shard psum completes them
        # later). Off (0) it costs nothing — the mask multiplies by 1.0.
        stop_count = min_count if (cfg.early_stop and self.D == 1) else 0

        t0 = time.perf_counter()
        while len(ranks) or pending is not None:
            dispatched = None
            if len(ranks) and (max_k is None or level <= max_k) and len(itemsets) < cfg.max_itemsets:
                parent_arr, base_idx, q_idx, slot_of, Cpad, wave_fn = self._pack_wave(
                    ranks, parents, qarr, level, slots_per_shard
                )
                plan = self._kernel_plan(Cpad, prepared.width)
                stages["planned_candidates"] += float(len(ranks))
                failures.fire("mine.wave")
                with trace.span("mine.wave", k=level, candidates=len(ranks)):
                    new_state, sups = wave_fn(
                        packed,
                        prev_state,
                        self._shard(parent_arr, self._cand_spec),
                        self._shard(base_idx, self._cand_spec),
                        self._shard(q_idx, self._cand_spec),
                        np.int32(stop_count),
                        la_block=plan.la_block,
                        ly_block=plan.ly_block,
                        batch_block=plan.batch_block,
                        backend=plan.backend,
                        early_stop=plan.early_stop,
                    )
                self.stage_counters["waves"] += 1
                dispatched = (ranks, parents, slot_of, sups)
                peak = max(peak, int(new_state.size * 4 // max(self.D * Mb, 1)))
                prev_state = new_state
                slots_per_shard = Cpad // Mb
                level += 1
            if not cfg.pipeline_waves and dispatched is not None:
                # degrade: block right away (no speculative wave in flight,
                # so the parent column is never consulted)
                pending = (dispatched[0], dispatched[2], dispatched[3])
                dispatched = None

            surv_mask = None  # boolean over the settled wave's device slots
            surv_ranks = surv_slots = None
            if pending is not None:
                p_ranks, p_slots, p_sups = pending
                with trace.span("mine.reduce", k=level - 1):
                    host = np.asarray(jax.device_get(p_sups))  # blocks on wave l-1
                svals = host[p_slots]
                keep = svals >= min_count
                if keep.any():
                    emit_items = np.sort(items_arr[p_ranks[keep]], axis=1)
                    for t, s in zip(emit_items.tolist(), svals[keep].tolist()):
                        itemsets[tuple(t)] = int(s)
                surv_mask = np.zeros(host.shape[0], bool)
                surv_mask[p_slots[keep]] = True
                surv_ranks, surv_slots = p_ranks[keep], p_slots[keep]
                pending = None

            if dispatched is not None:
                d_ranks, d_parents, d_slot_of, d_sups = dispatched
                if surv_mask is not None:
                    # speculative wave l was enumerated before wave l-1's
                    # supports arrived; drop children of dead parents from
                    # further enumeration (their own supports self-filter)
                    kept = surv_mask[d_parents]
                    stages["host_pruned_parent"] += float((~kept).sum())
                    d_ranks, d_slot_of = d_ranks[kept], d_slot_of[kept]
                    if cfg.early_stop:
                        sub = self._apriori_kept(d_ranks, surv_ranks)
                        if sub is not None:
                            stages["host_pruned_subset"] += float((~sub).sum())
                            d_ranks, d_slot_of = d_ranks[sub], d_slot_of[sub]
                pending = (d_ranks, d_slot_of, d_sups)
                ranks, parents, qarr = self._extensions(
                    d_ranks, d_slot_of, pair_packed, prefix_packed, K
                )
            elif surv_mask is not None and not cfg.pipeline_waves:
                ranks, parents, qarr = self._extensions(
                    surv_ranks, surv_slots, pair_packed, prefix_packed, K
                )
                if cfg.early_stop and len(ranks):
                    # un-pipelined, the closure check lands *before* dispatch:
                    # doomed candidates never ship to the device at all
                    sub = self._apriori_kept(ranks, surv_ranks)
                    if sub is not None:
                        stages["host_pruned_subset"] += float((~sub).sum())
                        ranks, parents, qarr = ranks[sub], parents[sub], qarr[sub]
            else:
                ranks = np.empty((0, 2), np.int32)
                parents = np.empty(0, np.int64)
                qarr = np.empty(0, np.int32)

        stages["mining_waves"] = time.perf_counter() - t0
        return PrepostResult(itemsets, flist_items, len(itemsets), len(itemsets), peak)

    def extend_with_sentinel(self, prepared: PreparedDB):
        """``(packed_ext, singleton_ext)``: the prepared N-list buffers with
        one all-invalid rank row appended at index ``K_s`` — the slot
        ``SegmentHandle.g2l`` routes globally-known-but-locally-absent items
        to. Re-device_put keeps the per-shard layout explicit."""
        if prepared.packed is None:
            raise ValueError("cannot extend an F1-only PreparedDB (no N-lists packed)")
        pad = np.broadcast_to(
            np.array([INF32, -1, 0], np.int32), (self.D, 1, prepared.width, 3)
        )
        ext = jnp.concatenate([prepared.packed, jnp.asarray(pad)], axis=1)
        ext = jax.device_put(ext, NamedSharding(self.mesh, P(self._da, None, None, None)))
        return ext, ext[:, :, :, 2]

    def mine_prepared_segments(
        self,
        handles: "list[SegmentHandle]",
        items: np.ndarray,
        supports: np.ndarray,
        C: np.ndarray,
        min_count: int,
        *,
        max_k: int | None | type(Ellipsis) = ...,
        peak_base: int = 0,
        executor=None,
        weights=None,
        seed=None,
        seed_out=None,
    ) -> PrepostResult:
        """The k>2 wave loop over a *segmented* database (the streaming
        reduce step): candidates are planned once against the global
        F-lists (``items``/``supports`` in stream-rank order, ``C`` the
        summed upper-triangular F2 matrix in the same rank space), each
        wave launches the fused intersect kernel once per segment, and the
        per-candidate supports are summed across segments before
        thresholding — exact because segments partition the transactions,
        so itemset supports are additive over them.

        Every segment carries its own merged-N-list state chain between
        waves (a segment is one partition's PPC forest); the *slot* layout
        (``_pack_wave``) is global and shared, so parent gathers at levels
        > 2 need no per-segment translation — only base/extension item
        indices (and the level-2 singleton parents) route through each
        segment's ``g2l``. Pipelining semantics match ``mine_prepared``.

        ``executor`` abstracts *where* waves run: the default
        ``LocalSegmentExecutor(self, handles)`` executes them in-process
        (exactly the pre-refactor behavior); ``repro.mining.distributed``
        passes a remote executor that broadcasts each wave to worker
        processes and sums their support vectors — the planning loop here
        is identical either way, which is what makes the distributed path
        bit-identical by construction.

        ``weights`` (or an executor carrying a ``weights`` attribute)
        switches the cross-segment reduce to the float64 weighted sum of
        time-decayed mining: ``supports``/``C``/``min_count`` are then
        read as float accumulations and emitted supports are floats; the
        per-segment device path is untouched (integer-exact), only the
        host reduce and threshold run in float.

        ``seed`` prunes with a standing query's previous waves (exact
        integer mode only): a dict of per-itemset support *upper bounds*
        — typically the exact supports the previous refresh collected,
        inflated by the rows appended since (each new row raises any
        support by at most 1, and expiry only lowers it). A candidate
        whose bound misses ``min_count`` is provably infrequent and is
        dropped before dispatch (``host_pruned_seed``) along with — by
        anti-monotonicity — the whole subtree it would have opened; a
        candidate absent from the seed is always kept. The emitted
        answer is therefore bit-identical to an unseeded mine.
        ``seed_out``, if a dict, collects the exact reduced support of
        every candidate this mine settles (frequent or not) — the raw
        material for the next refresh's seed.
        """
        cfg = self.cfg
        max_k = cfg.max_k if max_k is ... else max_k
        items_arr = np.asarray(items, np.int32)
        if executor is None:
            executor = LocalSegmentExecutor(self, handles, weights=weights)
        elif weights is not None:
            raise ValueError(
                "pass decay weights through the executor, not alongside one"
            )
        weighted = getattr(executor, "weights", None) is not None
        supports = np.asarray(supports, np.float64 if weighted else np.int64)
        as_sup = float if weighted else int
        K = len(items_arr)
        stages = self.last_stage_times = {
            "job1_flist": 0.0, "job2_ppc_pack": 0.0, "f2_scan": 0.0,
            "mining_waves": 0.0,
            "planned_candidates": 0.0,
            "host_pruned_parent": 0.0, "host_pruned_subset": 0.0,
            "host_pruned_seed": 0.0,
        }
        itemsets: dict[tuple[int, ...], int] = {}
        freq = supports >= min_count
        # result F-list stays support-descending (ties: item asc) whatever
        # the stream-rank order is — the contract every miner reports
        f_items = items_arr[freq]
        f_sups = supports[freq]
        order = np.lexsort((f_items, -f_sups))
        flist_items = f_items[order]
        for it, s in zip(flist_items.tolist(), f_sups[order].tolist()):
            itemsets[(int(it),)] = as_sup(s)
        peak = int(peak_base)
        if K == 0 or max_k == 1 or not itemsets or executor.n_segments == 0:
            return PrepostResult(itemsets, flist_items, len(itemsets), len(itemsets), peak)

        seed_keep = None
        if seed is not None and not weighted:

            def seed_keep(ranks_):
                cand = np.sort(items_arr[ranks_], axis=1)
                return np.fromiter(
                    (seed.get(tuple(t), min_count) >= min_count
                     for t in cand.tolist()),
                    bool, len(cand),
                )

        pair_ok = (C + C.T) >= min_count
        pair_packed = np.packbits(pair_ok, axis=1)
        prefix_packed = np.packbits(np.tri(K, K, -1, dtype=bool), axis=1)
        executor.begin()
        qs, ps = np.nonzero(C >= min_count)
        ranks = np.stack([qs, ps], axis=1).astype(np.int32)
        parents = ps.astype(np.int64)
        qarr = qs.astype(np.int32)
        level = 2
        Mb = self._Mb
        slots_per_shard = 0
        pending = None  # (ranks, slot_of, [per-segment device supports])

        t0 = time.perf_counter()
        while len(ranks) or pending is not None:
            if seed_keep is not None and len(ranks):
                km = seed_keep(ranks)
                if not km.all():
                    stages["host_pruned_seed"] += float((~km).sum())
                    ranks, parents, qarr = ranks[km], parents[km], qarr[km]
            dispatched = None
            if len(ranks) and (max_k is None or level <= max_k) and len(itemsets) < cfg.max_itemsets:
                parent_arr, base_idx, q_idx, slot_of, Cpad, wave_fn = self._pack_wave(
                    ranks, parents, qarr, level, slots_per_shard
                )
                # stop_count stays 0: per-segment supports are partial until
                # the cross-segment reduce, so only the host bound prunes here
                stages["planned_candidates"] += float(len(ranks))
                with trace.span("mine.wave", k=level, candidates=len(ranks),
                                segments=executor.n_segments):
                    token = executor.dispatch(
                        level, parent_arr, base_idx, q_idx, wave_fn is self._wave_local
                    )
                dispatched = (ranks, parents, slot_of, token)
                peak = max(peak, int(executor.state_bytes))
                slots_per_shard = Cpad // Mb
                level += 1
            if not cfg.pipeline_waves and dispatched is not None:
                pending = (dispatched[0], dispatched[2], dispatched[3])
                dispatched = None

            surv_mask = None
            surv_ranks = surv_slots = None
            if pending is not None:
                p_ranks, p_slots, p_token = pending
                # the streaming reduce: per-candidate supports summed over
                # segments (additivity over disjoint partitions), THEN
                # thresholded — this blocks on the settled wave
                with trace.span("mine.reduce", k=level - 1):
                    host = executor.collect(p_token)
                peak = max(peak, int(executor.state_bytes))
                svals = host[p_slots]
                keep = svals >= min_count
                if seed_out is not None and len(p_ranks):
                    # exact settled supports of EVERY candidate (dead ones
                    # included — near-frontier corpses are what the next
                    # refresh's seed prunes)
                    all_items = np.sort(items_arr[p_ranks], axis=1)
                    for t, s in zip(all_items.tolist(), svals.tolist()):
                        seed_out[tuple(t)] = as_sup(s)
                if keep.any():
                    emit_items = np.sort(items_arr[p_ranks[keep]], axis=1)
                    for t, s in zip(emit_items.tolist(), svals[keep].tolist()):
                        itemsets[tuple(t)] = as_sup(s)
                surv_mask = np.zeros(host.shape[0], bool)
                surv_mask[p_slots[keep]] = True
                surv_ranks, surv_slots = p_ranks[keep], p_slots[keep]
                pending = None

            if dispatched is not None:
                d_ranks, d_parents, d_slot_of, d_token = dispatched
                if surv_mask is not None:
                    kept = surv_mask[d_parents]
                    stages["host_pruned_parent"] += float((~kept).sum())
                    d_ranks, d_slot_of = d_ranks[kept], d_slot_of[kept]
                    if cfg.early_stop:
                        sub = self._apriori_kept(d_ranks, surv_ranks)
                        if sub is not None:
                            stages["host_pruned_subset"] += float((~sub).sum())
                            d_ranks, d_slot_of = d_ranks[sub], d_slot_of[sub]
                pending = (d_ranks, d_slot_of, d_token)
                ranks, parents, qarr = self._extensions(
                    d_ranks, d_slot_of, pair_packed, prefix_packed, K
                )
            elif surv_mask is not None and not cfg.pipeline_waves:
                ranks, parents, qarr = self._extensions(
                    surv_ranks, surv_slots, pair_packed, prefix_packed, K
                )
                if cfg.early_stop and len(ranks):
                    sub = self._apriori_kept(ranks, surv_ranks)
                    if sub is not None:
                        stages["host_pruned_subset"] += float((~sub).sum())
                        ranks, parents, qarr = ranks[sub], parents[sub], qarr[sub]
            else:
                ranks = np.empty((0, 2), np.int32)
                parents = np.empty(0, np.int64)
                qarr = np.empty(0, np.int32)

        stages["mining_waves"] = time.perf_counter() - t0
        return PrepostResult(itemsets, flist_items, len(itemsets), len(itemsets), peak)

    def mine(
        self,
        rows: np.ndarray,
        n_items: int,
        min_count: int,
        *,
        max_k: int | None | type(Ellipsis) = ...,
    ) -> PrepostResult:
        """One-shot mine = ``prepare`` at ``min_count`` + ``mine_prepared``.
        ``max_k=...`` inherits the config's cap; an explicit value overrides
        it per call (the bound jits are level-cap agnostic, so a warm miner
        serves any ``max_k``)."""
        max_k = self.cfg.max_k if max_k is ... else max_k
        prepared = self.prepare(
            rows, n_items, min_count, need_waves=max_k is None or max_k > 1
        )
        res = self.mine_prepared(prepared, min_count, max_k=max_k)
        # one-shot path pays its own prep: fold the real stage times back in
        self.last_stage_times.update(prepared.stage_times)
        return res
