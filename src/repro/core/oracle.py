"""Brute-force frequent-itemset oracle for tests (small DBs only)."""
from __future__ import annotations

from itertools import combinations

import numpy as np

from repro.core.encoding import PAD


def support_of(rows: np.ndarray, itemset, weights=None) -> int:
    """Exact support of one itemset by scanning every transaction."""
    w = np.ones(len(rows), np.int64) if weights is None else np.asarray(weights)
    mask = np.ones(len(rows), bool)
    for it in itemset:
        mask &= (rows == it).any(axis=1)
    return int(w[mask].sum())


def mine_bruteforce(rows: np.ndarray, n_items: int, min_count: int, max_k: int | None = None):
    """All frequent itemsets by Apriori-style BFS over explicit candidates."""
    present = [np.flatnonzero([support_of(rows, (i,)) >= min_count for i in range(n_items)])]
    f1 = [int(i) for i in present[0]]
    out: dict[tuple[int, ...], int] = {(i,): support_of(rows, (i,)) for i in f1}
    prev = [(i,) for i in f1]
    k = 2
    while prev and (max_k is None or k <= max_k):
        cur = []
        cand = set()
        for base in prev:
            for i in f1:
                if i > base[-1]:
                    cand.add(base + (i,))
        for c in sorted(cand):
            if any(tuple(s) not in out for s in combinations(c, len(c) - 1)):
                continue
            sup = support_of(rows, c)
            if sup >= min_count:
                out[c] = sup
                cur.append(c)
        prev = cur
        k += 1
    return out
