"""Derived pattern families over mined frequent itemsets.

The paper's lineage includes N-list miners for *closed* patterns (NAFCP,
ref [7]), subsume-enhanced mining (NSFI, ref [8]) and top-rank-k patterns
(NTK, ref [9]). Given the exact frequent-itemset dict our miners produce,
these families are clean post-passes — implemented here so the framework
exposes the same result surface as that literature:

  - closed:  no proper superset has the same support
  - maximal: no proper superset is frequent
  - top_rank_k: itemsets of the k highest distinct support values

All are property-tested against first-principles definitions.
"""
from __future__ import annotations

from collections import defaultdict


def closed_itemsets(itemsets: dict[tuple, int]) -> dict[tuple, int]:
    """Closed = no proper superset with equal support. O(n·k) via per-item
    inverted index rather than all-pairs."""
    by_item: dict[int, list[tuple]] = defaultdict(list)
    for s in itemsets:
        for i in s:
            by_item[i].append(s)
    out = {}
    for s, sup in itemsets.items():
        cands = by_item[s[0]] if s else list(itemsets)
        closed = True
        ss = set(s)
        for t in cands:
            if len(t) <= len(s) or itemsets[t] != sup:
                continue
            if ss.issubset(t):
                closed = False
                break
        if closed:
            out[s] = sup
    return out


def maximal_itemsets(itemsets: dict[tuple, int]) -> dict[tuple, int]:
    """Maximal = no proper frequent superset."""
    by_item: dict[int, list[tuple]] = defaultdict(list)
    for s in itemsets:
        for i in s:
            by_item[i].append(s)
    out = {}
    for s, sup in itemsets.items():
        cands = by_item[s[0]] if s else list(itemsets)
        ss = set(s)
        if not any(len(t) > len(s) and ss.issubset(t) for t in cands):
            out[s] = sup
    return out


def top_rank_k(itemsets: dict[tuple, int], k: int) -> dict[tuple, int]:
    """All itemsets whose support is among the k highest *distinct* support
    values (the NTK result surface)."""
    ranks = sorted({v for v in itemsets.values()}, reverse=True)[:k]
    keep = set(ranks)
    return {s: v for s, v in itemsets.items() if v in keep}
