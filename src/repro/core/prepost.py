"""Single-shard PrePost / PrePost+ miner (the paper's §3.3 baseline).

Set-enumeration DFS over F-list ranks. An itemset ``P = {p1 < ... < pk}``
(rank ascending) is extended with items ``q < p1``; its N-list lives on the
codes of its minimum-rank item (see nlist.py). Steps mirror the paper:
(1) support count -> F-list; (2) rank-encode + PPC-tree; (3) F2 from the
co-occurrence matrix (equals the paper's step-3 tree scan); (4) k>2 by
N-list intersection.

``cpe=True`` enables PrePost+'s Children-Parent-Equivalence pruning
(Deng & Lv 2015, paper ref [21]): if ``support(P ∪ {q}) == support(P)``,
every transaction holding ``P`` also holds ``q``, so ``q``'s whole branch
mirrors ``P``'s. We then (a) ban ``q`` from the subtree, (b) multiply the
subtree's itemset *multiplicity* by 2 — each explicit itemset ``S`` below
``P`` stands for ``S ∪ Q`` for every subset ``Q`` of the accumulated
equivalent items, all with ``support(S)``. ``total_count`` is exact
(property-tested equal to the cpe=False enumeration).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import encoding as enc
from repro.core import nlist as nl
from repro.core.ppc import build_ppc


@dataclasses.dataclass
class PrepostResult:
    """Low-level miner output (original item ids). The public surface is
    the enriched ``repro.mining.MineResult``; adapters build it from this."""

    itemsets: dict[tuple[int, ...], int]  # explicitly mined itemsets -> support
    flist_items: np.ndarray
    n_explicit: int
    total_count: int  # exact number of frequent itemsets (incl. CPE-implied)
    peak_bytes: int  # analytic peak of mining structures (paper's memory figs)

    def support_of(self, itemset) -> int:
        return self.itemsets[tuple(sorted(int(i) for i in itemset))]


def cooccurrence(rows: np.ndarray, weights: np.ndarray, k: int, block: int = 8192) -> np.ndarray:
    """Weighted pair co-occurrence ``C[i, j]`` (i < j) over rank-encoded rows.

    ``C = Xᵀ diag(w) X`` on the one-hot row matrix — the MXU-matmul form of
    the paper's F2 tree scan (kernels/cooccur implements the TPU tiling).
    """
    C = np.zeros((k, k), np.float64)
    for s in range(0, len(rows), block):
        chunk = rows[s : s + block]
        w = weights[s : s + block]
        X = np.zeros((len(chunk), k), np.float64)
        r, c = np.nonzero(chunk != enc.PAD)
        X[r, chunk[r, c]] = 1.0
        C += (X * w[:, None]).T @ X
    return np.triu(C, 1).astype(np.int64)


def mine_prepost(
    rows: np.ndarray,
    n_items: int,
    min_count: int,
    *,
    cpe: bool = False,
    max_k: int | None = None,
    max_itemsets: int = 2_000_000,
) -> PrepostResult:
    """Mine all frequent itemsets from a padded (R, L) transaction matrix."""
    supports = enc.item_support(rows, n_items)
    fl = enc.build_flist(supports, min_count)
    ranked = enc.rank_encode(rows, fl)
    urows, w = enc.dedup_rows(ranked)
    tree = build_ppc(urows, w)
    nlists = tree.nlists(fl.k)
    K = fl.k

    static_bytes = tree.n_nodes * 5 * 8 + sum(x.nbytes for x in nlists) + urows.nbytes
    peak = static_bytes
    itemsets: dict[tuple[int, ...], int] = {}
    total = 0

    def emit(ranks: tuple[int, ...], sup: int, m: int):
        nonlocal total
        ids = tuple(sorted(int(fl.items[r]) for r in ranks))
        itemsets[ids] = int(sup)
        total += m

    if K == 0:
        return PrepostResult(itemsets, fl.items, 0, 0, peak)

    C = cooccurrence(urows, w, K) if K > 1 and max_k != 1 else np.zeros((K, K), np.int64)
    peak += C.nbytes
    pair_ok = (C + C.T) >= min_count

    # DFS stack entries: (ranks, codes (n,3) on min-rank item, banned, mult, bytes_on_stack)
    stack: list[tuple[tuple[int, ...], np.ndarray, frozenset, int]] = []
    for p in range(K):
        emit((p,), int(fl.supports[p]), 1)
        if max_k != 1:
            stack.append(((p,), nlists[p], frozenset(), 1))

    stack_bytes = sum(c.nbytes for _, c, _, _ in stack)
    peak = max(peak, static_bytes + C.nbytes + stack_bytes)

    while stack and len(itemsets) < max_itemsets:
        ranks, codes, banned, mult = stack.pop()
        stack_bytes -= codes.nbytes
        base = ranks[0]
        if max_k is not None and len(ranks) >= max_k:
            continue
        psup = int(codes[:, 2].sum())
        eq: list[int] = []
        children: list[tuple[tuple[int, ...], np.ndarray]] = []
        for q in range(base - 1, -1, -1):
            if q in banned or not all(pair_ok[q, p] for p in ranks):
                continue
            counts = nl.intersect_np(
                nlists[q][:, 0], nlists[q][:, 1], codes[:, 0], codes[:, 1], codes[:, 2]
            )
            sup = int(counts.sum())
            if sup < min_count:
                continue
            if cpe and sup == psup:
                eq.append(q)
                emit((q,) + ranks, sup, 0)  # visibility only; counted via factor
                continue
            keep = counts > 0
            new_codes = np.column_stack([nlists[q][keep][:, :2], counts[keep]])
            children.append(((q,) + ranks, new_codes))
        factor = 1 << len(eq)
        if eq:
            total += mult * (factor - 1)  # implied copies of P itself
        child_banned = banned | frozenset(eq) if eq else banned
        child_mult = mult * factor
        for cranks, ccodes in children:
            emit(cranks, int(ccodes[:, 2].sum()), child_mult)
            stack.append((cranks, ccodes, child_banned, child_mult))
            stack_bytes += ccodes.nbytes
        peak = max(peak, static_bytes + C.nbytes + stack_bytes)

    return PrepostResult(itemsets, fl.items, len(itemsets), total, peak)
