"""The registered miners: every algorithm in the paper's comparison, one
front-door.

Host baselines (prepost, prepost+, fpgrowth, apriori, the brute-force
oracle) are thin adapters over ``repro.core``; ``hprepost`` wraps the
distributed ``HPrepostMiner`` and keeps one jit-warm instance per device
config so repeated mines through the same frontend (or a
``MiningEngine``) never rebuild the sharded programs.
"""
from __future__ import annotations

import functools
import threading
import time

import numpy as np

from repro.core import patterns as pat
from repro.mining.registry import register_miner
from repro.mining.result import MineResult
from repro.mining.spec import MineSpec


@functools.lru_cache(maxsize=1)
def default_mesh():
    """The 1×1 (data, model) mesh used when no mesh is bound explicitly."""
    from repro.compat import make_mesh

    return make_mesh((1, 1), ("data", "model"))


def _select_patterns(itemsets: dict, spec: MineSpec) -> dict:
    if spec.patterns == "closed":
        return pat.closed_itemsets(itemsets)
    if spec.patterns == "maximal":
        return pat.maximal_itemsets(itemsets)
    if spec.patterns == "top_rank_k":
        return pat.top_rank_k(itemsets, spec.rank_k)
    return itemsets


class _MinerBase:
    """Shared mine() driver: resolve threshold, time the backend, apply the
    pattern post-pass, assemble the enriched MineResult."""

    name = "?"
    exhaustive = True

    def __init__(self, mesh=None, data_axis=None, model_axis="model"):
        # Mesh kwargs are accepted uniformly so engines can construct any
        # registered miner the same way; host miners simply ignore them.
        del mesh, data_axis, model_axis

    def _run(self, rows, n_items, min_count, spec):
        """-> (itemsets, total_count, n_explicit, peak_bytes, stages, flist)."""
        raise NotImplementedError

    def _check_patterns(self, spec: MineSpec):
        if spec.patterns != "all" and not self.exhaustive:
            raise ValueError(
                f"patterns={spec.patterns!r} needs the full frequent collection; "
                f"miner {self.name!r} materializes an implicit (CPE-pruned) subset"
            )

    def _finish(
        self, itemsets, total, n_explicit, peak, stages, flist,
        *, spec, min_count, n_rows, t0, prep_shared=False,
    ) -> MineResult:
        """Assemble the enriched MineResult (pattern post-pass included) —
        shared by the one-shot ``mine`` and the engine's shared-prep path."""
        stages = dict(stages) if stages else {"mine": time.perf_counter() - t0}
        if spec.patterns != "all":
            tp = time.perf_counter()
            itemsets = _select_patterns(itemsets, spec)
            stages["patterns"] = time.perf_counter() - tp
        return MineResult(
            algorithm=self.name,
            itemsets=itemsets,
            total_count=total,
            n_explicit=n_explicit,
            min_count=min_count,
            n_rows=n_rows,
            peak_bytes=int(peak),
            wall_time_s=time.perf_counter() - t0,
            stage_times_s=dict(stages),
            flist_items=flist,
            prep_shared=prep_shared,
        )

    def mine(self, rows, n_items: int, spec: MineSpec) -> MineResult:
        rows = np.asarray(rows)
        min_count = spec.resolve(len(rows))
        self._check_patterns(spec)
        t0 = time.perf_counter()
        itemsets, total, n_explicit, peak, stages, flist = self._run(
            rows, n_items, min_count, spec
        )
        return self._finish(
            itemsets, total, n_explicit, peak, stages, flist,
            spec=spec, min_count=min_count, n_rows=len(rows), t0=t0,
        )


@register_miner("prepost")
class PrepostFrontend(_MinerBase):
    """Single-shard PrePost (the paper's §3.3 baseline)."""

    _cpe = False
    exhaustive = True

    def _run(self, rows, n_items, min_count, spec):
        from repro.core.prepost import mine_prepost

        res = mine_prepost(
            rows, n_items, min_count,
            cpe=self._cpe, max_k=spec.max_k, max_itemsets=spec.max_itemsets,
        )
        return (res.itemsets, res.total_count, res.n_explicit, res.peak_bytes,
                {}, res.flist_items)


@register_miner("prepost+")
class PrepostPlusFrontend(PrepostFrontend):
    """PrePost+ with Children-Parent-Equivalence pruning: exact
    ``total_count``, explicit ``itemsets`` are a pruned subset."""

    _cpe = True
    exhaustive = False


@register_miner("fpgrowth")
class FPGrowthFrontend(_MinerBase):
    """Pointer FP-tree FP-growth (the paper's main comparator)."""

    def _run(self, rows, n_items, min_count, spec):
        from repro.core.fpgrowth import mine_fpgrowth

        out, stats = mine_fpgrowth(
            rows, n_items, min_count, max_itemsets=spec.max_itemsets, max_k=spec.max_k
        )
        return out, len(out), len(out), stats["peak_bytes"], {}, None


@register_miner("apriori")
class AprioriFrontend(_MinerBase):
    """Vertical-bitmap Apriori (the related-work family)."""

    def _run(self, rows, n_items, min_count, spec):
        from repro.core.apriori import mine_apriori

        out, stats = mine_apriori(
            rows, n_items, min_count, max_itemsets=spec.max_itemsets, max_k=spec.max_k
        )
        return out, len(out), len(out), stats["peak_bytes"], {}, None


@register_miner("bruteforce")
class BruteForceFrontend(_MinerBase):
    """Transaction-scan oracle — small DBs only; anchors the parity tests."""

    def _run(self, rows, n_items, min_count, spec):
        from repro.core.oracle import mine_bruteforce

        out = mine_bruteforce(rows, n_items, min_count, max_k=spec.max_k)
        return out, len(out), len(out), rows.nbytes, {}, None


@register_miner("hprepost")
class HPrepostFrontend(_MinerBase):
    """The paper's contribution: distributed MapReduce miner on a mesh.

    One ``HPrepostMiner`` (and therefore one set of jitted sharded
    programs) is kept per device-level config; specs that differ only in
    threshold / ``max_k`` / patterns reuse it, so a resident frontend
    serves repeated traffic without recompiling.
    """

    exhaustive = True

    def __init__(self, mesh=None, data_axis=None, model_axis="model"):
        self.mesh = mesh if mesh is not None else default_mesh()
        if data_axis is None:
            data_axis = ("pod", "data") if "pod" in self.mesh.shape else "data"
        self.data_axis = data_axis
        self.model_axis = model_axis if model_axis in getattr(self.mesh, "axis_names", ()) else None
        self._miners: dict = {}
        # the service layer reaches miner_for from its prep thread while
        # the caller thread serves other requests: one lock, one miner
        # (and one set of jitted programs) per device config
        self._miners_lock = threading.Lock()
        self.miners_built = 0
        # the owning engine attaches its KernelTuner here; miners built by
        # this frontend resolve tuned plans through it (cfg.tune permitting)
        self.tuner = None

    def _device_config(self, spec: MineSpec):
        from repro.core.hprepost import HPrepostConfig

        # max_k deliberately left at its default: it is a per-call driver
        # knob (passed to mine()), not part of the compiled program.
        return HPrepostConfig(
            nlist_width=spec.nlist_width,
            candidate_unit=spec.candidate_unit,
            la_block=spec.la_block,
            ly_block=spec.ly_block,
            batch_block=spec.batch_block,
            partition_candidates=spec.partition_candidates,
            backend=spec.backend,
            max_f1=spec.max_f1,
            max_itemsets=spec.max_itemsets,
            early_stop=spec.early_stop,
            tune=spec.tune,
        )

    def _prep_config(self, spec: MineSpec):
        """The config subset ``prepare`` actually depends on — what prep
        caches and snapshots key on. Execution-only knobs (blocks, backend,
        early_stop, tune) are normalized away: a retune or backend switch
        must keep serving warm preps."""
        return self._device_config(spec).prep_key()

    def miner_for(self, spec: MineSpec):
        from repro.core.hprepost import HPrepostMiner

        cfg = self._device_config(spec)
        with self._miners_lock:
            miner = self._miners.get(cfg)
            if miner is None:
                miner = self._miners[cfg] = HPrepostMiner(
                    self.mesh, data_axis=self.data_axis, model_axis=self.model_axis, config=cfg
                )
                self.miners_built += 1
            miner.tuner = self.tuner
        return miner

    def _run(self, rows, n_items, min_count, spec):
        miner = self.miner_for(spec)
        res = miner.mine(rows, n_items, min_count, max_k=spec.max_k)
        return (res.itemsets, res.total_count, res.n_explicit, res.peak_bytes,
                dict(miner.last_stage_times), res.flist_items)

    # -------------------------------------------------- two-phase (planned)
    def prepare(self, rows, n_items: int, min_count_floor: int, spec: MineSpec,
                *, need_waves: bool = True):
        """Run the threshold-floor stages once -> ``(miner, PreparedDB)``.

        ``spec`` selects the device-level config (and so the resident
        miner); its own threshold is irrelevant here — every spec in the
        group whose threshold is at least ``min_count_floor`` can be served
        by ``mine_prepared`` from the returned PreparedDB."""
        miner = self.miner_for(spec)
        return miner, miner.prepare(
            np.asarray(rows), n_items, min_count_floor, need_waves=need_waves
        )

    def mine_prepared(self, miner, prepared, spec: MineSpec, *,
                      prep_stages=None, prep_shared: bool = False,
                      t0: float | None = None) -> MineResult:
        """Serve one spec from a shared ``PreparedDB`` (the k>2 waves only).

        ``prep_stages`` folds the real prep times into this result's
        ``stage_times_s`` — pass it on the one request that paid for prep;
        the others keep 0.0 prep keys and ``prep_shared=True``."""
        self._check_patterns(spec)
        min_count = spec.resolve(prepared.n_rows)
        if t0 is None:
            t0 = time.perf_counter()
        res = miner.mine_prepared(prepared, min_count, max_k=spec.max_k)
        stages = dict(miner.last_stage_times)
        if prep_stages:
            stages.update(prep_stages)
        return self._finish(
            res.itemsets, res.total_count, res.n_explicit, res.peak_bytes,
            stages, res.flist_items,
            spec=spec, min_count=min_count, n_rows=prepared.n_rows, t0=t0,
            prep_shared=prep_shared,
        )
