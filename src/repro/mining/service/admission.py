"""Admission control for the resident mining service: bounded queues,
typed overload/deadline errors, and deadline-aware load shedding.

PR 4's ``MiningService`` accepted unbounded load into a ``SimpleQueue`` —
the failure mode every real serving stack hits first: a traffic spike
buffers silently until memory (or every caller's patience) runs out. This
module is the backpressure layer in front of the worker loop:

  - ``AdmissionQueue``: a bounded FIFO with two independent budgets — a
    queue *depth* (requests waiting) and an *in-flight byte* budget
    (``rows`` bytes of every admitted-but-unresolved request, so a few
    huge databases can saturate the service as surely as many small
    ones). An offer that does not fit is REJECTED immediately — the
    caller's Future resolves with ``Overloaded`` now, instead of queueing
    into a timeout later.
  - Deadline-aware shedding: when the queue is full and the incoming
    request has a *later* deadline than some queued request, the queued
    request with the oldest (earliest) deadline is shed — it was the
    least likely to make its deadline anyway — and the newcomer is
    admitted. Requests without deadlines are never shed (treated as
    infinitely patient).
  - Typed errors: ``Overloaded`` / ``DeadlineExceeded`` / ``ServiceClosed``
    all subclass ``ServiceError``, so a caller can catch the service's
    own backpressure distinctly from a mining failure. The invariant the
    chaos harness enforces: every accepted Future resolves with a result
    or exactly one of these.

The queue stores the service's ``_Pending`` records; all it requires of
an item is ``nbytes`` and ``deadline_at`` attributes. Byte accounting is
*in-flight*, not just queued: ``offer`` charges, and the service's
``_finish`` (request resolved or failed) calls ``release`` — so the
budget also throttles work the batch window has already pulled off the
queue but not yet answered. Shed items are the one exception: ``offer``
reclaims their bytes itself, since they will never execute.
"""
from __future__ import annotations

import collections
import queue as _queue
import threading
import time


class ServiceError(RuntimeError):
    """Base of the service's own typed errors (vs. mining failures)."""


class Overloaded(ServiceError):
    """Admission refused: queue depth or in-flight byte budget exhausted.

    ``shed`` distinguishes a request rejected at the door (False) from an
    already-queued request evicted to admit later-deadline work (True).
    """

    def __init__(self, msg: str, *, shed: bool = False,
                 depth: int = 0, bytes_in_flight: int = 0):
        super().__init__(msg)
        self.shed = shed
        self.depth = depth
        self.bytes_in_flight = bytes_in_flight


class DeadlineExceeded(ServiceError):
    """The request's ``deadline_s`` passed before device work started."""


class ServiceClosed(ServiceError):
    """The service shut down (or its worker exited) before execution."""


def _eff(deadline_at: float | None) -> float:
    """Effective deadline for ordering: none = infinitely patient."""
    return float("inf") if deadline_at is None else deadline_at


class AdmissionQueue:
    """Bounded admission-controlled FIFO between ``submit`` and the worker.

    ``max_depth`` bounds queued (not yet batch-collected) requests;
    ``max_bytes`` bounds the *in-flight* byte total (queued + executing,
    until the owner calls ``release``). Either may be None (unbounded) —
    both None degrades to the old unbounded queue.
    """

    def __init__(self, *, max_depth: int | None = None,
                 max_bytes: int | None = None, registry=None):
        if max_depth is not None and max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        if max_bytes is not None and max_bytes < 1:
            raise ValueError(f"max_bytes must be >= 1, got {max_bytes}")
        self.max_depth = max_depth
        self.max_bytes = max_bytes
        self._items: collections.deque = collections.deque()
        self._cv = threading.Condition()
        self._bytes_in_flight = 0
        self.counters = {"admitted": 0, "rejected": 0, "shed": 0}
        # telemetry: the service passes its engine's shared registry; a
        # standalone queue (unit tests) gets a private one. Instrument
        # locks are leaves — safe to touch while holding ``_cv``.
        if registry is None:
            from repro.mining.telemetry import Registry

            registry = Registry()
        self.telemetry = registry
        self._depth_gauge = registry.gauge("admission.queue_depth")
        self._bytes_gauge = registry.gauge("admission.bytes_in_flight")
        self._wait_hist = registry.histogram("admission.queue_wait_s")

    def _update_gauges(self) -> None:
        # caller holds ``_cv``
        self._depth_gauge.set(sum(1 for it in self._items if it is not None))
        self._bytes_gauge.set(self._bytes_in_flight)

    # ------------------------------------------------------------- producer
    def offer(self, item) -> tuple[bool, list]:
        """Try to admit ``item``: ``(admitted, shed_items)``.

        May evict queued items (oldest effective deadline first) when that
        frees room AND every evicted deadline is strictly earlier than the
        incoming one. Shed items' bytes are reclaimed here (they will
        never execute); the caller owns resolving their Futures with
        ``Overloaded(shed=True)`` but must NOT ``release`` them again.
        """
        shed: list = []
        with self._cv:
            while self._over(item.nbytes):
                victim = self._sheddable(item)
                if victim is None:
                    self.counters["rejected"] += 1
                    return False, shed
                self._items.remove(victim)
                self._bytes_in_flight = max(0, self._bytes_in_flight - int(victim.nbytes))
                shed.append(victim)
                self.counters["shed"] += 1
            self._items.append(item)
            self._bytes_in_flight += int(item.nbytes)
            self.counters["admitted"] += 1
            self._update_gauges()
            self._cv.notify()
        return True, shed

    def _over(self, incoming_bytes: int) -> bool:
        # depth counts queued slots; bytes held by already-executing work
        # cannot be shed, so a byte-full service with an empty queue
        # rejects rather than evicts
        over_depth = self.max_depth is not None and len(self._items) + 1 > self.max_depth
        over_bytes = (
            self.max_bytes is not None
            and self._bytes_in_flight + int(incoming_bytes) > self.max_bytes
        )
        return over_depth or over_bytes

    def _sheddable(self, incoming):
        """The queued item to shed for ``incoming``, or None.

        Oldest-deadline-first: the queued item with the earliest effective
        deadline, and only if that deadline is strictly earlier than the
        incoming one — a full queue of no-deadline work rejects newcomers
        instead of churning."""
        victim = None
        for it in self._items:
            if victim is None or _eff(it.deadline_at) < _eff(victim.deadline_at):
                victim = it
        if victim is None or _eff(victim.deadline_at) >= _eff(incoming.deadline_at):
            return None
        return victim

    def put_sentinel(self) -> None:
        """Enqueue the worker-stop sentinel (bypasses admission)."""
        with self._cv:
            self._items.append(None)
            self._cv.notify()

    # ------------------------------------------------------------- consumer
    def get(self, timeout: float | None = None):
        """Pop the oldest entry (item or the None sentinel); raises
        ``queue.Empty`` on timeout — drop-in for the old SimpleQueue."""
        with self._cv:
            if not self._cv.wait_for(lambda: len(self._items) > 0, timeout):
                raise _queue.Empty
            item = self._items.popleft()
            self._update_gauges()
        if item is not None:
            submitted_at = getattr(item, "submitted_at", None)
            if submitted_at is not None:
                self._wait_hist.record(time.monotonic() - submitted_at)
        return item

    def release(self, nbytes: int) -> None:
        """Return ``nbytes`` to the in-flight budget (request resolved)."""
        with self._cv:
            self._bytes_in_flight = max(0, self._bytes_in_flight - int(nbytes))
            self._update_gauges()
            self._cv.notify_all()

    def drain_queued(self) -> list:
        """Remove and return every queued item (sentinels dropped) — the
        close-without-drain / worker-death path. The caller resolves their
        Futures and releases their bytes."""
        with self._cv:
            out = [it for it in self._items if it is not None]
            self._items.clear()
            self._update_gauges()
            return out

    # ------------------------------------------------------------ telemetry
    @property
    def depth(self) -> int:
        with self._cv:
            return sum(1 for it in self._items if it is not None)

    @property
    def bytes_in_flight(self) -> int:
        with self._cv:
            return self._bytes_in_flight

    def info(self) -> dict:
        with self._cv:
            return {
                **self.counters,
                "depth": sum(1 for it in self._items if it is not None),
                "bytes_in_flight": self._bytes_in_flight,
                "max_depth": self.max_depth,
                "max_bytes": self.max_bytes,
            }
