"""SnapshotStore: content-addressed on-disk PreparedDB snapshots.

The cross-process half of the engine's PreparedDB cache (ROADMAP
follow-up): a cold process pointed at a populated store warm-starts with
zero prep stages on a known database. Entries are keyed exactly like the
in-memory LRU — (algorithm, database fingerprint, n_items, device config)
plus the data-shard count the prep was laid out for — hashed to one
directory name, so any process that computes the same key finds the same
snapshot.

Layout per entry (written atomically, ``checkpoint/atomic`` style):

    <dir>/<key>/manifest.json   scalar meta + per-array file/dtype/shape/sha256
    <dir>/<key>/<name>.npy      one file per payload array

``get`` verifies every array against its manifest digest and shape; a
corrupted or partial entry (crash mid-write never produces one, but disk
rot or truncation can) is deleted and reported as a miss — the caller
re-prepares and the next ``put`` heals the store. GC is byte-budgeted,
evicting by mtime (``get`` touches entries, so eviction is LRU-ish).
"""
from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import os
import threading

import numpy as np

from repro.checkpoint.atomic import (
    dir_bytes, fsync_write, is_tmp, prune_oldest, reap_stale_tmp, save_array, write_dir_atomic,
)
from repro.fault import failures

MANIFEST = "manifest.json"
STORE_SCHEMA = 1


def _canonical(obj) -> str:
    """Deterministic JSON for key hashing (tuples/dataclasses normalized)."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        obj = dataclasses.asdict(obj)
    return json.dumps(obj, sort_keys=True, default=lambda o: list(o) if isinstance(o, (tuple, set)) else str(o))


class SnapshotStore:
    """Byte-budgeted, content-addressed PreparedDB snapshot directory.

    Thread-safe: the service's prep thread and worker pool may hit one
    store concurrently. All counters are under ``info()``.
    """

    def __init__(self, directory: str, *, byte_budget: int = 4 << 30):
        self.dir = directory
        self.byte_budget = int(byte_budget)
        os.makedirs(directory, exist_ok=True)
        self._lock = threading.Lock()
        self.stats = {
            "hits": 0, "misses": 0, "stores": 0,
            # puts skipped because the resident entry already serves at
            # least as loose a floor (content-addressed: nothing to gain)
            "store_skips": 0,
            "corrupt": 0,  # entries rejected (and deleted) by validation
            "evictions": 0,  # entries removed by the byte-budget GC
        }

    # ------------------------------------------------------------------ keys
    @staticmethod
    def key_for(algorithm: str, fingerprint, n_items: int, device_config, n_shards: int) -> str:
        """Stable hex key: same database + device config + shard count in
        any process maps to the same entry."""
        blob = _canonical(
            {
                "algorithm": algorithm,
                "fingerprint": fingerprint,
                "n_items": int(n_items),
                "device_config": device_config,
                "n_shards": int(n_shards),
            }
        )
        return hashlib.sha256(blob.encode()).hexdigest()

    def path_of(self, key: str) -> str:
        return os.path.join(self.dir, key)

    # ------------------------------------------------------------------- api
    def entries(self) -> list[str]:
        """Entry directories, oldest-mtime first (the GC eviction order)."""
        out = []
        for name in os.listdir(self.dir):
            path = os.path.join(self.dir, name)
            if is_tmp(name) or not os.path.isdir(path):
                continue
            try:
                out.append((os.path.getmtime(path), path))
            except OSError:
                pass
        return [p for _, p in sorted(out)]

    def bytes_in_use(self) -> int:
        return sum(dir_bytes(p) for p in self.entries())

    def info(self) -> dict:
        return {
            **self.stats,
            "entries": len(self.entries()),
            "bytes_in_use": self.bytes_in_use(),
            "byte_budget": self.byte_budget,
        }

    def get(self, key: str) -> dict | None:
        """The validated payload for ``key``, or None (miss / corrupt).

        Every array is re-hashed against the manifest digest before it is
        trusted; a *content* mismatch deletes the entry so a re-prepare +
        re-put replaces it instead of tripping on it forever. Transient
        I/O failures (fd exhaustion, another process's GC racing the
        read) are plain misses — they prove nothing about the bytes on
        disk, so the entry survives to be read again.

        The store lock is held across the whole read (and ``put`` holds
        it across the whole write): within one process, a reader can
        never interleave with a same-key replacement and observe arrays
        from two different snapshot generations that each pass their own
        digest. Snapshot payloads are small next to the mining itself —
        consistency is worth the serialization. Across processes the lock
        cannot help, so a content failure is re-read once before the
        entry is condemned: a reader racing another process's atomic
        replace sees a mixed/missing generation on the first read and the
        complete new entry on the second."""
        failures.fire("snapshot.read")  # chaos: corruption / I/O mid-read
        with self._lock:
            path = self.path_of(key)
            for attempt in (0, 1):
                if not os.path.isdir(path):
                    self.stats["misses"] += 1  # absent (or a racing GC won)
                    return None
                try:
                    payload = self._read_validated(path)
                except OSError as e:
                    if isinstance(e, FileNotFoundError):
                        if attempt == 0:
                            continue  # mid-replace by another process: re-read
                        self._reject(path)  # member still missing: partial
                    else:
                        self.stats["misses"] += 1  # transient I/O: keep it
                    return None
                except Exception:
                    if attempt == 0:
                        continue  # possibly a mid-replace read: re-read
                    self._reject(path)  # it really is broken on disk
                    return None
                try:
                    os.utime(path)  # recency for the byte-budget GC
                except OSError:
                    pass  # e.g. a cross-process GC won; the payload is valid
                self.stats["hits"] += 1
                return payload

    def _read_validated(self, path: str) -> dict:
        """One full read of an entry, digests and shapes checked; raises on
        any inconsistency (``ValueError``) or I/O failure (``OSError``)."""
        with open(os.path.join(path, MANIFEST)) as f:
            manifest = json.load(f)
        if manifest.get("schema") != STORE_SCHEMA:
            raise ValueError(f"store schema {manifest.get('schema')!r}")
        payload = dict(manifest["meta"])
        for name, spec in manifest["arrays"].items():
            with open(os.path.join(path, spec["file"]), "rb") as f:
                raw = f.read()
            if hashlib.sha256(raw).hexdigest() != spec["sha256"]:
                raise ValueError(f"digest mismatch for array {name!r}")
            arr = np.load(io.BytesIO(raw))
            if list(arr.shape) != spec["shape"] or str(arr.dtype) != spec["dtype"]:
                raise ValueError(f"shape/dtype mismatch for array {name!r}")
            payload[name] = arr
        return payload

    def _reject(self, path: str) -> None:
        import shutil

        shutil.rmtree(path, ignore_errors=True)
        self.stats["corrupt"] += 1
        self.stats["misses"] += 1

    def peek_meta(self, key: str) -> dict | None:
        """Scalar meta of an entry without loading arrays (put's policy
        check); None when absent or unreadable."""
        try:
            with open(os.path.join(self.path_of(key), MANIFEST)) as f:
                manifest = json.load(f)
            if manifest.get("schema") != STORE_SCHEMA:
                return None
            return dict(manifest["meta"])
        except Exception:
            return None

    @staticmethod
    def _improves(new_meta: dict, old_meta: dict) -> bool:
        """Whether a payload is worth replacing the resident entry: wave
        state (full prep) beats F1-only, then a looser floor beats a
        tighter one — mirroring the engine LRU's replacement policy."""
        if bool(new_meta.get("f1_only")) != bool(old_meta.get("f1_only")):
            return bool(old_meta.get("f1_only"))
        return int(new_meta.get("min_count_floor", 0)) < int(old_meta.get("min_count_floor", 0))

    def put(self, key: str, payload: dict) -> str | None:
        """Persist a ``PreparedDB.to_host()`` payload under ``key``.

        Atomic (tmp + fsync + rename); skipped when the resident entry is
        already at least as useful. Returns the entry path, or None when
        the write was skipped."""
        arrays = {k: v for k, v in payload.items() if isinstance(v, np.ndarray)}
        meta = {k: v for k, v in payload.items() if not isinstance(v, np.ndarray)}
        with self._lock:
            old = self.peek_meta(key)
            if old is not None and not self._improves(meta, old):
                self.stats["store_skips"] += 1
                return None
            path = self.path_of(key)

            def writer(tmp):
                manifest = {"schema": STORE_SCHEMA, "meta": meta, "arrays": {}}
                for name, arr in arrays.items():
                    fname = f"{name}.npy"
                    save_array(os.path.join(tmp, fname), arr)
                    with open(os.path.join(tmp, fname), "rb") as f:
                        digest = hashlib.sha256(f.read()).hexdigest()
                    manifest["arrays"][name] = {
                        "file": fname,
                        "dtype": str(arr.dtype),
                        "shape": list(arr.shape),
                        "sha256": digest,
                    }
                fsync_write(os.path.join(tmp, MANIFEST), json.dumps(manifest, sort_keys=True).encode())

            write_dir_atomic(path, writer)
            self.stats["stores"] += 1
            self._gc_locked()
        return path

    def gc(self) -> int:
        """Evict oldest entries until the byte budget holds; returns the
        number evicted."""
        with self._lock:
            return self._gc_locked()

    def _gc_locked(self) -> int:
        # the full-store walk (mtimes + per-entry sizes) is the only byte
        # accounting that stays correct when other processes also write
        # this directory; it runs once per spill, which is once per new
        # PreparedDB build — rare next to the mining it amortizes over
        reap_stale_tmp(self.dir)  # crashed writers' residue
        removed = prune_oldest(self.entries(), byte_budget=self.byte_budget)
        self.stats["evictions"] += len(removed)
        return len(removed)
