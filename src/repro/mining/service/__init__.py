"""repro.mining.service — the resident mining service layer.

Four modules on top of ``MiningEngine`` (the ROADMAP's serving
follow-ups, done):

  ``store``      cross-process persistence: a content-addressed on-disk
                 snapshot store of serialized PreparedDBs, so a cold
                 process warm-starts with zero prep stages
  ``admission``  backpressure: the bounded admission queue (depth +
                 in-flight byte budgets, oldest-deadline-first shedding)
                 and the typed service errors ``Overloaded`` /
                 ``DeadlineExceeded`` / ``ServiceClosed``
  ``scheduler``  async execution across *groups*: group g+1's prepare is
                 dispatched while group g's wave loop drains; host
                 algorithms run on worker threads alongside device
                 groups; priority ordering + deadline drops
  ``service``    the ``MiningService`` facade: ``submit() -> Future``, a
                 batching window that coalesces concurrent requests into
                 planned groups, crash-proof worker loop, graceful
                 drain-or-fail close, per-request telemetry

``MiningService``/``GroupScheduler`` are imported lazily: the engine
itself constructs a ``SnapshotStore`` (warm-start hooks), and an eager
import here would cycle back through ``repro.mining.engine``.
"""
from repro.mining.service.admission import (
    AdmissionQueue, DeadlineExceeded, Overloaded, ServiceClosed, ServiceError,
)
from repro.mining.service.store import SnapshotStore

__all__ = [
    "AdmissionQueue", "DeadlineExceeded", "GroupScheduler", "MiningService",
    "Overloaded", "ServiceClosed", "ServiceError", "SnapshotStore",
]


def __getattr__(name: str):
    if name == "MiningService":
        from repro.mining.service.service import MiningService

        return MiningService
    if name == "GroupScheduler":
        from repro.mining.service.scheduler import GroupScheduler

        return GroupScheduler
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
