"""repro.mining.service — the resident mining service layer.

Three modules on top of ``MiningEngine`` (the ROADMAP's serving
follow-ups, done):

  ``store``      cross-process persistence: a content-addressed on-disk
                 snapshot store of serialized PreparedDBs, so a cold
                 process warm-starts with zero prep stages
  ``scheduler``  async execution across *groups*: group g+1's prepare is
                 dispatched while group g's wave loop drains; host
                 algorithms run on worker threads alongside device groups
  ``service``    the ``MiningService`` facade: ``submit() -> Future``, a
                 batching window that coalesces concurrent requests into
                 planned groups, graceful drain, per-request telemetry

``MiningService``/``GroupScheduler`` are imported lazily: the engine
itself constructs a ``SnapshotStore`` (warm-start hooks), and an eager
import here would cycle back through ``repro.mining.engine``.
"""
from repro.mining.service.store import SnapshotStore

__all__ = ["GroupScheduler", "MiningService", "SnapshotStore"]


def __getattr__(name: str):
    if name == "MiningService":
        from repro.mining.service.service import MiningService

        return MiningService
    if name == "GroupScheduler":
        from repro.mining.service.scheduler import GroupScheduler

        return GroupScheduler
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
