"""GroupScheduler: async/overlapped execution across planned groups.

PR 2 software-pipelined the wave loop *within* one group (wave l+1
dispatched before wave l's supports land). This lifts the same idea one
level up, to the ROADMAP's "async/overlapped submit_many across groups"
follow-up:

  - hprepost requests are grouped exactly like ``MiningEngine.
    submit_many`` (database fingerprint + device config), but group g+1's
    *prepare* — the host shuffle plus device Jobs 1/2/pack/F2 — is
    dispatched on a dedicated prep thread while group g's k>2 wave loop is
    still draining on the caller thread. One prep thread keeps device
    pressure bounded and preserves group order; JAX dispatch is
    thread-safe, so the prep jobs interleave with the wave kernels instead
    of waiting behind them.
  - host-algorithm requests (apriori / fpgrowth / prepost / ...) carry no
    device state at all; they run on a small worker pool fully concurrent
    with the device groups.

Unlike ``submit_many``, singleton hprepost groups stay *groups* here: two
back-to-back requests on two distinct databases are precisely the case
where overlapping prepare(g+1) with mine(g) pays.

QoS (PR 8): within one batch, device groups are served highest
``spec.priority`` first (max over the group's members; FIFO between
equals), and any request whose ``deadline_at`` has already passed is
dropped with a typed ``DeadlineExceeded`` *before* its device work —
checked at classification and again right before its group serves, so a
deadline that expires while earlier groups drain still saves the work.

Results preserve request order. With ``return_exceptions=True`` a failed
request yields its exception object in the result slot (the service maps
those onto per-request futures); otherwise the first failure raises.
"""
from __future__ import annotations

import threading
import time

from concurrent.futures import ThreadPoolExecutor

from repro.mining.engine import MineRequest, MiningEngine
from repro.mining.service.admission import DeadlineExceeded
from repro.mining.telemetry import trace


class GroupScheduler:
    """Overlapped batch executor over one (thread-safe) ``MiningEngine``.

    ``overlap=False`` degrades to strictly sequential group execution —
    the baseline the service bench compares against.
    """

    def __init__(self, engine: MiningEngine, *, host_workers: int = 4, overlap: bool = True):
        self.engine = engine
        self.telemetry = engine.telemetry  # shared latency registry
        self.overlap = overlap
        self._host_pool = ThreadPoolExecutor(
            max_workers=max(1, host_workers), thread_name_prefix="mine-host"
        )
        self._prep_pool = ThreadPoolExecutor(max_workers=1, thread_name_prefix="mine-prep")
        self._stats_lock = threading.Lock()  # counters touched off-thread
        self.stats = {
            "batches": 0,
            "device_groups": 0,
            "host_requests": 0,
            # prepares that ran while an earlier group was still mining
            "overlapped_prepares": 0,
            "degraded_groups": 0,  # group floor tripped a guard -> per-request
            # requests resolved with DeadlineExceeded before device work
            "deadline_dropped": 0,
            # batches whose group order differed from FIFO due to priority
            "priority_reordered": 0,
        }

    def close(self) -> None:
        self._prep_pool.shutdown(wait=True)
        self._host_pool.shutdown(wait=True)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------------ run
    def run(self, requests, *, return_exceptions: bool = False) -> list:
        """Serve a batch; results align with the input order.

        Device groups run in submission order on the calling thread with
        their prepares pipelined one group ahead; host requests resolve on
        the worker pool whenever they finish."""
        requests: list[MineRequest] = list(requests)
        results: list = [None] * len(requests)
        groups: list[tuple[tuple, list[int]]] = []
        by_key: dict[tuple, int] = {}
        host_futures: list[tuple[int, object]] = []
        self.stats["batches"] += 1

        trace_root = next(
            (r.trace_id for r in requests if r.trace_id is not None), None
        )
        with trace.span("group.classify", parent=trace_root, n=len(requests)):
            for i, r in enumerate(requests):
                if self._expired(r):  # dead on arrival: no classification work
                    results[i] = self._drop(r)
                    continue
                key = self.engine._plan_key(r)
                if key is None:
                    self.stats["host_requests"] += 1
                    host_futures.append((i, self._submit_host(r)))
                elif key in by_key:
                    groups[by_key[key]][1].append(i)
                else:
                    by_key[key] = len(groups)
                    groups.append((key, [i]))
        self.stats["device_groups"] += len(groups)

        # highest-priority group first (max over members; stable, so equal
        # priorities keep FIFO order and the default priority=0 batch is
        # byte-identical to the pre-QoS scheduler)
        order = sorted(
            range(len(groups)),
            key=lambda g: -max(requests[i].spec.priority for i in groups[g][1]),
        )
        if order != sorted(order):
            self.stats["priority_reordered"] += 1
        groups = [groups[g] for g in order]

        # pipeline, one group ahead: group g+1's acquire is handed to the
        # prep thread right before group g's waves start draining here, so
        # exactly one prepare overlaps the mining — never the whole batch.
        # (Queueing every acquire up-front would let the prep thread run N
        # groups ahead and pin N PreparedDBs on device at once; one-ahead
        # gets the same wall-clock overlap with bounded residency.)
        group_reqs = [[requests[i] for i in idxs] for _, idxs in groups]
        ahead = None
        if self.overlap and groups:
            ahead = self._submit_prep(group_reqs[0], groups[0][0])
        for gi, (key, idxs) in enumerate(groups):
            reqs = group_reqs[gi]
            acq_fut, ahead = ahead, None
            if self.overlap and gi + 1 < len(groups):
                ahead = self._submit_prep(group_reqs[gi + 1], groups[gi + 1][0])
            group_root = next(
                (r.trace_id for r in reqs if r.trace_id is not None), None
            )
            t_acq = time.perf_counter()
            try:
                with trace.span("group.prep", parent=group_root,
                                overlapped=acq_fut is not None and gi > 0):
                    acq = acq_fut.result() if acq_fut is not None \
                        else self.engine._group_acquire(reqs, key)
                # wait observed by the serving thread: ~0 when the prep
                # pipelined ahead (the actual build cost is engine.prep_s)
                self.telemetry.histogram("scheduler.prep_wait_s").record(
                    time.perf_counter() - t_acq
                )
            except ValueError:
                # group-floor guard trip: degrade to per-request one-shots,
                # so a real per-request error surfaces on its own request
                self.stats["degraded_groups"] += 1
                for i, res in zip(idxs, [self._one(r) for r in reqs]):
                    results[i] = res
                continue
            except Exception as e:
                # any other acquire failure belongs to THIS group's slots,
                # not to the batch: other groups and host requests proceed
                for i in idxs:
                    results[i] = e
                continue
            # deadline recheck at serve time: members whose deadline passed
            # while earlier groups drained are dropped without device work
            live: list[tuple[int, MineRequest]] = []
            for i, r in zip(idxs, reqs):
                if self._expired(r):
                    results[i] = self._drop(r)
                else:
                    live.append((i, r))
            if not live:
                continue
            overlapped = self.overlap and acq[2] == "built" and gi > 0
            if overlapped:
                self.stats["overlapped_prepares"] += 1
            live_reqs = [r for _, r in live]
            t_serve = time.perf_counter()
            try:
                with trace.span("group.serve", parent=group_root,
                                n=len(live_reqs), source=acq[2]):
                    group_out = self.engine._group_serve(live_reqs, acq)
                for res in group_out:
                    res.service_stats["prep_overlapped"] = overlapped
            except Exception as e:  # serve failure: pin it to every member
                group_out = [e] * len(live_reqs)
            self.telemetry.histogram("scheduler.serve_s").record(
                time.perf_counter() - t_serve
            )
            for (i, _), res in zip(live, group_out):
                results[i] = res

        for i, fut in host_futures:
            results[i] = fut.result()  # _one never raises; errors are values

        if not return_exceptions:
            for res in results:
                if isinstance(res, BaseException):
                    raise res
        return results

    # --------------------------------------------------------------- helpers
    @staticmethod
    def _expired(r: MineRequest) -> bool:
        return r.deadline_at is not None and time.monotonic() > r.deadline_at

    def _drop(self, r: MineRequest) -> DeadlineExceeded:
        with self._stats_lock:
            self.stats["deadline_dropped"] += 1
        return DeadlineExceeded(
            f"deadline_s={r.spec.deadline_s} passed before mining started"
        )

    class _Done:
        """Pre-resolved stand-in for a pool future (pool already shut down)."""

        def __init__(self, value):
            self._value = value

        def result(self):
            return self._value

    def _submit_host(self, r: MineRequest):
        """Submit ``_one`` to the host pool; a dead/shut-down pool degrades
        to inline execution instead of killing the batch."""
        try:
            return self._host_pool.submit(self._one, r)
        except RuntimeError:
            return self._Done(self._one(r))

    def _submit_prep(self, reqs, key):
        """Submit a group acquire to the prep thread; None when the pool is
        dead (the caller then acquires inline — slower, never wrong)."""
        try:
            return self._prep_pool.submit(self.engine._group_acquire, reqs, key)
        except RuntimeError:
            return None

    def _one(self, r: MineRequest):
        """One-shot submit with the error held as a value (so a failing
        request costs its own slot, never the batch)."""
        if self._expired(r):  # checked at execution, not submission: a host
            return self._drop(r)  # request can expire waiting for a pool slot
        t0 = time.perf_counter()
        try:
            with trace.span("host.mine", parent=r.trace_id,
                            algorithm=r.spec.algorithm):
                return self.engine.submit(r.rows, r.n_items, r.spec)
        except Exception as e:
            return e
        finally:
            self.telemetry.histogram("scheduler.host_s").record(
                time.perf_counter() - t0
            )
