"""MiningService: the resident serving facade over engine + scheduler.

The paper's HPrepost amortizes MapReduce job setup across many queries on
one long-lived cluster; this is that posture as a process-local service.
One worker thread owns execution: ``submit`` enqueues a request and
returns a ``concurrent.futures.Future`` immediately, the worker coalesces
every request that arrives within a small batching window into one batch,
and the batch is planned into shared-prep groups and executed with
cross-group overlap by the ``GroupScheduler``. With a ``snapshot_dir``
bound, the engine underneath warm-starts from (and spills to) the
persistent PreparedDB store, so a freshly started service serves a known
database with zero prep stages.

Telemetry rides each ``MineResult.service_stats``: queue time, batch
size, where the prep came from (built / LRU cache / snapshot) and whether
it overlapped an earlier group's mining. ``drain()`` blocks until every
accepted request has resolved; ``close()`` drains and stops the worker
(also available as a context manager).

Streaming traffic (``repro.mining.stream``) rides the same queue:
``append`` and ``submit_stream`` return Futures and execute in arrival
order relative to everything in their batch, so a query submitted after
an append is guaranteed to see the new segment.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import Sequence

import numpy as np

from repro.mining.engine import MineRequest, MiningEngine
from repro.mining.result import MineResult
from repro.mining.service.scheduler import GroupScheduler
from repro.mining.spec import MineSpec


@dataclasses.dataclass
class _Pending:
    req: MineRequest | None  # None for stream operations
    future: Future
    submitted_at: float
    kind: str = "mine"  # "mine" | "stream" (append / stream query)
    run: object = None  # stream ops: zero-arg callable executed in order


class MiningService:
    """Async front-door: ``submit() -> Future[MineResult]``.

    ``batch_window_s`` is the coalescing window: once a request arrives,
    the worker keeps collecting for that long so concurrent callers land
    in one planned batch (sweep requests on one database become one
    shared-prep group; distinct databases become pipelined groups). 0
    serves strictly one request per batch.
    """

    def __init__(self, engine: MiningEngine | None = None, *, mesh=None,
                 snapshot_dir: str | None = None, batch_window_s: float = 0.02,
                 host_workers: int = 4, **engine_kwargs):
        if engine is not None and (mesh is not None or snapshot_dir is not None or engine_kwargs):
            raise ValueError("pass an engine or engine-construction kwargs, not both")
        self.engine = engine if engine is not None else MiningEngine(
            mesh, snapshot_dir=snapshot_dir, **engine_kwargs
        )
        self.scheduler = GroupScheduler(self.engine, host_workers=host_workers)
        self.batch_window_s = float(batch_window_s)
        self.stats = {"requests": 0, "batches": 0, "max_batch": 0}
        self._q: queue.SimpleQueue = queue.SimpleQueue()
        self._cv = threading.Condition()
        self._outstanding = 0
        self._closed = False
        self._worker = threading.Thread(
            target=self._loop, name="mining-service", daemon=True
        )
        self._worker.start()

    # ------------------------------------------------------------ submission
    def submit(self, rows, n_items: int, spec: MineSpec) -> Future:
        """Enqueue one request; the Future resolves to its ``MineResult``
        (or raises what the request raised)."""
        fut: Future = Future()
        with self._cv:
            # the closed check and the accounting are one atomic step:
            # close() flips the flag under the same lock, so a request is
            # either rejected here or counted before close()'s drain runs
            if self._closed:
                raise RuntimeError("MiningService is closed")
            self._outstanding += 1
            self.stats["requests"] += 1
        self._q.put(_Pending(MineRequest(rows, n_items, spec), fut, time.monotonic()))
        return fut

    def submit_many(self, requests: Sequence[MineRequest]) -> list[Future]:
        return [self.submit(r.rows, r.n_items, r.spec) for r in requests]

    def _submit_stream_op(self, run) -> Future:
        fut: Future = Future()
        with self._cv:
            if self._closed:
                raise RuntimeError("MiningService is closed")
            self._outstanding += 1
            self.stats["requests"] += 1
        self._q.put(_Pending(None, fut, time.monotonic(), kind="stream", run=run))
        return fut

    def append(self, rows, n_items: int | None = None, *, stream: str = "default",
               spec: MineSpec | None = None, stream_spec=None) -> Future:
        """Enqueue a streaming ingest (``engine.append``); the Future
        resolves to the append telemetry dict. Stream operations execute
        in arrival order relative to each other and to mining requests in
        the same batch, so a query submitted after an append observes it.

        The batch is copied HERE, at submit time — execution happens after
        the batching window, and a caller reusing its array for the next
        batch must not retroactively change what this one ingests."""
        rows = np.array(rows, np.int32, copy=True)
        return self._submit_stream_op(
            lambda: self.engine.append(
                rows, n_items, stream=stream, spec=spec, stream_spec=stream_spec
            )
        )

    def submit_stream(self, spec: MineSpec, *, stream: str = "default") -> Future:
        """Enqueue a query against the named stream's live ``SegmentedDB``;
        the Future resolves to its ``MineResult``."""
        return self._submit_stream_op(
            lambda: self.engine.submit_stream(spec, stream=stream)
        )

    def distribute(self, name: str = "default", **kw):
        """Create/fetch a distributed database (``engine.distribute``) —
        synchronous, since it spawns worker processes, not a mining op.
        Once created, ``append`` / ``submit_stream`` on its name serve it
        through the ordinary Future path, worker failover included."""
        return self.engine.distribute(name, **kw)

    def sweep(self, rows, n_items: int, spec: MineSpec,
              min_sups: Sequence[float]) -> list[Future]:
        """The paper's threshold sweep, submitted concurrently — the batch
        window coalesces it into one shared-prep group."""
        return [self.submit(rows, n_items, spec.with_(min_sup=s)) for s in min_sups]

    # ------------------------------------------------------------- lifecycle
    def drain(self) -> None:
        """Block until every accepted request has resolved."""
        with self._cv:
            self._cv.wait_for(lambda: self._outstanding == 0)

    def close(self) -> None:
        """Graceful shutdown: stop accepting, drain, stop the worker."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
        self.drain()
        self._q.put(None)  # wake + stop the worker
        self._worker.join()
        self.scheduler.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ---------------------------------------------------------- worker loop
    def _loop(self) -> None:
        while True:
            try:
                first = self._q.get(timeout=0.1)
            except queue.Empty:
                continue
            if first is None:
                return
            batch = [first]
            deadline = time.monotonic() + self.batch_window_s
            stop = False
            while True:
                timeout = deadline - time.monotonic()
                if timeout <= 0:
                    break
                try:
                    item = self._q.get(timeout=timeout)
                except queue.Empty:
                    break
                if item is None:
                    stop = True
                    break
                batch.append(item)
            self._serve(batch)
            if stop:
                return

    def _serve(self, batch: list[_Pending]) -> None:
        t_start = time.monotonic()
        # transition every future to RUNNING; one the caller already
        # cancelled is dropped here (set_result on it would raise
        # InvalidStateError and kill the worker), and RUNNING futures can
        # no longer be cancelled out from under the batch
        live = []
        for p in batch:
            if p.future.set_running_or_notify_cancel():
                live.append(p)
            else:
                with self._cv:
                    self._outstanding -= 1
                    self._cv.notify_all()
        batch = live
        if not batch:
            return
        self.stats["batches"] += 1
        self.stats["max_batch"] = max(self.stats["max_batch"], len(batch))
        # execute in arrival order: contiguous runs of mining requests go
        # through the scheduler as one planned sub-batch, stream operations
        # (appends / stream queries) run inline between them — a query that
        # arrived after an append must observe the appended segment
        results: list = [None] * len(batch)
        chunk: list[int] = []

        def flush_chunk():
            if not chunk:
                return
            try:
                out = self.scheduler.run(
                    [batch[j].req for j in chunk], return_exceptions=True
                )
            except BaseException as e:  # scheduler must not fail a batch silently
                out = [e] * len(chunk)
            for j, r in zip(chunk, out):
                results[j] = r
            chunk.clear()

        for i, p in enumerate(batch):
            if p.kind == "mine":
                chunk.append(i)
                continue
            flush_chunk()
            try:
                results[i] = p.run()
            except BaseException as e:
                results[i] = e
        flush_chunk()
        for p, res in zip(batch, results):
            if isinstance(res, BaseException):
                p.future.set_exception(res)
            else:
                if isinstance(res, MineResult):
                    res.service_stats.update(
                        queue_time_s=t_start - p.submitted_at, batch_size=len(batch)
                    )
                p.future.set_result(res)
            with self._cv:
                self._outstanding -= 1
                self._cv.notify_all()
