"""MiningService: the resident serving facade over engine + scheduler.

The paper's HPrepost amortizes MapReduce job setup across many queries on
one long-lived cluster; this is that posture as a process-local service.
One worker thread owns execution: ``submit`` enqueues a request and
returns a ``concurrent.futures.Future`` immediately, the worker coalesces
every request that arrives within a small batching window into one batch,
and the batch is planned into shared-prep groups and executed with
cross-group overlap by the ``GroupScheduler``. With a ``snapshot_dir``
bound, the engine underneath warm-starts from (and spills to) the
persistent PreparedDB store, so a freshly started service serves a known
database with zero prep stages.

Hardening (PR 8) — the invariant is *every accepted Future resolves*,
with a result or a typed error, whatever fails:

  - Admission control: ``max_queue_depth`` / ``max_queue_bytes`` bound the
    queue (``repro.mining.service.admission``). A request that does not
    fit resolves immediately with ``Overloaded`` — backpressure, not
    silent buffering — and when the incoming deadline is tighter than a
    queued one, the oldest-deadline request is shed instead.
  - QoS: ``spec.priority`` orders device groups, ``spec.deadline_s``
    drops late requests with ``DeadlineExceeded`` before device work
    (both enforced by the scheduler; stream queries check their deadline
    right before executing).
  - Crash-proof worker: any batch-serving failure (prep-thread death,
    executor shutdown, chaos injection) resolves every Future the batch
    owns with that error and the loop continues (``worker_restarts``
    counts them). If the loop itself ever exits, still-queued requests
    are failed with ``ServiceClosed`` — no orphaned Futures, ever.

Telemetry rides each ``MineResult.service_stats``: queue time, batch
size, where the prep came from (built / LRU cache / snapshot) and whether
it overlapped an earlier group's mining. ``stats`` stays the historical
counter dict *and* is callable: ``service.stats()`` returns the full
operator snapshot (admission/shed/deadline/retry/respawn counters,
scheduler + engine + per-stream distributed stats). ``drain()`` blocks
until every accepted request has resolved; ``close()`` drains — or, with
``drain=False``, fails queued requests with ``ServiceClosed`` — and stops
the worker (also available as a context manager).

Streaming traffic (``repro.mining.stream``) rides the same queue:
``append`` and ``submit_stream`` return Futures and execute in arrival
order relative to everything in their batch, so a query submitted after
an append is guaranteed to see the new segment.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future, InvalidStateError
from typing import Sequence

import numpy as np

from repro.fault import failures
from repro.mining.engine import MineRequest, MiningEngine
from repro.mining.result import MineResult
from repro.mining.service.admission import (
    AdmissionQueue, DeadlineExceeded, Overloaded, ServiceClosed,
)
from repro.mining.service.scheduler import GroupScheduler
from repro.mining.spec import MineSpec
from repro.mining.telemetry import trace


@dataclasses.dataclass(eq=False)  # identity ==: AdmissionQueue removes by it,
class _Pending:                   # and field-wise eq chokes on array payloads
    req: MineRequest | None  # None for stream operations
    future: Future
    submitted_at: float
    kind: str = "mine"  # "mine" | "stream" (append / stream query)
    run: object = None  # stream ops: zero-arg callable executed in order
    deadline_at: float | None = None  # monotonic instant; admission + QoS
    priority: int = 0
    nbytes: int = 0  # admission byte accounting (rows payload)
    released: bool = False  # accounting done exactly once (see _finish)
    trace_id: int | None = None  # root span id when a tracer is attached


class _ServiceStats(dict):
    """``service.stats`` — the historical counter dict, now also callable:
    ``service.stats()`` returns the merged operator snapshot."""

    def __init__(self, snapshot, **counters):
        super().__init__(**counters)
        self._snapshot = snapshot

    def __call__(self) -> dict:
        return self._snapshot()


class MiningService:
    """Async front-door: ``submit() -> Future[MineResult]``.

    ``batch_window_s`` is the coalescing window: once a request arrives,
    the worker keeps collecting for that long so concurrent callers land
    in one planned batch (sweep requests on one database become one
    shared-prep group; distinct databases become pipelined groups). 0
    serves strictly one request per batch.

    ``max_queue_depth`` / ``max_queue_bytes`` bound admission (None =
    unbounded, the pre-hardening behavior): depth counts queued requests,
    bytes count the ``rows`` payload of everything admitted but not yet
    resolved. Requests that do not fit resolve with ``Overloaded``.
    """

    def __init__(self, engine: MiningEngine | None = None, *, mesh=None,
                 snapshot_dir: str | None = None, batch_window_s: float = 0.02,
                 host_workers: int = 4, max_queue_depth: int | None = None,
                 max_queue_bytes: int | None = None, **engine_kwargs):
        if engine is not None and (mesh is not None or snapshot_dir is not None or engine_kwargs):
            raise ValueError("pass an engine or engine-construction kwargs, not both")
        self.engine = engine if engine is not None else MiningEngine(
            mesh, snapshot_dir=snapshot_dir, **engine_kwargs
        )
        self.scheduler = GroupScheduler(self.engine, host_workers=host_workers)
        self.batch_window_s = float(batch_window_s)
        self.stats = _ServiceStats(
            self._stats_snapshot,
            requests=0, batches=0, max_batch=0,
            worker_restarts=0,  # batches whose serve crashed (loop survived)
            stream_deadline_dropped=0,  # stream ops expired before running
        )
        self._q = AdmissionQueue(
            max_depth=max_queue_depth, max_bytes=max_queue_bytes,
            registry=self.engine.telemetry,
        )
        self._cv = threading.Condition()
        self._outstanding = 0
        self._closed = False
        self._worker_dead = False
        self._worker = threading.Thread(
            target=self._loop, name="mining-service", daemon=True
        )
        self._worker.start()

    # ------------------------------------------------------------ submission
    def submit(self, rows, n_items: int, spec: MineSpec) -> Future:
        """Enqueue one request; the Future resolves to its ``MineResult``
        (or raises what the request raised — including the typed admission
        errors ``Overloaded`` / ``DeadlineExceeded``)."""
        arr = np.asarray(rows)
        deadline_at = (
            time.monotonic() + spec.deadline_s if spec.deadline_s is not None else None
        )
        return self._enqueue(_Pending(
            MineRequest(rows, n_items, spec, deadline_at=deadline_at),
            Future(), time.monotonic(),
            deadline_at=deadline_at, priority=spec.priority, nbytes=int(arr.nbytes),
        ))

    def submit_many(self, requests: Sequence[MineRequest]) -> list[Future]:
        return [self.submit(r.rows, r.n_items, r.spec) for r in requests]

    def _submit_stream_op(self, run, *, spec: MineSpec | None = None,
                          nbytes: int = 0) -> Future:
        deadline_at = (
            time.monotonic() + spec.deadline_s
            if spec is not None and spec.deadline_s is not None else None
        )
        return self._enqueue(_Pending(
            None, Future(), time.monotonic(), kind="stream", run=run,
            deadline_at=deadline_at,
            priority=spec.priority if spec is not None else 0,
            nbytes=int(nbytes),
        ))

    def _enqueue(self, p: _Pending) -> Future:
        """Admission: the closed/dead check, the chaos point, and the queue
        offer are one atomic step under ``_cv`` — a request is either
        rejected here or guaranteed to be observed by the worker (or by
        the worker's exit drain). Every path returns a Future that WILL
        resolve."""
        shed: list[_Pending] = []
        admitted = False
        enq_err: BaseException | None = None
        with self._cv:
            if self._closed or self._worker_dead:
                raise ServiceClosed("MiningService is closed")
            try:
                failures.fire("service.enqueue")
            except BaseException as e:
                enq_err = e
            else:
                admitted, shed = self._q.offer(p)
                if admitted:
                    self._outstanding += 1
                    self.stats["requests"] += 1
        rec = trace.active()
        if admitted and rec is not None:
            # the request's root span: opened at submit time, closed when
            # its Future resolves in _serve (or on a crashed batch)
            p.trace_id = rec.open(
                "request", t0=p.submitted_at, kind=p.kind, priority=p.priority
            )
            if p.req is not None:
                p.req.trace_id = p.trace_id
        # resolve losers outside the lock (their callbacks run inline)
        for s in shed:
            if rec is not None and s.trace_id is not None:
                rec.close(s.trace_id, error="shed")
            self._resolve_exc(s.future, Overloaded(
                "request shed from the admission queue by later-deadline work",
                shed=True, depth=self._q.depth,
                bytes_in_flight=self._q.bytes_in_flight,
            ))
            # offer() already reclaimed shed bytes; only undo the counting
            self._finish(s, release_bytes=False)
        if enq_err is not None:
            self._resolve_exc(p.future, enq_err)
        elif not admitted:
            self._resolve_exc(p.future, Overloaded(
                "admission queue full "
                f"(max_depth={self._q.max_depth}, max_bytes={self._q.max_bytes})",
                depth=self._q.depth, bytes_in_flight=self._q.bytes_in_flight,
            ))
        return p.future

    def append(self, rows, n_items: int | None = None, *, stream: str = "default",
               spec: MineSpec | None = None, stream_spec=None) -> Future:
        """Enqueue a streaming ingest (``engine.append``); the Future
        resolves to the append telemetry dict. Stream operations execute
        in arrival order relative to each other and to mining requests in
        the same batch, so a query submitted after an append observes it.

        The batch is copied HERE, at submit time — execution happens after
        the batching window, and a caller reusing its array for the next
        batch must not retroactively change what this one ingests."""
        rows = np.array(rows, np.int32, copy=True)
        return self._submit_stream_op(
            lambda: self.engine.append(
                rows, n_items, stream=stream, spec=spec, stream_spec=stream_spec
            ),
            nbytes=rows.nbytes,
        )

    def submit_stream(self, spec: MineSpec, *, stream: str = "default") -> Future:
        """Enqueue a query against the named stream's live ``SegmentedDB``;
        the Future resolves to its ``MineResult``."""
        return self._submit_stream_op(
            lambda: self.engine.submit_stream(spec, stream=stream), spec=spec
        )

    def register_standing(self, spec: MineSpec, *, stream: str = "default") -> Future:
        """Enqueue a standing-query registration on the named stream; the
        Future resolves to the ``StandingQuery`` handle (its initial
        answer already delivered as diff 0). Registration rides the same
        arrival-order stream lane as ``append``/``submit_stream``, so a
        query registered after an append observes it — and every
        subsequent append's diff is delivered before that append's own
        Future resolves."""
        return self._submit_stream_op(
            lambda: self.engine.register_standing(spec, stream=stream), spec=spec
        )

    def cancel_standing(self, query, *, stream: str = "default") -> Future:
        """Enqueue a standing-query cancellation (arrival order: diffs
        already in flight ahead of it still deliver)."""
        return self._submit_stream_op(
            lambda: self.engine.cancel_standing(query, stream=stream)
        )

    def distribute(self, name: str = "default", **kw):
        """Create/fetch a distributed database (``engine.distribute``) —
        synchronous, since it spawns worker processes, not a mining op.
        Once created, ``append`` / ``submit_stream`` on its name serve it
        through the ordinary Future path, worker failover included."""
        return self.engine.distribute(name, **kw)

    def sweep(self, rows, n_items: int, spec: MineSpec,
              min_sups: Sequence[float]) -> list[Future]:
        """The paper's threshold sweep, submitted concurrently — the batch
        window coalesces it into one shared-prep group."""
        return [self.submit(rows, n_items, spec.with_(min_sup=s)) for s in min_sups]

    # ------------------------------------------------------------ accounting
    @staticmethod
    def _resolve_exc(fut: Future, exc: BaseException) -> None:
        """Resolve a Future with an error, tolerating a racing cancel —
        nothing here may throw, whatever state the caller drove it into."""
        try:
            fut.set_exception(exc)
        except InvalidStateError:
            pass

    def _finish(self, p: _Pending, *, release_bytes: bool = True) -> None:
        """Close out one accepted request's accounting, exactly once."""
        with self._cv:
            if p.released:
                return
            p.released = True
            self._outstanding -= 1
            self._cv.notify_all()
        if release_bytes:
            self._q.release(p.nbytes)

    def _stats_snapshot(self) -> dict:
        """The operator view: one dict merging every layer's counters.

        ``counters`` is the flat headline set (admitted / rejected / shed /
        deadline_dropped / retries / respawns); the nested sections carry
        each layer's full dict for drill-down. ``histograms`` is the shared
        telemetry registry's latency-distribution view (name -> count /
        sum / min / max / p50 / p95 / p99 / sparse buckets) — see
        ``repro.mining.telemetry``; ``telemetry`` carries its counters,
        gauges, and schema version."""
        service = {k: v for k, v in self.stats.items()}
        adm = self._q.info()
        sched = dict(self.scheduler.stats)
        streams = self.engine.stream_stats()
        tel = self.engine.telemetry.snapshot()
        return {
            "histograms": tel["histograms"],
            "telemetry": {"schema": tel["schema"], "counters": tel["counters"],
                          "gauges": tel["gauges"]},
            "counters": {
                "admitted": adm["admitted"],
                "rejected": adm["rejected"],
                "shed": adm["shed"],
                "deadline_dropped": sched.get("deadline_dropped", 0)
                + service["stream_deadline_dropped"],
                "retries": sum(int(s.get("rpc_retries", 0)) for s in streams.values()),
                "respawns": sum(int(s.get("respawns", 0)) for s in streams.values()),
            },
            "service": service,
            "admission": adm,
            "scheduler": sched,
            "engine": {"stats": dict(self.engine.stats),
                       "cache": self.engine.cache_info()},
            "streams": streams,
        }

    # ------------------------------------------------------------- lifecycle
    def drain(self) -> None:
        """Block until every accepted request has resolved."""
        with self._cv:
            self._cv.wait_for(lambda: self._outstanding == 0 or self._worker_dead)

    def close(self, *, drain: bool = True) -> None:
        """Shutdown: stop accepting, then either drain (default — every
        accepted request resolves normally) or fail still-queued requests
        fast with ``ServiceClosed`` (``drain=False``; the batch already
        executing finishes either way), then stop the worker."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
        if drain:
            self.drain()
        else:
            for p in self._q.drain_queued():
                self._resolve_exc(p.future, ServiceClosed(
                    "MiningService closed with drain=False while this request was queued"
                ))
                self._finish(p)
        self._q.put_sentinel()  # wake + stop the worker
        self._worker.join()
        self.scheduler.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ---------------------------------------------------------- worker loop
    def _loop(self) -> None:
        """Crash-proof batch loop: a serve failure resolves every Future
        the batch owns with that error and the loop continues. The exit
        drain in ``finally`` is the last line of the no-orphaned-Futures
        invariant — even an exit nothing anticipated fails what remains."""
        try:
            while True:
                batch, stop = self._collect()
                if batch:
                    try:
                        failures.fire("service.serve")  # chaos: worker death
                        self._serve(batch)
                    except BaseException as e:
                        self._fail_batch(batch, e)
                        with self._cv:
                            self.stats["worker_restarts"] += 1
                if stop:
                    return
        finally:
            self._worker_exited()

    def _collect(self) -> tuple[list[_Pending], bool]:
        """One batching window: ``(batch, stop)``. Empty batch + stop=False
        is the idle poll tick."""
        try:
            first = self._q.get(timeout=0.1)
        except queue.Empty:
            return [], False
        if first is None:
            return [], True
        batch = [first]
        deadline = time.monotonic() + self.batch_window_s
        while True:
            timeout = deadline - time.monotonic()
            if timeout <= 0:
                return batch, False
            try:
                item = self._q.get(timeout=timeout)
            except queue.Empty:
                return batch, False
            if item is None:
                return batch, True
            batch.append(item)

    def _fail_batch(self, batch: list[_Pending], exc: BaseException) -> None:
        """Resolve every unresolved Future in a crashed batch with the
        crash. Futures ``_serve`` already resolved (or dropped as
        cancelled) are left alone — ``_finish`` is idempotent."""
        rec = trace.active()
        for p in batch:
            if not p.future.done():
                self._resolve_exc(p.future, exc)
            if rec is not None and p.trace_id is not None:
                rec.close(p.trace_id, error=repr(exc))
            self._finish(p)

    def _worker_exited(self) -> None:
        """The worker thread is gone for good: nothing will ever pop the
        queue again, so fail whatever is still on it."""
        with self._cv:
            self._worker_dead = True
            self._cv.notify_all()
        for p in self._q.drain_queued():
            self._resolve_exc(p.future, ServiceClosed(
                "service worker exited before this request ran"
            ))
            self._finish(p)

    def _serve(self, batch: list[_Pending]) -> None:
        t_start = time.monotonic()
        # transition every future to RUNNING; one the caller already
        # cancelled is dropped here (set_result on it would raise
        # InvalidStateError and kill the worker), and RUNNING futures can
        # no longer be cancelled out from under the batch
        live = []
        for p in batch:
            if p.future.set_running_or_notify_cancel():
                live.append(p)
            else:
                self._finish(p)
        batch = live
        if not batch:
            return
        self.stats["batches"] += 1
        self.stats["max_batch"] = max(self.stats["max_batch"], len(batch))
        rec = trace.active()
        if rec is not None:
            for p in batch:
                if p.trace_id is not None:
                    rec.add("admission.wait", p.submitted_at, t_start,
                            parent=p.trace_id)
        # execute in arrival order: contiguous runs of mining requests go
        # through the scheduler as one planned sub-batch, stream operations
        # (appends / stream queries) run inline between them — a query that
        # arrived after an append must observe the appended segment
        results: list = [None] * len(batch)
        chunk: list[int] = []

        def flush_chunk():
            if not chunk:
                return
            try:
                out = self.scheduler.run(
                    [batch[j].req for j in chunk], return_exceptions=True
                )
            except BaseException as e:  # scheduler must not fail a batch silently
                out = [e] * len(chunk)
            for j, r in zip(chunk, out):
                results[j] = r
            chunk.clear()

        for i, p in enumerate(batch):
            if p.kind == "mine":
                chunk.append(i)
                continue
            flush_chunk()
            if p.deadline_at is not None and time.monotonic() > p.deadline_at:
                self.stats["stream_deadline_dropped"] += 1
                results[i] = DeadlineExceeded(
                    "deadline passed before the stream operation ran"
                )
                continue
            try:
                with trace.span("stream.op", parent=p.trace_id):
                    results[i] = p.run()
            except BaseException as e:
                results[i] = e
        flush_chunk()
        req_hist = self.engine.telemetry.histogram("service.request_s")
        for p, res in zip(batch, results):
            t_res = time.monotonic()
            if isinstance(res, BaseException):
                p.future.set_exception(res)
            else:
                if isinstance(res, MineResult):
                    res.service_stats.update(
                        queue_time_s=t_start - p.submitted_at, batch_size=len(batch)
                    )
                p.future.set_result(res)
            now = time.monotonic()
            req_hist.record(now - p.submitted_at)
            if rec is not None and p.trace_id is not None:
                rec.add("resolve", t_res, now, parent=p.trace_id,
                        ok=not isinstance(res, BaseException))
                rec.close(p.trace_id, t1=now)
            self._finish(p)
