"""Miner protocol + registry: ``@register_miner("name")`` is how an
algorithm joins the front-door. The registry maps names to factories
(classes); ``get_miner`` instantiates, ``list_miners`` enumerates — the CLI
and the parity tests iterate it so new algorithms are picked up for free.
"""
from __future__ import annotations

from typing import Callable, Protocol, runtime_checkable

from repro.mining.result import MineResult
from repro.mining.spec import MineSpec


@runtime_checkable
class Miner(Protocol):
    """One mining backend behind the unified front-door."""

    name: str
    # True when `itemsets` materializes *every* frequent itemset (pattern
    # post-passes need the full dict; CPE-pruned miners set False).
    exhaustive: bool

    def mine(self, rows, n_items: int, spec: MineSpec) -> MineResult:
        ...


_REGISTRY: dict[str, Callable[..., Miner]] = {}


def register_miner(name: str):
    """Class decorator registering a Miner factory under ``name``."""

    def deco(cls):
        cls.name = name
        if name in _REGISTRY:
            raise ValueError(f"miner {name!r} already registered")
        _REGISTRY[name] = cls
        return cls

    return deco


def get_miner(name: str, **kwargs) -> Miner:
    """Instantiate the miner registered under ``name``."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown miner {name!r}; registered: {list_miners()}") from None
    return factory(**kwargs)


def list_miners() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))
