"""MineResult: the one enriched answer every miner returns.

Supersedes the seed's per-algorithm surfaces (core ``MineResult`` without
timings, ``(dict, stats)`` tuples from fpgrowth/apriori, bare dict from the
oracle): itemsets + exact count + memory peak + wall time + per-stage
timings, whichever backend produced them.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class MineResult:
    """Frequent itemsets (original item ids) plus run telemetry.

    ``itemsets`` maps sorted item-id tuples to supports. ``total_count`` is
    the exact number of frequent itemsets — for CPE-pruned miners it exceeds
    ``n_explicit`` (``itemsets`` then holds the explicit subset only, each
    with its exact support). When ``spec.patterns != "all"``, ``itemsets``
    holds the selected family and ``n_explicit``/``total_count`` still
    describe the full frequent collection it was derived from.
    """

    algorithm: str
    itemsets: dict[tuple[int, ...], int]
    total_count: int  # exact number of frequent itemsets (incl. CPE-implied)
    n_explicit: int  # itemsets explicitly materialized by the miner
    min_count: int  # resolved absolute threshold used
    n_rows: int  # database size the threshold was resolved against
    peak_bytes: int  # analytic peak of mining structures (paper's memory figs)
    wall_time_s: float  # host-observed end-to-end mining time
    stage_times_s: dict[str, float] = dataclasses.field(default_factory=dict)
    flist_items: np.ndarray | None = None  # F1 items, support-descending
    # True when prep stages (Job 1/Job 2/pack/F2) were served from a shared
    # PreparedDB built for another request in the same planned group; the
    # request that paid for prep carries the real stage times, shared
    # consumers carry 0.0 for those keys (honest attribution, no double
    # counting when summing stage times across a sweep).
    prep_shared: bool = False
    # Serving-layer telemetry, filled by whoever routed the request:
    #   prep_source      "built" | "cache" | "snapshot" (engine)
    #   prep_overlapped  True when this group's prepare ran while an earlier
    #                    group was still mining (scheduler)
    #   queue_time_s     submit -> batch-execution-start (service)
    #   batch_size       requests coalesced into this request's batch (service)
    service_stats: dict = dataclasses.field(default_factory=dict)

    def support_of(self, itemset) -> int:
        return self.itemsets[tuple(sorted(int(i) for i in itemset))]

    def by_size(self, k: int) -> dict[tuple[int, ...], int]:
        """The mined itemsets of exactly ``k`` items."""
        return {s: v for s, v in self.itemsets.items() if len(s) == k}

    def top(self, n: int = 10) -> list[tuple[tuple[int, ...], int]]:
        """Largest-then-most-supported itemsets (the CLI's report order)."""
        return sorted(self.itemsets.items(), key=lambda kv: (-len(kv[0]), -kv[1]))[:n]

    def summary(self) -> str:
        return (
            f"{self.algorithm}: {self.total_count} frequent itemsets "
            f"({self.n_explicit} explicit) at min_count={self.min_count} "
            f"over {self.n_rows} rows in {self.wall_time_s:.3f}s "
            f"[peak {self.peak_bytes / 1e6:.2f} MB]"
        )
