"""repro.mining.tune — kernel execution plans: real backend dispatch plus a
small persisted autotuner for the fused intersect kernel's block knobs.

Three layers of the stack meet here:

* **Backend registry.** ``MineSpec.backend`` used to be a string switch
  (``auto|pallas|jnp``) that silently accepted anything. The registry below
  is the single source of truth: user-facing names (``auto``, ``pallas``,
  ``jnp``, ``pallas-tpu``, ``pallas-gpu``, ``pallas-interpret``) resolve via
  :func:`resolve_backend` to a *concrete* backend for the current platform,
  or raise with the registered list. ``auto`` picks the fastest available
  path (Pallas on TPU/GPU, jnp elsewhere); ``pallas`` forces a Pallas
  lowering, falling back to the interpreter off-accelerator — which is what
  makes the masked early-stop kernel testable in CPU CI.

* **KernelPlan.** One frozen record of everything the execution layer needs
  to launch a wave: the resolved backend, the three block knobs, and the
  early-stop flag. ``HPrepostMiner`` resolves a plan per (candidate-count,
  nlist-width) and threads it into the wave jits as static arguments, so
  retuning never touches prep caches or snapshot keys (blocks are
  execution-only).

* **KernelTuner.** ``la_block/ly_block/batch_block`` were manual knobs; the
  tuner replaces the guess with a small timed search over block configs on
  first use per (backend, platform, width-bucket, batch-bucket), persisted
  as ``kernel_plans.json`` next to the ``SnapshotStore`` so every process on
  the mesh reruns its best config with zero search trials.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import os
import threading
import time

import jax
import numpy as np

from repro.checkpoint.atomic import fsync_write

PLANS_SCHEMA = 1
PLANS_FILENAME = "kernel_plans.json"

# user-facing backend names -> how they resolve per platform. ``None`` means
# "not available here" and makes resolve_backend raise.
_REGISTRY: dict[str, dict[str, str | None]] = {
    "auto": {"tpu": "pallas-tpu", "gpu": "pallas-gpu", "*": "jnp"},
    "pallas": {"tpu": "pallas-tpu", "gpu": "pallas-gpu", "*": "pallas-interpret"},
    "jnp": {"*": "jnp"},
    "pallas-tpu": {"tpu": "pallas-tpu", "*": None},
    "pallas-gpu": {"gpu": "pallas-gpu", "*": None},
    "pallas-interpret": {"*": "pallas-interpret"},
}

# concrete backends an execution layer can actually be handed
PALLAS_BACKENDS = frozenset({"pallas-tpu", "pallas-gpu", "pallas-interpret"})


def registered_backends() -> list[str]:
    """Every name ``MineSpec.backend`` may carry."""
    return sorted(_REGISTRY)


def resolve_backend(name: str, platform: str | None = None) -> str:
    """Map a user-facing backend name to the concrete backend for this
    platform. Unknown names and unavailable backends raise ValueError."""
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown backend {name!r}; registered backends: "
            f"{', '.join(registered_backends())}"
        )
    platform = platform or jax.default_backend()
    table = _REGISTRY[name]
    resolved = table.get(platform, table.get("*"))
    if resolved is None:
        raise ValueError(
            f"backend {name!r} is not available on platform {platform!r} "
            f"(default backend: {jax.default_backend()!r})"
        )
    return resolved


def is_pallas(backend: str) -> bool:
    return backend in PALLAS_BACKENDS


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """Resolved execution config for one wave launch: a concrete backend,
    the intersect kernel's block knobs, and the early-stop flag. ``source``
    records where the blocks came from (``config`` = the HPrepostConfig
    defaults, ``tuned`` = fresh search, ``cached`` = persisted search)."""

    backend: str
    la_block: int
    ly_block: int
    batch_block: int
    early_stop: bool
    source: str = "config"


def static_plan(
    backend: str,
    la_block: int,
    ly_block: int,
    batch_block: int,
    early_stop: bool,
    platform: str | None = None,
) -> KernelPlan:
    """A plan straight from config knobs — no search, backend resolved."""
    return KernelPlan(
        backend=resolve_backend(backend, platform),
        la_block=la_block,
        ly_block=ly_block,
        batch_block=batch_block,
        early_stop=early_stop,
        source="config",
    )


def _bucket(n: int, lo: int, hi: int) -> int:
    """Smallest power of two >= n, clamped to [lo, hi] — plans are keyed
    and measured per bucket, not per exact shape."""
    n = max(int(n), 1)
    b = 1 << (n - 1).bit_length()
    return max(lo, min(hi, b))


def _synthetic_nlists(B: int, W: int) -> tuple[np.ndarray, ...]:
    """Timing fixtures: shape- and dtype-faithful PP-code batches. The
    kernel's cost is data-independent (dense mask contraction), so sorted
    random codes are as representative as real ones."""
    rng = np.random.default_rng(0)
    a_pre = np.sort(rng.integers(0, 1 << 20, (B, W)), axis=1).astype(np.int32)
    a_post = np.sort(rng.integers(0, 1 << 20, (B, W)), axis=1).astype(np.int32)
    y_pre = np.sort(rng.integers(0, 1 << 20, (B, W)), axis=1).astype(np.int32)
    y_post = np.sort(rng.integers(0, 1 << 20, (B, W)), axis=1).astype(np.int32)
    y_cnt = rng.integers(1, 8, (B, W)).astype(np.int32)
    a_cnt = rng.integers(1, 8, (B, W)).astype(np.int32)
    return a_pre, a_post, a_cnt, y_pre, y_post, y_cnt


class KernelTuner:
    """Timed block-config search with a cross-process JSON plan cache.

    ``plan_for`` is the only entry point: it buckets the requested shape,
    serves a persisted plan when one exists (``stats['trials']`` stays 0 —
    the property ``make tune-smoke`` asserts), and otherwise times a small
    cartesian search and persists the winner atomically.
    """

    LA_CHOICES = (128, 256, 512)
    BB_CHOICES = (4, 8, 16)

    def __init__(self, plan_dir: str | None = None, platform: str | None = None):
        self._dir = plan_dir
        self._platform = platform or jax.default_backend()
        self._plans: dict[str, dict] = {}
        self._lock = threading.Lock()
        self.stats = {
            "trials": 0,       # timed kernel launches this process
            "tuned": 0,        # keys searched this process
            "plan_hits": 0,    # keys served from memory/disk
            "loaded_plans": 0, # keys read from kernel_plans.json
        }
        if self._dir:
            self._load()
            self.stats["loaded_plans"] = len(self._plans)

    # ------------------------------------------------------------ persistence
    def _path(self) -> str:
        return os.path.join(self._dir, PLANS_FILENAME)

    def _load(self) -> None:
        try:
            with open(self._path(), "rb") as f:
                doc = json.loads(f.read().decode())
        except (FileNotFoundError, ValueError, OSError):
            return
        if doc.get("schema") != PLANS_SCHEMA:
            return
        self._plans.update(doc.get("plans", {}))

    def _save(self) -> None:
        if not self._dir:
            return
        os.makedirs(self._dir, exist_ok=True)
        doc = {"schema": PLANS_SCHEMA, "plans": self._plans}
        fsync_write(self._path(), json.dumps(doc, indent=1, sort_keys=True).encode())

    # ------------------------------------------------------------ the search
    def _key(self, backend: str, B: int, W: int, early_stop: bool) -> str:
        wb = _bucket(W, 8, 1024)
        bbk = _bucket(B, 8, 512)
        return f"{backend}|{self._platform}|es{int(early_stop)}|W{wb}|B{bbk}"

    def _measure_us(self, backend, B, W, la, ly, bb, early_stop, reps=3) -> float:
        from repro.kernels.nlist_intersect.ops import nlist_intersect

        arrs = _synthetic_nlists(B, W)
        a_pre, a_post, a_cnt, y_pre, y_post, y_cnt = arrs

        def launch():
            merged, sup = nlist_intersect(
                a_pre, a_post, y_pre, y_post, y_cnt,
                a_cnt=a_cnt, backend=backend,
                la_block=la, ly_block=ly, batch_block=bb,
                early_stop=early_stop, min_count=2 if early_stop else None,
            )
            jax.block_until_ready((merged, sup))

        launch()  # compile outside the timed region
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            launch()
            best = min(best, time.perf_counter() - t0)
            self.stats["trials"] += 1
        return best * 1e6

    def _search(self, backend: str, B: int, W: int, early_stop: bool) -> dict:
        # measure at the bucketed shape (that is what the key promises);
        # the interpreter is a python loop, so cap its fixture sizes
        wb = _bucket(W, 8, 1024)
        bbk = _bucket(B, 8, 512)
        if backend == "pallas-interpret":
            wb, bbk = min(wb, 128), min(bbk, 32)
        la_opts = sorted({min(wb, c) for c in self.LA_CHOICES})
        bb_opts = sorted({min(bbk, c) for c in self.BB_CHOICES})
        best = None
        for la, bb in itertools.product(la_opts, bb_opts):
            us = self._measure_us(backend, bbk, wb, la, la, bb, early_stop)
            if best is None or us < best["best_us"]:
                best = {
                    "la_block": la, "ly_block": la, "batch_block": bb,
                    "best_us": round(us, 1),
                    "trials": len(la_opts) * len(bb_opts),
                }
        return best

    # -------------------------------------------------------------- frontdoor
    def plan_for(
        self,
        *,
        backend: str,
        B: int,
        W: int,
        early_stop: bool,
        defaults: tuple[int, int, int] = (512, 512, 8),
        tune: bool = True,
    ) -> KernelPlan:
        resolved = resolve_backend(backend, self._platform)
        if resolved == "jnp" and not tune:
            # blocks are inert on the jnp path; skip even the dict lookup
            return KernelPlan(resolved, *defaults, early_stop, "config")
        key = self._key(resolved, B, W, early_stop)
        with self._lock:
            rec = self._plans.get(key)
            if rec is not None:
                self.stats["plan_hits"] += 1
                src = "cached"
            elif not tune:
                return KernelPlan(resolved, *defaults, early_stop, "config")
            else:
                rec = self._search(resolved, B, W, early_stop)
                self._plans[key] = rec
                self._save()
                self.stats["tuned"] += 1
                src = "tuned"
            return KernelPlan(
                backend=resolved,
                la_block=int(rec["la_block"]),
                ly_block=int(rec["ly_block"]),
                batch_block=int(rec["batch_block"]),
                early_stop=early_stop,
                source=src,
            )
