"""Per-request span trees with monotonic timestamps.

The recorder follows the ``repro.fault.failures`` attach/detach shape: a
module-global ``_active`` recorder that every instrumentation site reads
once. With nothing attached, ``span(...)`` returns a shared no-op
context manager — one global load and one function call, so the hot wave
loop pays nothing when tracing is off. Attach a ``TraceRecorder`` (the
CLI does this for ``--trace out.json``) and the same sites produce a
span tree per request:

    request                      (opened at submit, closed at resolve)
      admission.wait             (retroactive: submit -> batch start)
      group.classify
      group.prep
      group.serve
        mine.wave k=2            (device dispatch, per level)
        mine.reduce k=2          (host blocking collect + prune)
      resolve

Parenting is two-mode: explicit (``parent=`` span id, used across
threads — the service carries the request root's id on its ``_Pending``
record into the worker loop) and implicit (a thread-local stack, so
spans opened on one thread nest naturally: wave spans inside the
serving span). Timestamps are ``time.monotonic()`` seconds relative to
the recorder's epoch; exports are plain JSON (nested tree) and Chrome
trace-event format (``chrome://tracing`` / Perfetto loads it directly).
"""
from __future__ import annotations

import contextlib
import json
import threading
import time


class _NullSpan:
    """Reusable no-op context manager: the detached fast path."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()
_active: "TraceRecorder | None" = None
_tls = threading.local()


def active() -> "TraceRecorder | None":
    """The currently attached recorder, or None."""
    return _active


def attach(rec: "TraceRecorder | None") -> "TraceRecorder | None":
    """Install ``rec`` as the global recorder; returns the previous one."""
    global _active
    prev, _active = _active, rec
    return prev


@contextlib.contextmanager
def attached(rec: "TraceRecorder"):
    """Scoped attach — the CLI/test shape: ``with attached(rec): ...``."""
    prev = attach(rec)
    try:
        yield rec
    finally:
        attach(prev)


def span(name: str, *, parent: int | None = None, **args):
    """A context manager tracing one span under the attached recorder
    (no-op when detached). ``parent`` overrides the thread-local stack."""
    rec = _active
    if rec is None:
        return _NULL
    return rec.span(name, parent=parent, **args)


def current_span() -> int | None:
    """Id of the innermost open span on this thread (implicit parent)."""
    stack = getattr(_tls, "stack", None)
    return stack[-1] if stack else None


class TraceRecorder:
    """Collects spans; thread-safe; exports JSON trees + Chrome events."""

    def __init__(self):
        self._lock = threading.Lock()
        self._next_id = 0
        self.epoch = time.monotonic()
        # id -> {"name", "t0", "t1", "parent", "tid", "args"}; t1 None while open
        self.spans: dict[int, dict] = {}

    # ------------------------------------------------------ span plumbing
    def open(self, name: str, *, t0: float | None = None,
             parent: int | None = None, **args) -> int:
        """Open a span at ``t0`` (now when omitted); returns its id."""
        t0 = time.monotonic() if t0 is None else t0
        with self._lock:
            sid = self._next_id
            self._next_id += 1
            self.spans[sid] = {
                "name": name,
                "t0": t0,
                "t1": None,
                "parent": parent,
                "tid": threading.get_ident(),
                "args": dict(args) if args else {},
            }
        return sid

    def close(self, sid: int, *, t1: float | None = None, **args) -> None:
        t1 = time.monotonic() if t1 is None else t1
        with self._lock:
            s = self.spans.get(sid)
            if s is not None and s["t1"] is None:
                s["t1"] = t1
                if args:
                    s["args"].update(args)

    def add(self, name: str, t0: float, t1: float, *,
            parent: int | None = None, **args) -> int:
        """Record a retroactive span from explicit monotonic timestamps
        (e.g. admission wait: submit time -> batch start time)."""
        sid = self.open(name, t0=t0, parent=parent, **args)
        self.close(sid, t1=max(t1, t0))
        return sid

    @contextlib.contextmanager
    def span(self, name: str, *, parent: int | None = None, **args):
        """Scoped span; nests under this thread's innermost open span
        unless ``parent`` is given explicitly."""
        if parent is None:
            parent = current_span()
        sid = self.open(name, parent=parent, **args)
        stack = getattr(_tls, "stack", None)
        if stack is None:
            stack = _tls.stack = []
        stack.append(sid)
        try:
            yield sid
        finally:
            stack.pop()
            self.close(sid)

    def __len__(self) -> int:
        with self._lock:
            return len(self.spans)

    # ----------------------------------------------------------- exports
    def _closed(self) -> list[tuple[int, dict]]:
        """Snapshot of spans, open ones closed at 'now' for export."""
        now = time.monotonic()
        with self._lock:
            out = []
            for sid, s in sorted(self.spans.items()):
                s = dict(s)
                if s["t1"] is None:
                    s["t1"] = now
                    s["args"] = {**s["args"], "open": True}
                out.append((sid, s))
        return out

    def to_json(self) -> list[dict]:
        """Nested span trees (list of roots), times relative to epoch."""
        spans = self._closed()
        nodes = {
            sid: {
                "id": sid,
                "name": s["name"],
                "t_start_s": s["t0"] - self.epoch,
                "dur_s": s["t1"] - s["t0"],
                "args": s["args"],
                "children": [],
            }
            for sid, s in spans
        }
        roots = []
        for sid, s in spans:
            p = s["parent"]
            if p is not None and p in nodes:
                nodes[p]["children"].append(nodes[sid])
            else:
                roots.append(nodes[sid])
        return roots

    def to_chrome(self) -> list[dict]:
        """Chrome trace-event list (``ph: "X"`` complete events, us)."""
        events = []
        for sid, s in self._closed():
            ev = {
                "name": s["name"],
                "ph": "X",
                "ts": (s["t0"] - self.epoch) * 1e6,
                "dur": (s["t1"] - s["t0"]) * 1e6,
                "pid": 0,
                "tid": s["tid"],
                "cat": "mining",
                "args": {**s["args"], "span_id": sid},
            }
            if s["parent"] is not None:
                ev["args"]["parent_id"] = s["parent"]
            events.append(ev)
        return events

    def save_chrome(self, path: str) -> int:
        """Write the Chrome trace-event JSON array; returns event count."""
        events = self.to_chrome()
        with open(path, "w") as f:
            json.dump(events, f, indent=1)
            f.write("\n")
        return len(events)

    def save_json(self, path: str) -> int:
        roots = self.to_json()
        with open(path, "w") as f:
            json.dump(roots, f, indent=1)
            f.write("\n")
        return len(roots)
