"""Background periodic stats emitter: registry snapshots to JSON-lines.

``StatsEmitter`` snapshots a callable (typically ``MiningService.stats``
or ``Registry.snapshot``) every ``interval_s`` on a daemon thread and
appends one JSON line per tick to a sink (a path, ``"-"`` for stderr, or
any file-like with ``write``). Each line is an envelope::

    {"schema": 1, "seq": 3, "reason": "interval",
     "uptime_s": 0.61, "wall_time": 1754650000.1, "stats": {...}}

``schema`` is ``hist.SCHEMA_VERSION`` — consumers key parsing off it.

Failure containment is the whole point of the design: the emitter sits
*beside* the request path, never in it. Every tick first fires the
``telemetry.emit`` chaos point (``repro.fault.failures``) and then runs
the snapshot + write inside a try — an injected fault or a sink I/O
error increments ``stats["dropped"]`` / ``stats["errors"]`` and the loop
keeps ticking; nothing ever propagates to a request Future (the chaos
soak asserts exactly this). ``stop()`` emits one final snapshot
(``reason: "final"``) so short runs still land a complete record.
"""
from __future__ import annotations

import json
import os
import sys
import threading
import time

from repro.fault import failures

from .hist import SCHEMA_VERSION


class StatsEmitter:
    """Periodic JSON-lines snapshots of ``snapshot_fn()`` to ``sink``."""

    def __init__(self, snapshot_fn, sink, interval_s: float = 1.0):
        if interval_s <= 0:
            raise ValueError("interval_s must be > 0")
        self._snapshot_fn = snapshot_fn
        self.interval_s = float(interval_s)
        self._own_file = None
        if sink == "-":
            self._sink = sys.stderr
        elif isinstance(sink, (str, os.PathLike)):
            d = os.path.dirname(os.fspath(sink))
            if d:
                os.makedirs(d, exist_ok=True)
            self._own_file = open(sink, "a")
            self._sink = self._own_file
        else:
            self._sink = sink
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()  # serializes emit_once vs stop
        self._t0 = time.monotonic()
        self.stats = {
            "emits": 0,       # lines successfully written (any reason)
            "periodic": 0,    # successful interval ticks
            "dropped": 0,     # chaos-dropped ticks (telemetry.emit fired)
            "errors": 0,      # snapshot/serialize/write failures
        }

    # ---------------------------------------------------------- lifecycle
    def start(self) -> "StatsEmitter":
        if self._thread is not None:
            return self
        self._t0 = time.monotonic()
        self._thread = threading.Thread(
            target=self._loop, name="stats-emitter", daemon=True
        )
        self._thread.start()
        return self

    def stop(self, *, final: bool = True) -> None:
        """Stop the loop; emit one last snapshot unless ``final=False``."""
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout=5.0)
            self._thread = None
        if final:
            self.emit_once(reason="final")
        if self._own_file is not None:
            try:
                self._own_file.close()
            except OSError:
                pass
            self._own_file = None

    def __enter__(self) -> "StatsEmitter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # --------------------------------------------------------------- emit
    def emit_once(self, *, reason: str = "interval") -> bool:
        """One snapshot+write attempt. Never raises: chaos drops and sink
        errors are counted and swallowed — a lost emit is a lost line,
        not a failed request."""
        with self._lock:
            try:
                failures.fire("telemetry.emit")
            except Exception:
                self.stats["dropped"] += 1
                return False
            try:
                snap = self._snapshot_fn()
                line = json.dumps(
                    {
                        "schema": SCHEMA_VERSION,
                        "seq": self.stats["emits"],
                        "reason": reason,
                        "uptime_s": round(time.monotonic() - self._t0, 6),
                        "wall_time": time.time(),
                        "stats": snap,
                    },
                    default=str,
                )
                if self._own_file is not None and self._own_file.closed:
                    raise OSError("emitter sink closed")
                self._sink.write(line + "\n")
                flush = getattr(self._sink, "flush", None)
                if flush is not None:
                    flush()
            except Exception:
                self.stats["errors"] += 1
                return False
            self.stats["emits"] += 1
            if reason == "interval":
                self.stats["periodic"] += 1
            return True

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            self.emit_once(reason="interval")
