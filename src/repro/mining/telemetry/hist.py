"""Fixed log-bucket latency histograms plus a counter/gauge registry.

``LatencyHistogram`` is the workhorse: a fixed set of log-spaced bucket
upper edges (shared by every instance created with the default layout, so
histograms merge without resampling), exact ``count``/``sum``/``min``/
``max`` under a lock, and quantile *estimates* located from the bucket
boundaries. The estimate contract — what the property tests pin down — is

  - counts are exact (every ``record`` lands in exactly one bucket);
  - ``merge`` is associative and commutative and loses nothing: the
    merged histogram is bucket-for-bucket the sum of its inputs;
  - a quantile estimate is bounded by the edges of the bucket that
    contains the true quantile (and by the observed min/max, which can
    only tighten that interval — both always contain the true value).

Everything here is plain Python + ``threading.Lock``: instruments are
touched from the service worker loop, the scheduler prep pool, stream
append paths and RPC collect loops concurrently. Recording is O(log
buckets) (a bisect) under a per-instrument lock — nanoseconds against
the microsecond-scale latencies being measured, and execution-orthogonal
by construction: nothing here ever feeds a prep/device/snapshot key.

``Registry`` is the shared namespace: get-or-create by dotted name
(``admission.queue_wait_s``, ``engine.stage.mining_waves_s``,
``dist.<stream>.worker<wid>.wave_rpc_s``, ...), one ``snapshot()`` that
the stats surface and the periodic emitter both consume. The snapshot
dict carries ``SCHEMA_VERSION`` so JSON-lines consumers can detect
layout changes.
"""
from __future__ import annotations

import math
import threading
from bisect import bisect_left

# Version of the snapshot/emitter JSON layout. Bump when bucket edges,
# snapshot keys, or the emitter envelope change shape.
SCHEMA_VERSION = 1

# Default bucket upper edges (seconds): log-spaced, factor 2, from 1us up
# to ~9 minutes; values above the last edge land in a +Inf overflow
# bucket. 30 edges -> 31 buckets, small enough to snapshot densely.
_N_EDGES = 30
DEFAULT_EDGES = tuple(1e-6 * (2.0 ** i) for i in range(_N_EDGES))


class LatencyHistogram:
    """Thread-safe fixed-bucket histogram over non-negative seconds."""

    __slots__ = ("edges", "counts", "n", "total", "vmin", "vmax", "_lock")

    def __init__(self, edges=DEFAULT_EDGES):
        self.edges = tuple(edges)
        if not self.edges or any(
            b <= a for a, b in zip(self.edges, self.edges[1:])
        ):
            raise ValueError("edges must be non-empty and strictly increasing")
        self.counts = [0] * (len(self.edges) + 1)  # last = overflow (+Inf)
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self._lock = threading.Lock()

    # ------------------------------------------------------------ record
    def record(self, seconds: float) -> None:
        """Record one latency observation (negative clamps to 0)."""
        v = float(seconds)
        if v < 0.0 or v != v:  # clamp negatives, drop NaN to 0
            v = 0.0
        i = bisect_left(self.edges, v)  # first edge >= v; len(edges) = +Inf
        with self._lock:
            self.counts[i] += 1
            self.n += 1
            self.total += v
            if v < self.vmin:
                self.vmin = v
            if v > self.vmax:
                self.vmax = v

    # ------------------------------------------------------------- merge
    def merge(self, other: "LatencyHistogram") -> "LatencyHistogram":
        """Fold ``other`` into self (exact: bucket-wise sum). Returns self."""
        if self.edges != other.edges:
            raise ValueError("cannot merge histograms with different edges")
        # lock ordering by id() so concurrent cross-merges cannot deadlock
        first, second = (self, other) if id(self) < id(other) else (other, self)
        with first._lock, second._lock:
            for i, c in enumerate(other.counts):
                self.counts[i] += c
            self.n += other.n
            self.total += other.total
            if other.vmin < self.vmin:
                self.vmin = other.vmin
            if other.vmax > self.vmax:
                self.vmax = other.vmax
        return self

    def copy(self) -> "LatencyHistogram":
        h = LatencyHistogram(self.edges)
        with self._lock:
            h.counts = list(self.counts)
            h.n = self.n
            h.total = self.total
            h.vmin = self.vmin
            h.vmax = self.vmax
        return h

    # --------------------------------------------------------- quantiles
    def _bucket_bounds(self, i: int) -> tuple[float, float]:
        lo = 0.0 if i == 0 else self.edges[i - 1]
        hi = self.edges[i] if i < len(self.edges) else math.inf
        return lo, hi

    def quantile_bounds(self, q: float) -> tuple[float, float]:
        """Edges of the bucket containing the true q-quantile (the k-th
        smallest observation, k = ceil(q*n) clamped to [1, n])."""
        with self._lock:
            if self.n == 0:
                return (0.0, 0.0)
            k = min(self.n, max(1, math.ceil(q * self.n)))
            cum = 0
            for i, c in enumerate(self.counts):
                cum += c
                if cum >= k:
                    return self._bucket_bounds(i)
        return self._bucket_bounds(len(self.edges))  # unreachable

    def quantile(self, q: float) -> float:
        """Point estimate for the q-quantile: geometric midpoint of the
        containing bucket, tightened by the observed min/max. Always lies
        within ``quantile_bounds(q)``."""
        with self._lock:
            if self.n == 0:
                return 0.0
            k = min(self.n, max(1, math.ceil(q * self.n)))
            cum = 0
            idx = len(self.edges)
            for i, c in enumerate(self.counts):
                cum += c
                if cum >= k:
                    idx = i
                    break
            lo, hi = self._bucket_bounds(idx)
            if not math.isfinite(hi):
                hi = max(self.vmax, lo)  # overflow bucket: cap at observed max
            est = math.sqrt(lo * hi) if lo > 0.0 else hi / 2.0
            # clamp into the bucket, then tighten by observed extremes —
            # the true quantile lies in both intervals, so their
            # intersection is non-empty and still inside the bucket
            est = min(max(est, lo), hi)
            est = min(max(est, self.vmin), self.vmax)
            return min(max(est, lo), hi)

    # ---------------------------------------------------------- snapshot
    def snapshot(self) -> dict:
        """JSON-ready summary. Bucket counts are exported sparsely keyed
        by upper edge ("inf" for the overflow bucket)."""
        with self._lock:
            n, total = self.n, self.total
            vmin = self.vmin if n else 0.0
            vmax = self.vmax if n else 0.0
            buckets = {
                ("inf" if i == len(self.edges) else repr(self.edges[i])): c
                for i, c in enumerate(self.counts)
                if c
            }
        return {
            "count": n,
            "sum_s": total,
            "min_s": vmin,
            "max_s": vmax,
            "p50_s": self.quantile(0.50),
            "p95_s": self.quantile(0.95),
            "p99_s": self.quantile(0.99),
            "buckets": buckets,
        }


class Counter:
    """Monotone counter (thread-safe)."""

    __slots__ = ("_v", "_lock")

    def __init__(self):
        self._v = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._v += n

    @property
    def value(self) -> int:
        return self._v


class Gauge:
    """Point-in-time value (thread-safe set/add)."""

    __slots__ = ("_v", "_lock")

    def __init__(self):
        self._v = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._v = v

    def add(self, dv: float) -> None:
        with self._lock:
            self._v += dv

    @property
    def value(self) -> float:
        return self._v


class Registry:
    """Get-or-create namespace of instruments, snapshotted as one dict."""

    def __init__(self):
        self._lock = threading.Lock()
        self._hists: dict[str, LatencyHistogram] = {}
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}

    def histogram(self, name: str) -> LatencyHistogram:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = LatencyHistogram()
            return h

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter()
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge()
            return g

    def histograms(self) -> dict:
        """name -> histogram snapshot, sorted by name."""
        with self._lock:
            items = sorted(self._hists.items())
        return {name: h.snapshot() for name, h in items}

    def snapshot(self) -> dict:
        with self._lock:
            counters = sorted(self._counters.items())
            gauges = sorted(self._gauges.items())
        return {
            "schema": SCHEMA_VERSION,
            "histograms": self.histograms(),
            "counters": {n: c.value for n, c in counters},
            "gauges": {n: g.value for n, g in gauges},
        }
