"""repro.mining.telemetry — latency histograms, request trace spans, and
a periodic stats emitter for the serving stack.

Three orthogonal pieces (see each module's docstring):

  - :mod:`.hist` — ``LatencyHistogram`` (fixed log buckets, mergeable,
    thread-safe, exact counts, p50/p95/p99 from bucket edges) plus the
    ``Registry`` of named histograms/counters/gauges every serving layer
    shares (one per ``MiningEngine``, at ``engine.telemetry``);
  - :mod:`.trace` — per-request span trees behind a ``failures``-style
    global attach/detach, exported as JSON or Chrome trace events;
  - :mod:`.emit` — ``StatsEmitter``, a background JSON-lines snapshot
    loop with chaos-point drop containment (``telemetry.emit``).

Instrumentation is execution-orthogonal: registry and tracer state never
feed prep/device/snapshot keys, and with no tracer attached the span
sites cost one global read.
"""
from .emit import StatsEmitter
from .hist import (
    DEFAULT_EDGES,
    SCHEMA_VERSION,
    Counter,
    Gauge,
    LatencyHistogram,
    Registry,
)
from .trace import TraceRecorder, active, attach, attached, current_span, span

__all__ = [
    "DEFAULT_EDGES",
    "SCHEMA_VERSION",
    "Counter",
    "Gauge",
    "LatencyHistogram",
    "Registry",
    "StatsEmitter",
    "TraceRecorder",
    "active",
    "attach",
    "attached",
    "current_span",
    "span",
]
