"""repro.mining — the unified front-door to every frequent-itemset miner.

The paper compares a *family* of algorithms (HPrepost vs. PrePost/PrePost+,
FP-growth, Apriori); this package gives them one typed call surface:

    from repro.mining import MineSpec, mine

    res = mine(rows, n_items, MineSpec(algorithm="hprepost", min_sup=0.3))
    res.itemsets, res.total_count, res.wall_time_s, res.stage_times_s

    # resident session (warm jit caches across submits); threshold sweeps
    # are planned — prep stages run once at the loosest threshold and every
    # min_sup is served from the shared PreparedDB:
    from repro.mining import MiningEngine
    eng = MiningEngine(mesh)
    results = eng.sweep(rows, n_items, MineSpec(max_k=5), [0.4, 0.3, 0.2])

Registered algorithms: ``hprepost`` (the paper's distributed miner),
``prepost`` / ``prepost+``, ``fpgrowth``, ``apriori``, ``bruteforce``
(test oracle). New miners join via ``@register_miner("name")``.

The serving layer lives in ``repro.mining.service`` (re-exported lazily
from here): ``MiningService`` (submit -> Future, batching window, drain),
``GroupScheduler`` (cross-group prepare/mine overlap) and
``SnapshotStore`` (cross-process PreparedDB persistence; also reachable
as ``MiningEngine(snapshot_dir=...)`` for warm starts without a service).
"""
from repro.mining.engine import MineRequest, MiningEngine
from repro.mining import miners as _miners  # noqa: F401  (populates the registry)
from repro.mining.miners import default_mesh
from repro.mining.registry import Miner, get_miner, list_miners, register_miner
from repro.mining.result import MineResult
from repro.mining.spec import PATTERN_KINDS, MineSpec


_default_engine: MiningEngine | None = None


def mine(rows, n_items: int, spec: MineSpec | None = None, **spec_kwargs) -> MineResult:
    """One-shot front-door: ``mine(rows, n_items, MineSpec(...))`` or
    ``mine(rows, n_items, algorithm="prepost", min_sup=0.3)``.

    Routed through a process-wide default ``MiningEngine`` so even ad-hoc
    calls reuse warm jit caches on the default mesh.
    """
    global _default_engine
    if spec is None:
        spec = MineSpec(**spec_kwargs)
    elif spec_kwargs:
        raise TypeError("pass a MineSpec or spec kwargs, not both")
    if _default_engine is None:
        _default_engine = MiningEngine()
    return _default_engine.submit(rows, n_items, spec)


__all__ = [
    "GroupScheduler",
    "MineSpec",
    "MineResult",
    "MineRequest",
    "Miner",
    "MiningEngine",
    "MiningService",
    "PATTERN_KINDS",
    "SnapshotStore",
    "StreamSpec",
    "StreamingMiner",
    "default_mesh",
    "get_miner",
    "list_miners",
    "mine",
    "register_miner",
]


def __getattr__(name: str):
    # the serving and streaming layers are imported on first touch: they
    # spin thread pools and cycle back through this package, neither of
    # which belongs in a bare ``import repro.mining``
    if name in ("MiningService", "GroupScheduler", "SnapshotStore"):
        import repro.mining.service as _service

        return getattr(_service, name)
    if name in ("StreamSpec", "StreamingMiner"):
        import repro.mining.stream as _stream

        return getattr(_stream, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
