"""Standing queries: registered once, answered after every mutation.

``StandingRegistry`` rides inside a ``StreamingMiner`` or
``DistributedMiner`` (duck-typed ``owner``: ``mine(spec, _seed=)``,
``stats`` dict, ``stream_spec``, ``rows_appended`` monotone counter).
After every append/expiry the owner calls ``refresh_all`` — under its
operation lock, so diffs observe exactly the arrival-order stream state —
and each registered query is re-mined incrementally and handed a
``MineDiff`` against its previously delivered answer.

Incrementality is two-fold. Prep is already incremental (segments are
append-time artifacts; a refresh never re-prepares anything). Planning
reuses the previous answer's *settled waves* as a seed: each refresh
records the exact reduced support of every candidate it examined —
frequent or not — and the registry keeps them as per-itemset upper
bounds, inflated by the rows appended since they were recorded (a new
row raises any support by at most 1; expiry only lowers it). On the
next refresh, a candidate whose bound misses the threshold is provably
infrequent and never dispatches — and anti-monotonicity kills its whole
subtree with it (``mine_prepared_segments(seed=...)``). The near-frontier
corpses of wave ``l`` are exactly the candidates a naive re-mine would
re-intersect every append; once examined, they stay pruned until enough
rows arrive to possibly revive them, at which point they are re-examined
and their bound refreshed. The bound only kills provably-infrequent
candidates, so every refresh stays bit-identical to an unseeded mine; it
applies only on the exact integer path (decayed streams re-mine
unseeded).

Pattern post-passes (closed/maximal/top_rank_k) ride ``MineSpec.patterns``
unchanged: the refresh mines with ``patterns="all"`` (the full answer is
what the next seed needs — filtered views are not anti-monotone), then
applies the post-pass to the *delivered* view the diffs are built over.

Replaying a query's diff stream from empty (``replay_diffs``)
reconstructs its latest delivered answer exactly — the invariant the
chaos soak and the property tests check.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future

from repro.fault import failures
from repro.mining.spec import MineSpec


@dataclasses.dataclass(frozen=True)
class MineDiff:
    """One incremental answer: what changed vs the previous delivery."""

    seq: int  # 0-based delivery number for this query
    cause: str  # "register" | "append" | "expire"
    entered: dict  # itemset -> support, newly frequent
    left: dict  # itemset -> last delivered support, no longer frequent
    changed: dict  # itemset -> (old_support, new_support), still frequent
    n_rows: int  # stream rows the answer covers
    min_count: object  # resolved threshold (int; float when decayed)
    total: int  # size of the delivered frequent set after this diff
    latency_s: float  # register/refresh wall time for this delivery


def apply_diff(acc: dict, diff: MineDiff) -> dict:
    """Fold one diff into an accumulated answer dict, in place."""
    for t in diff.left:
        acc.pop(t, None)
    acc.update(diff.entered)
    for t, (_, new) in diff.changed.items():
        acc[t] = new
    return acc


def replay_diffs(diffs) -> dict:
    """The answer a subscriber reconstructs from a diff stream alone."""
    acc: dict = {}
    for d in diffs:
        apply_diff(acc, d)
    return acc


class StandingQuery:
    """One registered continuous query. ``latest`` is the last delivered
    answer (post pattern-pass), ``diffs`` the full delivery history, and
    ``next_diff()`` a Future resolving with the next delivery — the
    ``MiningService`` hands these out so subscribers block on arrival
    order, not on polling."""

    def __init__(self, qid: int, spec: MineSpec):
        self.qid = qid
        self.spec = spec
        self.seq = 0
        self.latest: dict | None = None
        self.diffs: list[MineDiff] = []
        self.active = True
        # seed state: per-itemset support upper bounds from previously
        # settled waves, and the owner's rows_appended mark they are
        # current at (refreshes inflate them by the rows since)
        self._bound: dict | None = None
        self._rows_mark = 0
        self._waiters: list[Future] = []
        self._wlock = threading.Lock()

    def next_diff(self) -> Future:
        """A Future resolving with this query's next delivered diff."""
        f: Future = Future()
        with self._wlock:
            self._waiters.append(f)
        return f

    def _deliver(self, d: MineDiff) -> None:
        self.diffs.append(d)
        with self._wlock:
            waiters, self._waiters = self._waiters, []
        for f in waiters:
            if not f.cancelled():
                f.set_result(d)


class StandingRegistry:
    """The owner-embedded registry: register/cancel plus the per-mutation
    refresh fan-out. All methods run under the owner's operation lock."""

    def __init__(self, owner):
        self.owner = owner
        self.queries: dict[int, StandingQuery] = {}
        self._next = 0

    def __len__(self) -> int:
        return len(self.queries)

    def register(self, spec: MineSpec) -> StandingQuery:
        """Register a continuous query and deliver its initial answer
        (``cause="register"`` — ``entered`` is the whole frequent set, so
        a replay from empty starts correct). A spec the owner cannot
        serve raises here and registers nothing."""
        q = StandingQuery(self._next, spec)
        self._refresh(q, "register")  # raises before registration on bad spec
        self._next += 1
        self.queries[q.qid] = q
        self.owner.stats["standing_queries"] = len(self.queries)
        return q

    def cancel(self, q: StandingQuery) -> None:
        q.active = False
        self.queries.pop(q.qid, None)
        self.owner.stats["standing_queries"] = len(self.queries)

    def refresh_all(self, cause: str) -> int:
        """Re-answer every registered query after one mutation; returns
        how many diffs were delivered. A refresh failure (chaos, device)
        is accounted and skipped — the query's delivered state is
        untouched, so its diff chain stays consistent, and the next
        mutation's refresh catches it up."""
        delivered = 0
        for q in list(self.queries.values()):
            try:
                self._refresh(q, cause)
                delivered += 1
            except Exception:
                self.owner.stats["diff_errors"] += 1
        return delivered

    def _refresh(self, q: StandingQuery, cause: str) -> None:
        from repro.mining.miners import _select_patterns

        failures.fire("stream.diff")
        t0 = time.perf_counter()
        owner = self.owner
        spec_full = (
            q.spec if q.spec.patterns == "all" else q.spec.with_(patterns="all")
        )
        seed = None
        exact = owner.stream_spec.decay == 1.0
        if q._bound is not None and exact:
            added = owner.rows_appended - q._rows_mark
            # inflate every recorded bound by the rows appended since it
            # was settled — still a true upper bound (expiry only shrinks)
            seed = {t: s + added for t, s in q._bound.items()}
        seed_out: dict = {}
        res = owner.mine(spec_full, _seed=seed, _seed_out=seed_out if exact else None)
        if exact:
            # carry inflated bounds forward, overwritten wherever this
            # refresh settled an exact support again
            bound = seed if seed is not None else {}
            bound.update(seed_out)
            q._bound = bound
            q._rows_mark = owner.rows_appended
        delivered = (
            res.itemsets if q.spec.patterns == "all"
            else _select_patterns(res.itemsets, q.spec)
        )
        old = q.latest if q.latest is not None else {}
        entered = {t: s for t, s in delivered.items() if t not in old}
        left = {t: s for t, s in old.items() if t not in delivered}
        changed = {
            t: (old[t], s) for t, s in delivered.items()
            if t in old and old[t] != s
        }
        lat = time.perf_counter() - t0
        d = MineDiff(
            seq=q.seq, cause=cause, entered=entered, left=left, changed=changed,
            n_rows=res.n_rows, min_count=res.min_count, total=len(delivered),
            latency_s=lat,
        )
        q.seq += 1
        q.latest = dict(delivered)
        st = owner.stats
        st["diffs_delivered"] += 1
        st["diff_latency_s_total"] += lat
        st["last_diff_latency_s"] = lat
        # distribution view of the same latency (the totals above stay for
        # compatibility): per-stream refresh latency histogram
        engine = getattr(owner, "engine", None)
        if engine is not None:
            engine.telemetry.histogram(
                f"stream.{getattr(owner, 'name', 'default')}.diff_s"
            ).record(lat)
        st["seed_pruned_candidates"] += int(
            res.stage_times_s.get("host_pruned_seed", 0)
        )
        q._deliver(d)
