"""Time-decayed supports over a ``SegmentedDB``: the damped-window model.

Each append is one *tick*. A segment appended at tick ``t`` contributes
its (exact, integer, device-computed) per-itemset supports scaled by
``decay ** (now - t)`` — newest batch weight 1, history fading
geometrically. The damping happens **only in the host-side cross-segment
reduce** (``LocalSegmentExecutor.collect`` with ``weights``): the packed
N-lists, the wave kernels, and the per-segment supports stay on the
exact integer path, and the float64 accumulation + post-reduce float
threshold are the only inexact steps. Segments are per-batch (decay
disables compaction — a merged segment has no single age), so the model
is exactly the classic damped window over batches.

``damped_oracle`` is the reference: a pure-host weighted Apriori over
the raw batches, used by the parity tests.
"""
from __future__ import annotations

from itertools import combinations

import numpy as np

from repro.core import encoding as enc


def segment_weights(segments, tick_now: int, decay: float) -> np.ndarray:
    """Per-segment damping factors ``decay ** (tick_now - seg.tick)``."""
    return np.array(
        [float(decay) ** (int(tick_now) - int(s.tick)) for s in segments],
        np.float64,
    )


def weighted_state(db, weights: np.ndarray):
    """The decayed global aggregates of a ``SegmentedDB``: weighted item
    counts over the stream rank space, the weighted F2 matrix, and the
    weighted row total (what ``min_sup`` resolves against). Mirrors
    ``register_batch`` / ``add_segment`` with each segment's integer
    contribution scaled by its weight."""
    items = np.asarray(db.order, np.int32)
    K = len(items)
    wsups = np.zeros(K, np.float64)
    wC = np.zeros((K, K), np.float64)
    wrows = 0.0
    for w, s in zip(weights, db.segments):
        hist = enc.item_support(s.rows, db.n_items)
        wsups += w * hist[items]
        gr = db.rank_of[s.local_items]
        wC[np.ix_(gr, gr)] += w * np.asarray(s.prepared.C, np.float64)
        wrows += w * s.n_rows
    return items, wsups, wC, wrows


def resolve_weighted(spec, wrows: float) -> float:
    """The float threshold of a decayed query: an absolute ``min_count``
    is used as-is; ``min_sup`` resolves against the *weighted* row total
    (no ceil — weighted supports are not integers). Floored at a tiny
    positive epsilon so an empty/exhausted window reports nothing rather
    than everything."""
    if spec.min_count is not None:
        return float(spec.min_count)
    if spec.min_sup is None:
        raise ValueError("MineSpec needs min_sup or min_count to mine")
    return max(float(spec.min_sup) * float(wrows), 1e-9)


def _row_sets(rows: np.ndarray) -> list:
    return [
        frozenset(int(i) for i in r if i != enc.PAD)
        for r in np.asarray(rows)
    ]


def damped_oracle(batches, n_items: int, decay: float, min_weight: float,
                  max_k: int | None = None) -> dict:
    """Reference damped-window mine: weighted Apriori straight off the
    raw batches (batch ``b`` of ``T`` weighted ``decay ** (T-1-b)``).
    Returns ``{itemset: weighted_support}`` for every itemset whose
    weighted support reaches ``min_weight``."""
    T = len(batches)
    sets_w = [(_row_sets(b_rows), float(decay) ** (T - 1 - b))
              for b, b_rows in enumerate(batches)]

    def wsup(fx: frozenset) -> float:
        return sum(
            w * sum(1 for r in rs if fx <= r) for rs, w in sets_w
        )

    out: dict[tuple, float] = {}
    f1 = []
    for i in range(n_items):
        s = wsup(frozenset((i,)))
        if s >= min_weight:
            out[(i,)] = s
            f1.append(i)
    prev = {frozenset((i,)) for i in f1}
    k = 2
    while prev and (max_k is None or k <= max_k):
        cur = set()
        for combo in combinations(f1, k):
            fx = frozenset(combo)
            if any(fx - {i} not in prev for i in fx):
                continue
            s = wsup(fx)
            if s >= min_weight:
                out[tuple(sorted(combo))] = s
                cur.add(fx)
        prev = cur
        k += 1
    return out
