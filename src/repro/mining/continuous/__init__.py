"""repro.mining.continuous — continuous mining over a ``SegmentedDB``.

Three exact modes layered on ``repro.mining.stream``'s additive-support
segments, all driven by ``StreamSpec`` knobs and served by the same
``StreamingMiner`` / ``DistributedMiner`` / ``MiningService`` surfaces:

  - **sliding windows** (``window_rows`` / ``window_batches``): append
    time expires the oldest segments via ``SegmentedDB.drop_segments``,
    the exact inverse of append — a windowed mine is bit-identical to a
    one-shot mine over exactly the retained rows;
  - **time-decayed supports** (``decay < 1``): per-segment geometric
    weights in the cross-segment reduce (float64 accumulation next to
    the exact integer path, threshold post-reduce), checked against the
    ``damped_oracle`` reference;
  - **standing queries** (``register(spec) -> StandingQuery``): every
    append/expiry re-mines incrementally — previous answer as the
    pruning seed — and delivers a ``MineDiff`` whose cumulative replay
    reconstructs the exact frequent set.
"""
from repro.mining.continuous.decay import (
    damped_oracle, resolve_weighted, segment_weights, weighted_state,
)
from repro.mining.continuous.standing import (
    MineDiff, StandingQuery, StandingRegistry, apply_diff, replay_diffs,
)

__all__ = [
    "MineDiff", "StandingQuery", "StandingRegistry",
    "apply_diff", "replay_diffs",
    "damped_oracle", "resolve_weighted", "segment_weights", "weighted_state",
]
