"""MiningEngine: a resident mining session, modeled on serving/engine.py.

The serving engine binds a model + mesh once and answers request waves
from warm jitted programs; this is the same shape for mining traffic. The
engine binds a mesh once, lazily constructs one frontend per registered
algorithm, and routes every ``submit`` through the unified
``MineSpec -> MineResult`` surface. Because the hprepost frontend keys its
``HPrepostMiner`` instances (and so the compiled sharded programs) on the
device-level part of the spec, back-to-back submits — sweeps over
``min_sup``, repeated production queries, mixed-algorithm batches — hit
the jit cache instead of recompiling.

Shared-work planning: the paper's entire experimental surface is the
threshold sweep (every runtime/memory figure is "all min-sup" over one
database), and Job 1 / Job 2 / pack / F2 depend only on the *loosest*
threshold in the sweep. ``sweep`` and ``submit_many`` therefore group
hprepost requests by (database fingerprint, device config), build one
``PreparedDB`` at the group's loosest threshold, and serve every threshold
from it through ``mine_prepared`` — prep runs once per group, not once per
request. Host miners keep the one-shot path.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Iterable, Sequence

import numpy as np

from repro.mining.registry import Miner, get_miner
from repro.mining.result import MineResult
from repro.mining.spec import MineSpec


@dataclasses.dataclass
class MineRequest:
    """One unit of mining traffic: a database plus its spec."""

    rows: object  # (R, L) padded transaction matrix
    n_items: int
    spec: MineSpec


class MiningEngine:
    """Session front-door over the miner registry.

    ``mesh=None`` binds the default 1×1 host mesh; production callers pass
    ``repro.launch.mesh.make_production_mesh()`` (or any mesh) and every
    mesh-bound miner in the session shares it.
    """

    def __init__(self, mesh=None, data_axis=None, model_axis="model"):
        self.mesh = mesh
        self.data_axis = data_axis
        self.model_axis = model_axis
        self._frontends: dict[str, Miner] = {}
        self.stats = {
            "submits": 0,  # requests answered (planned or not)
            "frontends_built": 0,
            "prepares": 0,  # shared PreparedDB builds (one per planned group)
            "prepared_mines": 0,  # requests served from a shared PreparedDB
        }

    def frontend(self, algorithm: str) -> Miner:
        """The session's (lazily built, then resident) miner for ``algorithm``."""
        fe = self._frontends.get(algorithm)
        if fe is None:
            fe = get_miner(
                algorithm, mesh=self.mesh, data_axis=self.data_axis, model_axis=self.model_axis
            )
            self._frontends[algorithm] = fe
            self.stats["frontends_built"] += 1
        return fe

    @property
    def miners_built(self) -> int:
        """Device-level miners compiled so far (jit-cache warmth metric)."""
        return sum(getattr(fe, "miners_built", 0) for fe in self._frontends.values())

    def submit(self, rows, n_items: int, spec: MineSpec) -> MineResult:
        """Mine one database through the session's warm frontends."""
        self.stats["submits"] += 1
        return self.frontend(spec.algorithm).mine(rows, n_items, spec)

    # ------------------------------------------------------ planned batches
    @staticmethod
    def _fingerprint(rows) -> tuple:
        """Content identity of a database (planning must never share prep
        across different data, whatever object carries it)."""
        arr = np.ascontiguousarray(np.asarray(rows))
        digest = hashlib.sha1(arr.tobytes()).hexdigest()
        return (arr.shape, str(arr.dtype), digest)

    def _plan_key(self, req: MineRequest, fp_cache: dict):
        """Group key for shared-prep planning, or None for the one-shot path.

        Only the distributed hprepost backend has a prepare/mine split; a
        group must agree on the database and on every device-level knob
        (the per-call threshold / max_k / patterns are free to differ)."""
        if req.spec.algorithm != "hprepost":
            return None
        fe = self.frontend("hprepost")
        fp = fp_cache.get(id(req.rows))
        if fp is None:
            fp = fp_cache[id(req.rows)] = self._fingerprint(req.rows)
        return (req.spec.algorithm, fp, req.n_items, fe._device_config(req.spec))

    def _run_group(self, reqs: list[MineRequest]) -> list[MineResult]:
        """Serve one planned group: prep once at the loosest threshold, then
        the k>2 waves per request. The first request pays (and reports) the
        shared prep; the rest carry 0.0 prep stages and ``prep_shared``."""
        fe = self.frontend("hprepost")
        rows = np.asarray(reqs[0].rows)
        n_rows = len(rows)
        floor = min(r.spec.resolve(n_rows) for r in reqs)
        need_waves = any(r.spec.max_k is None or r.spec.max_k > 1 for r in reqs)
        t0 = time.perf_counter()
        try:
            miner, prepared = fe.prepare(
                rows, reqs[0].n_items, floor, reqs[0].spec, need_waves=need_waves
            )
        except ValueError:
            # the floor F-list can trip guards (max_f1) that tighter
            # thresholds in the group would individually pass; don't fail
            # the whole batch — degrade to the one-shot path per request,
            # where any real per-request error surfaces precisely
            return [self.submit(r.rows, r.n_items, r.spec) for r in reqs]
        self.stats["prepares"] += 1
        out = []
        for j, r in enumerate(reqs):
            self.stats["submits"] += 1
            self.stats["prepared_mines"] += 1
            out.append(
                fe.mine_prepared(
                    miner, prepared, r.spec,
                    prep_stages=prepared.stage_times if j == 0 else None,
                    prep_shared=j > 0,
                    t0=t0 if j == 0 else None,
                )
            )
        return out

    def submit_many(self, requests: Iterable[MineRequest]) -> list[MineResult]:
        """Serve a batch of requests; results align with the input order.

        Requests that share (database, device config) on the hprepost
        backend are planned together — one PreparedDB at the group's
        loosest threshold serves all of them. Everything else (host
        algorithms, singleton groups) takes the one-shot path; frontends
        stay warm across the whole batch either way."""
        requests = list(requests)
        results: list[MineResult | None] = [None] * len(requests)
        groups: dict[tuple, list[int]] = {}
        fp_cache: dict[int, tuple] = {}
        loners: list[int] = []
        for i, r in enumerate(requests):
            key = self._plan_key(r, fp_cache)
            if key is None:
                loners.append(i)
            else:
                groups.setdefault(key, []).append(i)
        for idxs in groups.values():
            if len(idxs) == 1:
                loners.append(idxs[0])
                continue
            for i, res in zip(idxs, self._run_group([requests[i] for i in idxs])):
                results[i] = res
        for i in sorted(loners):
            r = requests[i]
            results[i] = self.submit(r.rows, r.n_items, r.spec)
        return results

    def sweep(self, rows, n_items: int, spec: MineSpec,
              min_sups: Sequence[float]) -> list[MineResult]:
        """Threshold sweep (the paper's x-axis) on one warm miner.

        For hprepost the sweep is planned: Job 1 / Job 2 / pack / F2 run
        once at the loosest threshold and every ``min_sup`` is served from
        the shared PreparedDB — results are itemset-identical to
        independent ``submit`` calls per threshold."""
        return self.submit_many(
            [MineRequest(rows, n_items, spec.with_(min_sup=s)) for s in min_sups]
        )
