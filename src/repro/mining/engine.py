"""MiningEngine: a resident mining session, modeled on serving/engine.py.

The serving engine binds a model + mesh once and answers request waves
from warm jitted programs; this is the same shape for mining traffic. The
engine binds a mesh once, lazily constructs one frontend per registered
algorithm, and routes every ``submit`` through the unified
``MineSpec -> MineResult`` surface. Because the hprepost frontend keys its
``HPrepostMiner`` instances (and so the compiled sharded programs) on the
device-level part of the spec, back-to-back submits — sweeps over
``min_sup``, repeated production queries, mixed-algorithm batches — hit
the jit cache instead of recompiling.

Shared-work planning: the paper's entire experimental surface is the
threshold sweep (every runtime/memory figure is "all min-sup" over one
database), and Job 1 / Job 2 / pack / F2 depend only on the *loosest*
threshold in the sweep. ``sweep`` and ``submit_many`` therefore group
hprepost requests by (database fingerprint, device config), build one
``PreparedDB`` at the group's loosest threshold, and serve every threshold
from it through ``mine_prepared`` — prep runs once per group, not once per
request. Host miners keep the one-shot path.

Persistent PreparedDB cache: planning used to live per-``sweep``/
``submit_many`` invocation, so repeated *ad-hoc* ``submit`` s on the same
database still re-ran every prep stage. The engine now keeps an LRU of
device-resident ``PreparedDB`` s keyed exactly like planned groups —
(database fingerprint, n_items, device config) — under a configurable
byte budget (``prep_cache_bytes``, accounted with ``PreparedDB.
prep_bytes``). A cached entry serves any request whose resolved threshold
is at least the entry's floor; looser thresholds (or a k>1 request
hitting an F1-only entry) rebuild at the new floor and replace it.
``cache_info()`` surfaces hit/miss/eviction counters.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import time
from typing import Iterable, Sequence

import numpy as np

from repro.mining.registry import Miner, get_miner
from repro.mining.result import MineResult
from repro.mining.spec import MineSpec


@dataclasses.dataclass
class MineRequest:
    """One unit of mining traffic: a database plus its spec."""

    rows: object  # (R, L) padded transaction matrix
    n_items: int
    spec: MineSpec


class MiningEngine:
    """Session front-door over the miner registry.

    ``mesh=None`` binds the default 1×1 host mesh; production callers pass
    ``repro.launch.mesh.make_production_mesh()`` (or any mesh) and every
    mesh-bound miner in the session shares it.
    """

    def __init__(self, mesh=None, data_axis=None, model_axis="model",
                 prep_cache_bytes: int = 1 << 30):
        self.mesh = mesh
        self.data_axis = data_axis
        self.model_axis = model_axis
        self._frontends: dict[str, Miner] = {}
        self.stats = {
            "submits": 0,  # requests answered (planned or not)
            "frontends_built": 0,
            # shared PreparedDB builds made for a *planned group*; ad-hoc
            # submit builds are visible as cache_info()["misses"] instead
            "prepares": 0,
            "prepared_mines": 0,  # requests served from a shared PreparedDB
        }
        # persistent PreparedDB cache: (fingerprint, n_items, device config)
        # -> (miner, PreparedDB), LRU under a per-shard byte budget;
        # prep_cache_bytes <= 0 disables caching entirely
        self.prep_cache_bytes = int(prep_cache_bytes)
        self._prep_cache: collections.OrderedDict = collections.OrderedDict()
        self._cache_stats = {"hits": 0, "misses": 0, "evictions": 0}

    def frontend(self, algorithm: str) -> Miner:
        """The session's (lazily built, then resident) miner for ``algorithm``."""
        fe = self._frontends.get(algorithm)
        if fe is None:
            fe = get_miner(
                algorithm, mesh=self.mesh, data_axis=self.data_axis, model_axis=self.model_axis
            )
            self._frontends[algorithm] = fe
            self.stats["frontends_built"] += 1
        return fe

    @property
    def miners_built(self) -> int:
        """Device-level miners compiled so far (jit-cache warmth metric)."""
        return sum(getattr(fe, "miners_built", 0) for fe in self._frontends.values())

    def submit(self, rows, n_items: int, spec: MineSpec) -> MineResult:
        """Mine one database through the session's warm frontends.

        hprepost requests route through the persistent PreparedDB cache:
        back-to-back submits on the same database re-run zero prep stages
        (the second answer carries ``prep_shared`` and 0.0 prep times)."""
        self.stats["submits"] += 1
        if spec.algorithm == "hprepost" and self.prep_cache_bytes > 0:
            return self._submit_cached(rows, n_items, spec)
        return self.frontend(spec.algorithm).mine(rows, n_items, spec)

    # ------------------------------------------------ PreparedDB LRU cache
    def cache_info(self) -> dict:
        """Counters + occupancy of the persistent PreparedDB cache."""
        return {
            **self._cache_stats,
            "entries": len(self._prep_cache),
            "bytes_in_use": sum(
                p.prep_bytes for _, p in self._prep_cache.values()
            ),
            "byte_budget": self.prep_cache_bytes,
        }

    def _cache_key(self, rows, n_items: int, spec: MineSpec,
                   fp_cache: dict | None = None) -> tuple:
        fe = self.frontend("hprepost")
        fp = None if fp_cache is None else fp_cache.get(id(rows))
        if fp is None:
            fp = self._fingerprint(rows)
            if fp_cache is not None:
                fp_cache[id(rows)] = fp
        return (spec.algorithm, fp, n_items, fe._device_config(spec))

    def _cache_lookup(self, key, min_count: int, need_waves: bool):
        """``(miner, prepared)`` if the cached entry can serve, else None.

        A floor-``f`` entry serves any ``min_count >= f`` exactly (see
        ``PreparedDB``); a looser request — or a k>1 request against an
        F1-only entry — cannot be served and must rebuild."""
        ent = self._prep_cache.get(key)
        if ent is None:
            self._cache_stats["misses"] += 1
            return None
        _, prepared = ent
        if min_count < prepared.min_count_floor or (need_waves and prepared.f1_only):
            self._cache_stats["misses"] += 1
            return None
        self._prep_cache.move_to_end(key)
        self._cache_stats["hits"] += 1
        return ent

    def _cache_insert(self, key, miner, prepared) -> None:
        """Insert (replacing any stale entry), then evict least-recently-
        used entries until the byte budget holds — possibly including the
        new entry itself when it alone exceeds the budget.

        Exception: a cheap F1-only build never replaces a full
        (waves-capable) entry at the same key — the wave state (Job 2 /
        pack / F2) is the expensive part, it keeps serving future k>1
        traffic, and F1-only prep costs one histogram to redo."""
        if self.prep_cache_bytes <= 0:
            return
        old = self._prep_cache.get(key)
        if old is not None and prepared.f1_only and not old[1].f1_only:
            return
        self._prep_cache.pop(key, None)
        self._prep_cache[key] = (miner, prepared)
        in_use = sum(p.prep_bytes for _, p in self._prep_cache.values())
        while in_use > self.prep_cache_bytes and self._prep_cache:
            _, (_, dropped) = self._prep_cache.popitem(last=False)
            in_use -= dropped.prep_bytes
            self._cache_stats["evictions"] += 1

    def _submit_cached(self, rows, n_items: int, spec: MineSpec) -> MineResult:
        fe = self.frontend("hprepost")
        rows = np.asarray(rows)
        key = self._cache_key(rows, n_items, spec)
        min_count = spec.resolve(len(rows))
        need_waves = spec.max_k is None or spec.max_k > 1
        ent = self._cache_lookup(key, min_count, need_waves)
        if ent is not None:
            self.stats["prepared_mines"] += 1
            miner, prepared = ent
            return fe.mine_prepared(miner, prepared, spec, prep_shared=True)
        t0 = time.perf_counter()
        miner, prepared = fe.prepare(rows, n_items, min_count, spec,
                                     need_waves=need_waves)
        self._cache_insert(key, miner, prepared)
        return fe.mine_prepared(
            miner, prepared, spec, prep_stages=prepared.stage_times, t0=t0
        )

    # ------------------------------------------------------ planned batches
    @staticmethod
    def _fingerprint(rows) -> tuple:
        """Content identity of a database (planning must never share prep
        across different data, whatever object carries it)."""
        arr = np.ascontiguousarray(np.asarray(rows))
        digest = hashlib.sha1(arr.tobytes()).hexdigest()
        return (arr.shape, str(arr.dtype), digest)

    def _plan_key(self, req: MineRequest, fp_cache: dict):
        """Group key for shared-prep planning, or None for the one-shot path.

        Only the distributed hprepost backend has a prepare/mine split; a
        group must agree on the database and on every device-level knob
        (the per-call threshold / max_k / patterns are free to differ). The
        key doubles as the persistent PreparedDB cache key."""
        if req.spec.algorithm != "hprepost":
            return None
        return self._cache_key(req.rows, req.n_items, req.spec, fp_cache)

    def _run_group(self, reqs: list[MineRequest], key: tuple) -> list[MineResult]:
        """Serve one planned group: prep once at the loosest threshold, then
        the k>2 waves per request. The first request pays (and reports) the
        shared prep; the rest carry 0.0 prep stages and ``prep_shared``. A
        persistent-cache hit at the group floor skips prep entirely (every
        request is then a shared consumer)."""
        fe = self.frontend("hprepost")
        rows = np.asarray(reqs[0].rows)
        n_rows = len(rows)
        floor = min(r.spec.resolve(n_rows) for r in reqs)
        need_waves = any(r.spec.max_k is None or r.spec.max_k > 1 for r in reqs)
        ent = (
            self._cache_lookup(key, floor, need_waves)
            if self.prep_cache_bytes > 0 else None
        )
        if ent is not None:
            miner, prepared = ent
            out = []
            for r in reqs:
                self.stats["submits"] += 1
                self.stats["prepared_mines"] += 1
                out.append(
                    fe.mine_prepared(miner, prepared, r.spec, prep_shared=True)
                )
            return out
        t0 = time.perf_counter()
        try:
            miner, prepared = fe.prepare(
                rows, reqs[0].n_items, floor, reqs[0].spec, need_waves=need_waves
            )
        except ValueError:
            # the floor F-list can trip guards (max_f1) that tighter
            # thresholds in the group would individually pass; don't fail
            # the whole batch — degrade to the one-shot path per request,
            # where any real per-request error surfaces precisely
            return [self.submit(r.rows, r.n_items, r.spec) for r in reqs]
        self.stats["prepares"] += 1
        self._cache_insert(key, miner, prepared)
        out = []
        for j, r in enumerate(reqs):
            self.stats["submits"] += 1
            self.stats["prepared_mines"] += 1
            out.append(
                fe.mine_prepared(
                    miner, prepared, r.spec,
                    prep_stages=prepared.stage_times if j == 0 else None,
                    prep_shared=j > 0,
                    t0=t0 if j == 0 else None,
                )
            )
        return out

    def submit_many(self, requests: Iterable[MineRequest]) -> list[MineResult]:
        """Serve a batch of requests; results align with the input order.

        Requests that share (database, device config) on the hprepost
        backend are planned together — one PreparedDB at the group's
        loosest threshold serves all of them. Everything else (host
        algorithms, singleton groups) takes the one-shot path; frontends
        stay warm across the whole batch either way."""
        requests = list(requests)
        results: list[MineResult | None] = [None] * len(requests)
        groups: dict[tuple, list[int]] = {}
        fp_cache: dict[int, tuple] = {}
        loners: list[int] = []
        for i, r in enumerate(requests):
            key = self._plan_key(r, fp_cache)
            if key is None:
                loners.append(i)
            else:
                groups.setdefault(key, []).append(i)
        for key, idxs in groups.items():
            if len(idxs) == 1:
                loners.append(idxs[0])
                continue
            for i, res in zip(idxs, self._run_group([requests[i] for i in idxs], key)):
                results[i] = res
        for i in sorted(loners):
            r = requests[i]
            results[i] = self.submit(r.rows, r.n_items, r.spec)
        return results

    def sweep(self, rows, n_items: int, spec: MineSpec,
              min_sups: Sequence[float]) -> list[MineResult]:
        """Threshold sweep (the paper's x-axis) on one warm miner.

        For hprepost the sweep is planned: Job 1 / Job 2 / pack / F2 run
        once at the loosest threshold and every ``min_sup`` is served from
        the shared PreparedDB — results are itemset-identical to
        independent ``submit`` calls per threshold."""
        return self.submit_many(
            [MineRequest(rows, n_items, spec.with_(min_sup=s)) for s in min_sups]
        )
