"""MiningEngine: a resident mining session, modeled on serving/engine.py.

The serving engine binds a model + mesh once and answers request waves
from warm jitted programs; this is the same shape for mining traffic. The
engine binds a mesh once, lazily constructs one frontend per registered
algorithm, and routes every ``submit`` through the unified
``MineSpec -> MineResult`` surface. Because the hprepost frontend keys its
``HPrepostMiner`` instances (and so the compiled sharded programs) on the
device-level part of the spec, back-to-back submits — sweeps over
``min_sup``, repeated production queries, mixed-algorithm batches — hit
the jit cache instead of recompiling.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

from repro.mining.registry import Miner, get_miner
from repro.mining.result import MineResult
from repro.mining.spec import MineSpec


@dataclasses.dataclass
class MineRequest:
    """One unit of mining traffic: a database plus its spec."""

    rows: object  # (R, L) padded transaction matrix
    n_items: int
    spec: MineSpec


class MiningEngine:
    """Session front-door over the miner registry.

    ``mesh=None`` binds the default 1×1 host mesh; production callers pass
    ``repro.launch.mesh.make_production_mesh()`` (or any mesh) and every
    mesh-bound miner in the session shares it.
    """

    def __init__(self, mesh=None, data_axis=None, model_axis="model"):
        self.mesh = mesh
        self.data_axis = data_axis
        self.model_axis = model_axis
        self._frontends: dict[str, Miner] = {}
        self.stats = {"submits": 0, "frontends_built": 0}

    def frontend(self, algorithm: str) -> Miner:
        """The session's (lazily built, then resident) miner for ``algorithm``."""
        fe = self._frontends.get(algorithm)
        if fe is None:
            fe = get_miner(
                algorithm, mesh=self.mesh, data_axis=self.data_axis, model_axis=self.model_axis
            )
            self._frontends[algorithm] = fe
            self.stats["frontends_built"] += 1
        return fe

    @property
    def miners_built(self) -> int:
        """Device-level miners compiled so far (jit-cache warmth metric)."""
        return sum(getattr(fe, "miners_built", 0) for fe in self._frontends.values())

    def submit(self, rows, n_items: int, spec: MineSpec) -> MineResult:
        """Mine one database through the session's warm frontends."""
        self.stats["submits"] += 1
        return self.frontend(spec.algorithm).mine(rows, n_items, spec)

    def submit_many(self, requests: Iterable[MineRequest]) -> list[MineResult]:
        """Serve a batch of requests; frontends stay warm across the batch."""
        return [self.submit(r.rows, r.n_items, r.spec) for r in requests]

    def sweep(self, rows, n_items: int, spec: MineSpec,
              min_sups: Sequence[float]) -> list[MineResult]:
        """Threshold sweep (the paper's x-axis) on one warm miner."""
        return [
            self.submit(rows, n_items, spec.with_(min_sup=s)) for s in min_sups
        ]
