"""MiningEngine: a resident mining session, modeled on serving/engine.py.

The serving engine binds a model + mesh once and answers request waves
from warm jitted programs; this is the same shape for mining traffic. The
engine binds a mesh once, lazily constructs one frontend per registered
algorithm, and routes every ``submit`` through the unified
``MineSpec -> MineResult`` surface. Because the hprepost frontend keys its
``HPrepostMiner`` instances (and so the compiled sharded programs) on the
device-level part of the spec, back-to-back submits — sweeps over
``min_sup``, repeated production queries, mixed-algorithm batches — hit
the jit cache instead of recompiling.

Shared-work planning: the paper's entire experimental surface is the
threshold sweep (every runtime/memory figure is "all min-sup" over one
database), and Job 1 / Job 2 / pack / F2 depend only on the *loosest*
threshold in the sweep. ``sweep`` and ``submit_many`` therefore group
hprepost requests by (database fingerprint, device config), build one
``PreparedDB`` at the group's loosest threshold, and serve every threshold
from it through ``mine_prepared`` — prep runs once per group, not once per
request. Host miners keep the one-shot path.

Persistent PreparedDB cache: the engine keeps an LRU of device-resident
``PreparedDB`` s keyed exactly like planned groups — (database
fingerprint, n_items, prep-level config; execution-only knobs like kernel
blocks, backend, and early-stop are normalized away) — under a
configurable byte budget
(``prep_cache_bytes``, accounted with ``PreparedDB.prep_bytes``). A cached
entry serves any request whose resolved threshold is at least the entry's
floor; looser thresholds (or a k>1 request hitting an F1-only entry)
rebuild at the new floor and replace it. ``cache_info()`` surfaces
hit/miss/eviction counters.

Cross-process persistence (the snapshot store): with ``snapshot_dir`` (or
an explicit ``snapshot_store``) bound, every PreparedDB the engine builds
is spilled — atomically, content-addressed — to disk, and every LRU miss
consults the store before re-running prep. A cold process pointed at a
populated store therefore warm-starts with **zero** prep stages on a known
database: ``stats["prepares"]`` stays 0 and results carry
``service_stats["prep_source"] == "snapshot"``. The store requires the
LRU to be enabled (``prep_cache_bytes > 0``) — a loaded snapshot lands in
the LRU like any other entry.

Streaming ingestion (``repro.mining.stream``): ``append`` folds a new
transaction batch into a named live ``SegmentedDB`` as its own prepared
segment (the paper's map step, run on the new partition only) and
``submit_stream`` mines the segmented database via summed per-segment
counts + cross-segment waves (the reduce) — no full rebuild when data
arrives, and per-segment snapshots warm-start a replayed stream.

The engine is thread-safe (one coarse lock over planning state): the
service layer (``repro.mining.service``) overlaps group g+1's prepare
with group g's wave drain and runs host algorithms on worker threads, all
against one engine.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import threading
import time
import weakref
from typing import Iterable, Sequence

import numpy as np

from repro.fault import failures
from repro.mining.registry import Miner, get_miner
from repro.mining.result import MineResult
from repro.mining.spec import MineSpec
from repro.mining.service.store import SnapshotStore
from repro.mining.telemetry import Registry

# per-stage latency histograms are recorded for these stage_times_s keys
# (only when > 0 — a prep_shared consumer's zeroed prep stages are not
# observations, they are accounting)
_STAGE_KEYS = ("job1_flist", "job2_ppc_pack", "f2_scan", "mining_waves")


@dataclasses.dataclass
class MineRequest:
    """One unit of mining traffic: a database plus its spec.

    ``deadline_at`` is an absolute ``time.monotonic()`` instant stamped by
    the service from ``spec.deadline_s`` at admission; the scheduler drops
    (``DeadlineExceeded``) requests whose deadline passes before their
    device work starts. None = no deadline."""

    rows: object  # (R, L) padded transaction matrix
    n_items: int
    spec: MineSpec
    deadline_at: float | None = None
    # root span id stamped by the service when a tracer is attached, so
    # scheduler/engine spans parent into the request's tree. Like QoS
    # fields, never part of any plan/prep/snapshot key.
    trace_id: int | None = None


class MiningEngine:
    """Session front-door over the miner registry.

    ``mesh=None`` binds the default 1×1 host mesh; production callers pass
    ``repro.launch.mesh.make_production_mesh()`` (or any mesh) and every
    mesh-bound miner in the session shares it.
    """

    def __init__(self, mesh=None, data_axis=None, model_axis="model",
                 prep_cache_bytes: int = 1 << 30,
                 snapshot_dir: str | None = None,
                 snapshot_store: SnapshotStore | None = None,
                 snapshot_bytes: int = 4 << 30):
        self.mesh = mesh
        self.data_axis = data_axis
        self.model_axis = model_axis
        self._frontends: dict[str, Miner] = {}
        self.stats = {
            "submits": 0,  # requests answered (planned or not)
            "frontends_built": 0,
            # shared PreparedDB builds made for a *planned group*; ad-hoc
            # submit builds are visible as cache_info()["misses"] instead
            "prepares": 0,
            "prepared_mines": 0,  # requests served from a shared PreparedDB
        }
        # persistent PreparedDB cache: (fingerprint, n_items, prep config)
        # -> (miner, PreparedDB), LRU under a per-shard byte budget;
        # prep_cache_bytes <= 0 disables caching entirely
        self.prep_cache_bytes = int(prep_cache_bytes)
        self._prep_cache: collections.OrderedDict = collections.OrderedDict()
        self._cache_stats = {
            "hits": 0, "misses": 0, "evictions": 0,
            "snapshot_hits": 0, "snapshot_misses": 0,
            "snapshot_spill_failures": 0,
        }
        if snapshot_store is None and snapshot_dir is not None:
            snapshot_store = SnapshotStore(snapshot_dir, byte_budget=snapshot_bytes)
        self.snapshot_store = snapshot_store
        # one kernel-plan autotuner per engine, persisted next to the
        # snapshot store (kernel_plans.json) so a warm process reruns its
        # best block configs with zero search trials; attached to every
        # hprepost frontend the engine builds. Plans only resolve through
        # it when a spec opts in (``tune=True``).
        plan_dir = snapshot_dir
        if plan_dir is None and snapshot_store is not None:
            plan_dir = getattr(snapshot_store, "dir", None)
        from repro.mining.tune import KernelTuner

        self.tuner = KernelTuner(plan_dir=plan_dir)
        # engine-lifetime fingerprint memo: id(array) -> (weakref, fp,
        # frozen, sample); compacted (dead weakrefs dropped) when it
        # reaches _fp_sweep_at, which doubles past the live count so
        # sweeps stay amortized O(1). ``frozen`` records that the memo
        # itself made the array read-only (see _fingerprint) and must
        # restore writeability on invalidation; ``sample`` is the
        # stride-sampled digest re-checked on every hit (catches
        # mutation through pre-existing writeable views).
        self._fp_memo: dict[int, tuple[weakref.ref, tuple, bool, str]] = {}
        self._fp_sweep_at = 1024
        # live streaming databases (repro.mining.stream), by name; each
        # StreamingMiner serializes its own appends/queries internally
        self._streams: dict[str, object] = {}
        # the session's latency/counter registry (repro.mining.telemetry)
        # — shared by every layer stacked on this engine (admission queue,
        # scheduler, streams, distributed coordinators), surfaced through
        # ``MiningService.stats()["histograms"]`` and the periodic
        # emitter. Execution-orthogonal: never part of any prep/device/
        # snapshot key.
        self.telemetry = Registry()
        # one coarse re-entrant lock over planning state (frontends, LRU,
        # fingerprint memo, counters); device/host mining itself runs
        # outside it, so threads overlap on the expensive parts only
        self._lock = threading.RLock()

    def frontend(self, algorithm: str) -> Miner:
        """The session's (lazily built, then resident) miner for ``algorithm``."""
        with self._lock:
            fe = self._frontends.get(algorithm)
            if fe is None:
                fe = get_miner(
                    algorithm, mesh=self.mesh, data_axis=self.data_axis, model_axis=self.model_axis
                )
                if hasattr(fe, "tuner"):
                    fe.tuner = self.tuner
                self._frontends[algorithm] = fe
                self.stats["frontends_built"] += 1
            return fe

    @property
    def miners_built(self) -> int:
        """Device-level miners compiled so far (jit-cache warmth metric)."""
        return sum(getattr(fe, "miners_built", 0) for fe in self._frontends.values())

    def submit(self, rows, n_items: int, spec: MineSpec) -> MineResult:
        """Mine one database through the session's warm frontends.

        hprepost requests route through the persistent PreparedDB cache
        (and, when bound, the snapshot store): back-to-back submits on the
        same database re-run zero prep stages (the second answer carries
        ``prep_shared`` and 0.0 prep times)."""
        with self._lock:
            self.stats["submits"] += 1
        if spec.algorithm == "hprepost" and self.prep_cache_bytes > 0:
            return self._submit_cached(rows, n_items, spec)
        res = self.frontend(spec.algorithm).mine(rows, n_items, spec)
        self._observe_result(res)
        return res

    def _observe_result(self, res: MineResult) -> None:
        """Record one answered request into the latency registry. Totals
        stay in ``stats``/``cache_info``; these are the distributions."""
        t = self.telemetry
        t.histogram("engine.mine_s").record(res.wall_time_s)
        for k in _STAGE_KEYS:
            v = res.stage_times_s.get(k, 0.0)
            if v > 0.0:
                t.histogram(f"engine.stage.{k}_s").record(v)

    # --------------------------------------------------------- fingerprints
    @staticmethod
    def _digest(arr: np.ndarray) -> tuple:
        """Content identity of a database (planning must never share prep
        across different data, whatever object carries it)."""
        arr = np.ascontiguousarray(arr)
        digest = hashlib.sha1(arr.tobytes()).hexdigest()
        return (arr.shape, str(arr.dtype), digest)

    @staticmethod
    def _sample_digest(arr: np.ndarray) -> str:
        """Stride-sampled content digest — the cheap guard re-checked on
        every memo hit. Hashes at most ~64KiB of the array's bytes (every
        byte for arrays at or under that size, so the guard is exact
        there), keeping hit-path cost O(1)-ish while making a mutation
        that slips past it require every changed byte to fall between
        sample strides. Requires a C-contiguous array; the memo only
        admits those."""
        buf = arr.view(np.uint8).reshape(-1)
        step = max(1, buf.size // 65536)
        return hashlib.sha1(buf[::step].tobytes()).hexdigest()

    def _fingerprint(self, rows) -> tuple:
        """``_digest`` memoized per array object for the engine's lifetime,
        so hot-path submits on a resident database skip the O(R·L) hash.

        The memo key is object identity guarded by a weakref: a collected
        array (whose id may be recycled by a new allocation) can never
        return a stale fingerprint, because the dead/reseated weakref fails
        the identity check and the digest is recomputed.

        In-place mutation cannot slip a stale fingerprint through either:
        an array is only memoized while it is READ-ONLY. A writeable
        owning array is frozen (``setflags(write=False)``) on first
        memoization — direct mutation then raises at the caller's site,
        and the sanctioned mutation routes (``setflags(write=True)``, or
        ``invalidate_fingerprints`` which also restores writeability) both
        auto-invalidate: a memo entry whose array has become writeable
        again fails the hit check and is re-hashed. Views (``arr.base`` is
        not None) are never memoized — their content can change through
        the base without this array's flags moving.

        The one route the flags cannot police — a WRITEABLE VIEW taken
        *before* the submit keeps its own writeable flag (NumPy does not
        propagate ``setflags`` to existing views), so writing through it
        mutates the frozen base without tripping anything — is guarded by
        a stride-sampled digest (``_sample_digest``) re-verified on every
        hit: a mismatch drops the entry and re-hashes in full. The guard
        is exact for arrays <= 64KiB and probabilistic above (a mutation
        confined entirely to unsampled bytes passes); callers wanting a
        hard guarantee still use the sanctioned routes above."""
        arr = np.asarray(rows)
        with self._lock:
            memo = self._fp_memo.get(id(arr))
        was_frozen = False
        if memo is not None and memo[0]() is arr:
            if not arr.flags.writeable:
                if self._sample_digest(arr) == memo[3]:
                    return memo[1]
                # mutated through a pre-existing writeable view: the
                # entry is stale even though the flags never moved.
                # Remember that the memo froze this array so the fresh
                # entry still thaws it on invalidation.
                was_frozen = memo[2]
            # else: caller unfroze to mutate — auto-invalidate
            with self._lock:
                self._fp_memo.pop(id(arr), None)
        fp = self._digest(arr)
        if arr.base is not None:
            return fp  # view: base mutation is invisible here — no memo
        if not arr.flags.c_contiguous:
            return fp  # sample guard needs a flat byte view — no memo
        try:
            ref = weakref.ref(arr)
        except TypeError:
            return fp  # not weakref-able: correctness first, no memo
        frozen = was_frozen
        if arr.flags.writeable:
            try:
                arr.setflags(write=False)
                frozen = True
            except ValueError:
                return fp  # cannot freeze: mutation undetectable — no memo
        sample = self._sample_digest(arr)
        with self._lock:
            if len(self._fp_memo) >= self._fp_sweep_at:  # drop dead entries
                self._fp_memo = {
                    k: v for k, v in self._fp_memo.items() if v[0]() is not None
                }
                # all-live memos (many resident DBs) must not re-sweep on
                # every insert: back off to double the surviving size
                self._fp_sweep_at = max(1024, 2 * len(self._fp_memo))
            self._fp_memo[id(arr)] = (ref, fp, frozen, sample)
        return fp

    def invalidate_fingerprints(self, rows=None) -> None:
        """Forget memoized fingerprints — all of them, or just ``rows`` —
        restoring writeability on arrays the memo froze.

        The convenience route for callers that want to mutate a submitted
        array in place (the raw route is ``rows.setflags(write=True)``,
        which the memo also treats as invalidation). Note this drops the
        *fingerprint* memo only; cached PreparedDB entries are keyed by
        content and stay valid."""
        def _thaw(entry):
            arr = entry[0]()
            if entry[2] and arr is not None:
                try:
                    arr.setflags(write=True)
                except ValueError:
                    pass
        with self._lock:
            if rows is None:
                for entry in self._fp_memo.values():
                    _thaw(entry)
                self._fp_memo.clear()
            else:
                entry = self._fp_memo.pop(id(np.asarray(rows)), None)
                if entry is not None:
                    _thaw(entry)

    # ------------------------------------------------ PreparedDB LRU cache
    def cache_info(self) -> dict:
        """Counters + occupancy of the persistent PreparedDB cache (and the
        snapshot store, when one is bound)."""
        with self._lock:
            info = {
                **self._cache_stats,
                "entries": len(self._prep_cache),
                "bytes_in_use": sum(
                    p.prep_bytes for _, p in self._prep_cache.values()
                ),
                "byte_budget": self.prep_cache_bytes,
            }
        if self.snapshot_store is not None:
            info["snapshot_store"] = self.snapshot_store.info()
        return info

    def clear_prep_cache(self) -> None:
        """Drop every in-memory PreparedDB (the LRU only — the snapshot
        store and the fingerprint memo are untouched). Simulates a process
        restart for warm-start benches/tests, or frees device memory."""
        with self._lock:
            self._prep_cache.clear()

    def _cache_key(self, rows, n_items: int, spec: MineSpec) -> tuple:
        # keyed on the *prep* config — execution-only knobs (blocks,
        # backend, early_stop, tune) are normalized away, so a retune or
        # backend switch keeps hitting warm PreparedDBs and snapshots
        fe = self.frontend("hprepost")
        return (spec.algorithm, self._fingerprint(rows), n_items, fe._prep_config(spec))

    def _store_key(self, key: tuple, miner) -> str:
        """The on-disk identity of ``key``: the LRU key plus the data-shard
        count the prep is laid out for (a D=2 snapshot cannot serve a D=4
        mesh — see ``PreparedDB.from_host``)."""
        algorithm, fp, n_items, cfg = key
        return SnapshotStore.key_for(algorithm, fp, n_items, cfg, miner.D)

    def _cache_lookup(self, key, min_count: int, need_waves: bool):
        """``(miner, prepared)`` if the cached entry can serve, else None.

        A floor-``f`` entry serves any ``min_count >= f`` exactly (see
        ``PreparedDB``); a looser request — or a k>1 request against an
        F1-only entry — cannot be served and must rebuild."""
        with self._lock:
            ent = self._prep_cache.get(key)
            if ent is None:
                self._cache_stats["misses"] += 1
                return None
            _, prepared = ent
            if min_count < prepared.min_count_floor or (need_waves and prepared.f1_only):
                self._cache_stats["misses"] += 1
                return None
            self._prep_cache.move_to_end(key)
            self._cache_stats["hits"] += 1
            return ent

    def _cache_insert(self, key, miner, prepared, *, spill: bool = True) -> None:
        """Insert (replacing any stale entry), then evict least-recently-
        used entries until the byte budget holds — possibly including the
        new entry itself when it alone exceeds the budget.

        Exception: a cheap F1-only build never replaces a full
        (waves-capable) entry at the same key — the wave state (Job 2 /
        pack / F2) is the expensive part, it keeps serving future k>1
        traffic, and F1-only prep costs one histogram to redo.

        With a snapshot store bound, the entry is also spilled to disk
        (``spill=False`` for entries that just came *from* the store)."""
        if self.prep_cache_bytes <= 0:
            return
        with self._lock:
            old = self._prep_cache.get(key)
            if old is not None and prepared.f1_only and not old[1].f1_only:
                return
            self._prep_cache.pop(key, None)
            self._prep_cache[key] = (miner, prepared)
            in_use = sum(p.prep_bytes for _, p in self._prep_cache.values())
            while in_use > self.prep_cache_bytes and self._prep_cache:
                _, (_, dropped) = self._prep_cache.popitem(last=False)
                in_use -= dropped.prep_bytes
                self._cache_stats["evictions"] += 1
        if spill and self.snapshot_store is not None:
            # outside the lock: device->host gather + disk write are slow,
            # and the store rejects writes that would not improve the entry.
            # Spilling is best-effort: a full/readonly disk (or a lost
            # cross-process publish race) must never fail the mining
            # request that just built a perfectly good PreparedDB
            try:
                self.snapshot_store.put(self._store_key(key, miner), prepared.to_host())
            except Exception:
                with self._lock:
                    self._cache_stats["snapshot_spill_failures"] += 1

    def _snapshot_load(self, key, min_count: int, need_waves: bool, spec: MineSpec):
        """Warm-start ``(miner, prepared)`` from the snapshot store, else
        None. A usable snapshot lands in the LRU (without re-spilling)."""
        if self.snapshot_store is None:
            return None
        from repro.core.hprepost import PreparedDB

        fe = self.frontend("hprepost")
        miner = fe.miner_for(spec)
        try:
            payload = self.snapshot_store.get(self._store_key(key, miner))
        except Exception:  # a store I/O failure is a miss, never an error
            payload = None
        prepared = None
        if payload is not None:
            try:
                floor = int(payload["min_count_floor"])
                if min_count >= floor and not (need_waves and bool(payload["f1_only"])):
                    prepared = PreparedDB.from_host(payload, miner)
            except (ValueError, KeyError, TypeError):
                prepared = None  # unusable payload == miss; prep will heal it
        if prepared is None:
            with self._lock:
                self._cache_stats["snapshot_misses"] += 1
            return None
        self._cache_insert(key, miner, prepared, spill=False)
        with self._lock:
            self._cache_stats["snapshot_hits"] += 1
        return (miner, prepared)

    def _submit_cached(self, rows, n_items: int, spec: MineSpec) -> MineResult:
        fe = self.frontend("hprepost")
        rows = np.asarray(rows)
        key = self._cache_key(rows, n_items, spec)
        min_count = spec.resolve(len(rows))
        need_waves = spec.max_k is None or spec.max_k > 1
        t_lk = time.perf_counter()
        ent = self._cache_lookup(key, min_count, need_waves)
        source = "cache"
        if ent is None:
            ent = self._snapshot_load(key, min_count, need_waves, spec)
            source = "snapshot"
        if ent is not None:
            self.telemetry.histogram(f"engine.{source}_hit_s").record(
                time.perf_counter() - t_lk
            )
            with self._lock:
                self.stats["prepared_mines"] += 1
            _, prepared = ent
            # mine with the *current* spec's miner, not the one that built
            # the entry: cache keys span execution configs now, and the
            # PreparedDB layout only depends on the mesh (shared engine-wide)
            res = fe.mine_prepared(fe.miner_for(spec), prepared, spec, prep_shared=True)
            res.service_stats["prep_source"] = source
            self._observe_result(res)
            return res
        t0 = time.perf_counter()
        miner, prepared = fe.prepare(rows, n_items, min_count, spec,
                                     need_waves=need_waves)
        self.telemetry.histogram("engine.prep_s").record(time.perf_counter() - t0)
        self._cache_insert(key, miner, prepared)
        res = fe.mine_prepared(
            miner, prepared, spec, prep_stages=prepared.stage_times, t0=t0
        )
        res.service_stats["prep_source"] = "built"
        self._observe_result(res)
        return res

    # ------------------------------------------------------------ streaming
    def stream(self, name: str = "default", *, n_items: int | None = None,
               spec: MineSpec | None = None, stream_spec=None):
        """The named ``StreamingMiner``, created on first touch (creation
        needs ``n_items``; ``spec`` fixes its device config, ``stream_spec``
        its segmentation/compaction knobs). Segments warm-start from the
        engine's snapshot store when one is bound."""
        from repro.mining.stream import StreamingMiner

        with self._lock:
            s = self._streams.get(name)
            if s is None:
                if n_items is None:
                    raise ValueError(
                        f"stream {name!r} does not exist yet; pass n_items to create it"
                    )
                s = StreamingMiner(
                    self, n_items, spec=spec, stream_spec=stream_spec, name=name
                )
                self._streams[name] = s
            elif n_items is not None and n_items != s.n_items:
                raise ValueError(
                    f"stream {name!r} was created with n_items={s.n_items}, got {n_items}"
                )
            return s

    def distribute(self, name: str = "default", *, n_items: int | None = None,
                   workers: int = 2, spec: MineSpec | None = None,
                   stream_spec=None, snapshot_dir: str | None = None,
                   heartbeat_s: float = 0.0, **kw):
        """The named ``DistributedMiner`` (coordinator + ``workers`` spawned
        worker processes), created on first touch. It registers under the
        same namespace as ``stream``, so ``engine.append`` /
        ``engine.submit_stream`` — and therefore the ``MiningService``
        submit path — serve distributed databases unchanged. Workers share
        the engine's snapshot directory by default (the failover warm
        path); pass ``snapshot_dir`` to point them elsewhere."""
        from repro.mining.distributed import DistributedMiner

        with self._lock:
            s = self._streams.get(name)
            if s is None:
                if n_items is None:
                    raise ValueError(
                        f"distributed db {name!r} does not exist yet; "
                        "pass n_items to create it"
                    )
                s = DistributedMiner(
                    self, n_items, workers=workers, spec=spec,
                    stream_spec=stream_spec, snapshot_dir=snapshot_dir,
                    heartbeat_s=heartbeat_s, name=name, **kw
                )
                self._streams[name] = s
            elif n_items is not None and n_items != s.n_items:
                raise ValueError(
                    f"stream {name!r} was created with n_items={s.n_items}, got {n_items}"
                )
            return s

    def append(self, rows, n_items: int | None = None, *, stream: str = "default",
               spec: MineSpec | None = None, stream_spec=None) -> dict:
        """Ingest one transaction batch into the named stream (the map
        step runs on the new batch only — earlier segments are never
        re-prepared). Returns per-append telemetry."""
        return self.stream(
            stream, n_items=n_items, spec=spec, stream_spec=stream_spec
        ).append(rows)

    def submit_stream(self, spec: MineSpec, *, stream: str = "default") -> MineResult:
        """Mine the named stream's live ``SegmentedDB`` (global F1/F2 from
        summed per-segment counts, cross-segment waves)."""
        with self._lock:
            s = self._streams.get(stream)
            if s is None:
                raise KeyError(f"no stream named {stream!r}; engine.append(...) first")
            self.stats["submits"] += 1
        return s.mine(spec)

    def register_standing(self, spec: MineSpec, *, stream: str = "default"):
        """Register a standing query on the named stream: mined once now,
        then re-answered with a ``MineDiff`` after every append/expiry.
        Returns the ``StandingQuery`` handle (``latest``, ``diffs``,
        ``next_diff() -> Future``). Works on streaming and distributed
        databases alike."""
        with self._lock:
            s = self._streams.get(stream)
            if s is None:
                raise KeyError(f"no stream named {stream!r}; engine.append(...) first")
        return s.register(spec)

    def cancel_standing(self, query, *, stream: str = "default") -> None:
        """Cancel a standing query returned by ``register_standing``."""
        with self._lock:
            s = self._streams.get(stream)
            if s is None:
                raise KeyError(f"no stream named {stream!r}")
        s.cancel(query)

    def stream_stats(self) -> dict:
        """Per-stream telemetry snapshot: ``{name: stats_dict}`` for every
        live streaming/distributed database (operator surface — the
        distributed dicts carry rpc_retries / respawns / failovers)."""
        with self._lock:
            streams = dict(self._streams)
        out = {}
        for name, s in streams.items():
            stats = getattr(s, "stats", None)
            if isinstance(stats, dict):
                out[name] = dict(stats)
        return out

    # ------------------------------------------------------ planned batches
    def _plan_key(self, req: MineRequest):
        """Group key for shared-prep planning, or None for the one-shot path.

        Only the distributed hprepost backend has a prepare/mine split; a
        group must agree on the database and on every prep-level knob
        (the per-call threshold / max_k / patterns — and the execution-only
        kernel knobs — are free to differ). The key doubles as the
        persistent PreparedDB cache key."""
        if req.spec.algorithm != "hprepost":
            return None
        return self._cache_key(req.rows, req.n_items, req.spec)

    def _group_acquire(self, reqs: list[MineRequest], key: tuple):
        """Acquire the group's PreparedDB: ``(miner, prepared, source,
        prep_s)`` with source "cache" | "snapshot" | "built" and ``prep_s``
        the prepare wall seconds actually paid (None unless built).

        This is the (possibly expensive) prepare half of serving a planned
        group; the service scheduler runs it on a prep thread so group g+1
        acquires while group g's wave loop is still draining. Raises the
        prepare ``ValueError`` when the group floor trips a guard — the
        caller degrades to per-request submits."""
        failures.fire("service.prep")  # chaos: prep-thread death mid-acquire
        fe = self.frontend("hprepost")
        rows = np.asarray(reqs[0].rows)
        n_rows = len(rows)
        floor = min(r.spec.resolve(n_rows) for r in reqs)
        need_waves = any(r.spec.max_k is None or r.spec.max_k > 1 for r in reqs)
        if self.prep_cache_bytes > 0:
            t_lk = time.perf_counter()
            ent = self._cache_lookup(key, floor, need_waves)
            if ent is not None:
                self.telemetry.histogram("engine.cache_hit_s").record(
                    time.perf_counter() - t_lk
                )
                return (*ent, "cache", None)
            ent = self._snapshot_load(key, floor, need_waves, reqs[0].spec)
            if ent is not None:
                self.telemetry.histogram("engine.snapshot_hit_s").record(
                    time.perf_counter() - t_lk
                )
                return (*ent, "snapshot", None)
        t0 = time.perf_counter()
        miner, prepared = fe.prepare(
            rows, reqs[0].n_items, floor, reqs[0].spec, need_waves=need_waves
        )
        prep_s = time.perf_counter() - t0
        self.telemetry.histogram("engine.prep_s").record(prep_s)
        with self._lock:
            self.stats["prepares"] += 1
        self._cache_insert(key, miner, prepared)
        return miner, prepared, "built", prep_s

    def _group_serve(self, reqs: list[MineRequest], acq) -> list[MineResult]:
        """The k>2 waves per request of one planned group, over an acquired
        PreparedDB. On a "built" acquire the first request pays (and
        reports) the shared prep; every other consumer carries 0.0 prep
        stages and ``prep_shared``.

        The payer's wall time is reconstructed as prep work + its own
        waves: when the acquire ran ahead on a prep thread, the idle gap
        between prepare finishing and the group being served is scheduling
        delay, not work, and must not inflate ``wall_time_s``."""
        _, prepared, source, prep_s = acq
        fe = self.frontend("hprepost")
        out = []
        for j, r in enumerate(reqs):
            with self._lock:
                self.stats["submits"] += 1
                self.stats["prepared_mines"] += 1
            payer = source == "built" and j == 0
            res = fe.mine_prepared(
                fe.miner_for(r.spec), prepared, r.spec,
                prep_stages=prepared.stage_times if payer else None,
                prep_shared=not payer,
                t0=time.perf_counter() - prep_s if payer else None,
            )
            res.service_stats["prep_source"] = source
            self._observe_result(res)
            out.append(res)
        return out

    def _run_group(self, reqs: list[MineRequest], key: tuple) -> list[MineResult]:
        """Serve one planned group: acquire the PreparedDB (cache / snapshot
        / one build at the loosest threshold), then the waves per request."""
        try:
            acq = self._group_acquire(reqs, key)
        except ValueError:
            # the floor F-list can trip guards (max_f1) that tighter
            # thresholds in the group would individually pass; don't fail
            # the whole batch — degrade to the one-shot path per request,
            # where any real per-request error surfaces precisely
            return [self.submit(r.rows, r.n_items, r.spec) for r in reqs]
        return self._group_serve(reqs, acq)

    def submit_many(self, requests: Iterable[MineRequest]) -> list[MineResult]:
        """Serve a batch of requests; results align with the input order.

        Requests that share (database, device config) on the hprepost
        backend are planned together — one PreparedDB at the group's
        loosest threshold serves all of them. Everything else (host
        algorithms, singleton groups) takes the one-shot path; frontends
        stay warm across the whole batch either way."""
        requests = list(requests)
        results: list[MineResult | None] = [None] * len(requests)
        groups: dict[tuple, list[int]] = {}
        loners: list[int] = []
        for i, r in enumerate(requests):
            key = self._plan_key(r)
            if key is None:
                loners.append(i)
            else:
                groups.setdefault(key, []).append(i)
        for key, idxs in groups.items():
            if len(idxs) == 1:
                loners.append(idxs[0])
                continue
            for i, res in zip(idxs, self._run_group([requests[i] for i in idxs], key)):
                results[i] = res
        for i in sorted(loners):
            r = requests[i]
            results[i] = self.submit(r.rows, r.n_items, r.spec)
        return results

    def sweep(self, rows, n_items: int, spec: MineSpec,
              min_sups: Sequence[float]) -> list[MineResult]:
        """Threshold sweep (the paper's x-axis) on one warm miner.

        For hprepost the sweep is planned: Job 1 / Job 2 / pack / F2 run
        once at the loosest threshold and every ``min_sup`` is served from
        the shared PreparedDB — results are itemset-identical to
        independent ``submit`` calls per threshold."""
        return self.submit_many(
            [MineRequest(rows, n_items, spec.with_(min_sup=s)) for s in min_sups]
        )
