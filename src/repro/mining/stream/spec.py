"""StreamSpec: the streaming-ingestion knobs, one frozen config object.

Mirrors ``MineSpec``'s posture (hashable, ``with_``-less — streams are
long-lived, the spec is fixed at stream creation): how new batches are
padded into segments, and when the LSM-style compactor folds small
segments back together.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """How a ``StreamingMiner`` segments and compacts its database.

    ``row_pad`` pads every appended batch's row count up to a multiple
    (padding rows are all-PAD, support-neutral) so repeated equal-sized
    appends hit the same jitted prepare/wave shapes instead of recompiling.

    Compaction (LSM-style): a pass merges the ``compact_fanin`` smallest
    segments' host rows and re-prepares them as one segment. It triggers
    when the segment count exceeds ``max_segments``, or when segments
    smaller than ``small_rows`` rows together hold more than
    ``small_byte_frac`` of the database's bytes (``small_rows=0`` disables
    the byte-fraction trigger). ``compact_async=True`` runs the merge
    re-prepare on a background thread (the PR 4 prep-thread posture) so it
    stays off the append/query path; queries meanwhile serve from the
    uncompacted segments — bit-for-bit the same answers, supports being
    additive either way.
    """

    row_pad: int = 1  # pad each batch's rows to a multiple of this
    max_segments: int = 16  # compaction trigger: segment count
    small_rows: int = 0  # a segment under this many rows is "small"
    small_byte_frac: float = 0.5  # trigger: small segments' byte fraction
    compact_fanin: int = 4  # smallest segments merged per compaction pass
    compact_async: bool = False  # merge re-prepare on a background thread

    def __post_init__(self):
        if self.row_pad < 1:
            raise ValueError(f"row_pad must be >= 1, got {self.row_pad}")
        if self.max_segments < 1:
            raise ValueError(f"max_segments must be >= 1, got {self.max_segments}")
        if self.compact_fanin < 2:
            raise ValueError(f"compact_fanin must be >= 2, got {self.compact_fanin}")
        if not (0.0 < self.small_byte_frac <= 1.0):
            raise ValueError(
                f"small_byte_frac must be in (0, 1], got {self.small_byte_frac}"
            )
        if self.small_rows < 0:
            raise ValueError(f"small_rows must be >= 0, got {self.small_rows}")
