"""StreamSpec: the streaming-ingestion knobs, one frozen config object.

Mirrors ``MineSpec``'s posture (hashable, ``with_``-less — streams are
long-lived, the spec is fixed at stream creation): how new batches are
padded into segments, and when the LSM-style compactor folds small
segments back together.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """How a ``StreamingMiner`` segments and compacts its database.

    ``row_pad`` pads every appended batch's row count up to a multiple
    (padding rows are all-PAD, support-neutral) so repeated equal-sized
    appends hit the same jitted prepare/wave shapes instead of recompiling.

    Compaction (LSM-style): a pass merges the ``compact_fanin`` smallest
    segments' host rows and re-prepares them as one segment. It triggers
    when the segment count exceeds ``max_segments``, or when segments
    smaller than ``small_rows`` rows together hold more than
    ``small_byte_frac`` of the database's bytes (``small_rows=0`` disables
    the byte-fraction trigger). ``compact_async=True`` runs the merge
    re-prepare on a background thread (the PR 4 prep-thread posture) so it
    stays off the append/query path; queries meanwhile serve from the
    uncompacted segments — bit-for-bit the same answers, supports being
    additive either way.

    Continuous-mode knobs (``repro.mining.continuous``):

    ``window_rows`` / ``window_batches`` arm a sliding window: at append
    time the oldest segments are expired (``SegmentedDB.drop_segments``)
    until the retained suffix is the *minimal* one still covering at
    least that many real rows / appended batches. Expiry is exact —
    supports are additive per segment, so a drop subtracts the segment's
    counts and F2 block bit-for-bit. With a window armed, compaction only
    merges append-order-contiguous runs, so expiry stays segment-granular.

    ``decay < 1`` arms time-decayed supports: at query time segment
    supports are weighted by ``decay ** (appends since the segment
    arrived)`` and accumulated in float64 next to the exact integer path
    (threshold applied post-reduce). Decay requires per-segment ages, so
    it disables compaction (a merged segment has no single age) — the
    byte-fraction trigger must be left off.
    """

    row_pad: int = 1  # pad each batch's rows to a multiple of this
    max_segments: int = 16  # compaction trigger: segment count
    small_rows: int = 0  # a segment under this many rows is "small"
    small_byte_frac: float = 0.5  # trigger: small segments' byte fraction
    compact_fanin: int = 4  # smallest segments merged per compaction pass
    compact_async: bool = False  # merge re-prepare on a background thread
    window_rows: int = 0  # sliding window over real rows (0 = unbounded)
    window_batches: int = 0  # sliding window over appended batches
    decay: float = 1.0  # per-append damping of older segments' supports

    def __post_init__(self):
        if self.row_pad < 1:
            raise ValueError(f"row_pad must be >= 1, got {self.row_pad}")
        if self.max_segments < 1:
            raise ValueError(f"max_segments must be >= 1, got {self.max_segments}")
        if self.compact_fanin < 2:
            raise ValueError(f"compact_fanin must be >= 2, got {self.compact_fanin}")
        if self.compact_fanin > self.max_segments:
            # contradictory: the count trigger fires at > max_segments, but
            # a pass would want to merge more segments than the trigger
            # guarantees exist — the stream would thrash or never converge
            raise ValueError(
                f"compact_fanin={self.compact_fanin} exceeds "
                f"max_segments={self.max_segments}; a compaction pass cannot "
                "merge more segments than the trigger guarantees live"
            )
        if not (0.0 < self.small_byte_frac <= 1.0):
            raise ValueError(
                f"small_byte_frac must be in (0, 1], got {self.small_byte_frac}"
            )
        if self.small_rows < 0:
            raise ValueError(f"small_rows must be >= 0, got {self.small_rows}")
        if self.window_rows < 0:
            raise ValueError(f"window_rows must be >= 0, got {self.window_rows}")
        if self.window_batches < 0:
            raise ValueError(
                f"window_batches must be >= 0, got {self.window_batches}"
            )
        if self.window_rows and self.window_batches:
            raise ValueError(
                "window_rows and window_batches are alternative window units; "
                "set at most one"
            )
        if not (0.0 < self.decay <= 1.0):
            raise ValueError(f"decay must be in (0, 1], got {self.decay}")
        if self.decay < 1.0 and self.small_rows > 0:
            raise ValueError(
                "decay < 1 disables compaction (a merged segment has no "
                "single age) but small_rows > 0 arms the byte-fraction "
                "compaction trigger — remove one"
            )

    @property
    def windowed(self) -> bool:
        """True when a sliding window (rows or batches) is armed."""
        return bool(self.window_rows or self.window_batches)
