"""repro.mining.stream — streaming ingestion over segmented N-list databases.

The paper's map/reduce split kept *live*: per-batch PPC-tree/N-list
segments are independent map outputs (``StreamingMiner.append`` preps only
the new batch), global F1/F2 are summed per-segment counts (the reduce),
and queries run the k>2 wave loop per segment with per-candidate supports
summed across segments — exact by support additivity over disjoint
partitions. An LSM-style compactor folds small segments back together off
the query path. Front doors: ``MiningEngine.append`` / ``submit_stream``
and the ``MiningService`` equivalents.
"""
from repro.mining.stream.segmented import Segment, SegmentedDB
from repro.mining.stream.spec import StreamSpec
from repro.mining.stream.stream import StreamingMiner

__all__ = ["Segment", "SegmentedDB", "StreamSpec", "StreamingMiner"]
