"""SegmentedDB: an ordered collection of per-batch prepared segments plus
the merged global aggregates the reduce step needs.

The paper's MapReduce observation, kept live instead of re-derived: PPC
trees / N-lists built over *disjoint* transaction partitions are
independent map outputs, and per-itemset supports are additive in the
reduce. A ``SegmentedDB`` therefore holds

  - one ``Segment`` per appended batch (its host rows for later
    compaction, its device-resident ``PreparedDB``, and its
    sentinel-extended N-list buffers ready for cross-segment waves),
  - the **stream item order**: an append-only map item -> global rank,
    assigned at first appearance. Every segment's PPC tree is built in
    this shared order (``HPrepostMiner.prepare(flist=...)``), which is
    what makes cross-segment N-list intersections exact — ancestor
    relations agree across all segments, and a segment's local rank space
    is an order-preserving subset of the global one,
  - the merged global item counts (summed per-batch histograms — the
    streaming Job 1 reduce) and the merged F2 co-occurrence matrix in
    stream-rank space (summed per-segment ``PreparedDB.C``, embedded
    monotonically — the streaming F2 reduce).

Pure data structure: no device work and no locking here — the
``StreamingMiner`` orchestrates both.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any

import numpy as np

from repro.core import encoding as enc
from repro.core.hprepost import PreparedDB, SegmentHandle


@dataclasses.dataclass
class Segment:
    """One appended batch, prepared and device-resident."""

    seg_id: int
    rows: np.ndarray  # host copy, row-padded (all-PAD pad rows)
    n_rows: int  # real (pre-padding) transaction count
    prepared: PreparedDB
    packed_ext: Any  # device (D, K_s + 1, W_s, 3), sentinel row appended
    singleton_ext: Any  # packed_ext[..., 2]
    local_items: np.ndarray  # items in this segment's tree, stream order
    item_to_local: np.ndarray  # (n_items,) int32: item -> local rank | -1
    digest: str  # content digest of ``rows`` (snapshot identity)
    n_batches: int = 1  # appended batches folded in (compaction merges sum)
    tick: int = 0  # append tick this segment arrived at (decay ages off it)

    @property
    def k(self) -> int:
        return len(self.local_items)

    @property
    def nbytes(self) -> int:
        return int(self.rows.nbytes)


def segment_handles(segments: "list[Segment]", order_arr: np.ndarray) -> list[SegmentHandle]:
    """Wave handles for ``segments`` against a global rank space given as
    ``order_arr`` (rank -> item). ``g2l`` routes ranks a segment never saw
    (items first seen in later batches, or absent from it) to the sentinel
    row. Shared by ``SegmentedDB.handles`` and the distributed worker,
    whose query_begin receives ``order_arr`` from the coordinator."""
    out = []
    for s in segments:
        loc = s.item_to_local[order_arr]
        g2l = np.where(loc >= 0, loc, s.k).astype(np.int32)
        out.append(SegmentHandle(packed=s.packed_ext, singleton=s.singleton_ext, g2l=g2l))
    return out


class SegmentedDB:
    """Ordered segments + merged global state for one stream."""

    def __init__(self, n_items: int):
        self.n_items = int(n_items)
        self.segments: list[Segment] = []
        self.rank_of = np.full(n_items, -1, np.int32)  # item -> stream rank
        self.order: list[int] = []  # stream rank -> item
        self.counts = np.zeros(n_items, np.int64)  # global Job 1 reduce
        self.C = np.zeros((0, 0), np.int64)  # global F2 reduce (triu, rank space)
        self.n_rows = 0  # real appended transactions (thresholds resolve here)

    @property
    def n_ranked(self) -> int:
        return len(self.order)

    # --------------------------------------------------------- item order
    def register_batch(self, hist: np.ndarray) -> np.ndarray:
        """Fold one batch histogram into the global counts, assigning
        stream ranks to never-seen items (by batch support descending,
        ties item-ascending — deterministic, so a replayed stream
        reproduces the exact same rank space). Returns the new items."""
        present = np.flatnonzero(hist > 0)
        fresh = present[self.rank_of[present] < 0]
        if len(fresh):
            fresh = fresh[np.lexsort((fresh, -hist[fresh]))]
            self.rank_of[fresh] = np.arange(
                self.n_ranked, self.n_ranked + len(fresh), dtype=np.int32
            )
            self.order.extend(int(i) for i in fresh)
            grown = np.zeros((self.n_ranked, self.n_ranked), np.int64)
            grown[: self.C.shape[0], : self.C.shape[1]] = self.C
            self.C = grown
        self.counts += hist
        return fresh

    def present_in_order(self, hist: np.ndarray) -> np.ndarray:
        """Items of one batch, sorted by stream rank (the order its
        segment F-list must use). Call after ``register_batch``."""
        present = np.flatnonzero(hist > 0)
        return present[np.argsort(self.rank_of[present], kind="stable")].astype(np.int32)

    # ----------------------------------------------------------- segments
    def add_segment(self, seg: Segment) -> None:
        """Append a segment and fold its F2 matrix into the global one.
        The local C is in local rank space; local order is the stream
        order restricted to the segment's items, so the embedding by
        global ranks is monotone and stays upper-triangular."""
        gr = self.rank_of[seg.local_items]
        self.C[np.ix_(gr, gr)] += seg.prepared.C
        self.segments.append(seg)

    def drop_segments(self, victim_ids: set[int]) -> "list[Segment]":
        """The retraction primitive: remove the named segments and
        subtract their aggregates from the global state — the exact
        inverse of ``register_batch`` + ``add_segment``, because supports
        are additive over disjoint partitions. Item ranks are append-only
        and stay assigned (an item whose every occurrence expired simply
        reports count 0, i.e. infrequent at any positive threshold), so
        the stream rank space — and with it every surviving segment's
        packed layout and snapshot key — is untouched. Returns the
        dropped segments, oldest first."""
        dropped = [s for s in self.segments if s.seg_id in victim_ids]
        if not dropped:
            return []
        self.segments = [s for s in self.segments if s.seg_id not in victim_ids]
        for s in dropped:
            gr = self.rank_of[s.local_items]
            self.C[np.ix_(gr, gr)] -= s.prepared.C
            self.counts -= enc.item_support(s.rows, self.n_items)
            self.n_rows -= s.n_rows
        return dropped

    def replace_segments(self, victim_ids: set[int], merged: Segment) -> bool:
        """Swap compacted segments for their merge, preserving order (the
        merge lands at the earliest victim's position). Global counts and
        C are untouched: the merged segment's aggregates equal the sum of
        its parts, which are already folded in — which is also why a
        compaction pass cannot change any query answer.

        Returns False — and swaps NOTHING — when any victim is no longer
        live: a sliding window may have expired it while an async merge
        was in flight, and installing the merge would resurrect retracted
        rows. The discarded pass wasted only prep work."""
        live = {s.seg_id for s in self.segments}
        if not victim_ids <= live:
            return False
        out, placed = [], False
        for s in self.segments:
            if s.seg_id in victim_ids:
                if not placed:
                    out.append(merged)
                    placed = True
                continue
            out.append(s)
        self.segments = out
        return True

    def handles(self) -> list[SegmentHandle]:
        """Per-segment wave handles against the *current* global rank
        space (module-level ``segment_handles`` over this db's order)."""
        return segment_handles(self.segments, np.asarray(self.order, np.int32))

    def digest(self) -> str:
        """Segment-set digest: identifies the exact segment layout (used
        to key caches/telemetry on the live stream state)."""
        h = hashlib.sha1()
        for s in self.segments:
            h.update(s.digest.encode())
        h.update(str(self.n_rows).encode())
        return h.hexdigest()

    def stats(self) -> dict:
        return {
            "segments": len(self.segments),
            "rows": self.n_rows,
            "batches": sum(s.n_batches for s in self.segments),
            "items_ranked": self.n_ranked,
            "segment_rows": [s.n_rows for s in self.segments],
            "bytes": sum(s.nbytes for s in self.segments),
        }
