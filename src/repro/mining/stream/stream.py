"""StreamingMiner: incremental ingestion over a ``SegmentedDB``.

``append(rows_batch)`` is the paper's *map* step run on only the new
partition: one host histogram, then Job 2 / pack / F2 on the batch alone
(``HPrepostMiner.prepare`` with the stream's imposed global item order) —
never a rebuild of earlier segments. ``mine(spec)`` is the *reduce*:
global F1/F2 come from summed per-segment counts, and the k>2 wave loop
plans candidates once against the global F-lists while launching the
fused intersect kernel per segment, summing per-candidate supports across
segments before thresholding (``mine_prepared_segments``). Exactness
rides on support additivity over disjoint partitions plus the shared
stream item order every segment's tree is built in.

Per-segment persistence: with the engine's ``SnapshotStore`` bound, every
segment build is spilled under a key extended with the segment's imposed
item order (same batch + same stream history -> same key), so a restarted
process replaying its append log warm-starts every already-seen segment
with **zero** prep stages (``stats["seg_prepares"] == 0``).

Compaction (LSM-style): when the ``StreamSpec`` thresholds trip, the
smallest segments' host rows are merged and re-prepared as one segment —
global counts/C are untouched (the merge's aggregates equal the sum of
its parts), so query answers are bit-for-bit unchanged. With
``compact_async`` the merge runs on a background thread, off the
append/query path, and swaps in when ready.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.core import encoding as enc
from repro.core.hprepost import PreparedDB
from repro.fault import failures
from repro.mining.engine import MiningEngine
from repro.mining.result import MineResult
from repro.mining.spec import MineSpec
from repro.mining.stream.segmented import Segment, SegmentedDB
from repro.mining.stream.spec import StreamSpec
from repro.mining.telemetry import trace

# content identity of a row block — the engine's fingerprint digest, so
# stream snapshot keys and engine fingerprints can never drift apart
_digest = MiningEngine._digest


def segment_key(digest: tuple, local_items: np.ndarray, n_items: int,
                device_cfg, n_shards: int) -> str:
    """On-disk identity of a segment build: the batch content, the imposed
    item order (the same rows appended into a different stream history pack
    differently!), the prep-level device config, and the shard count.
    Execution-only knobs (kernel blocks, backend, early_stop, tune) are
    normalized away via ``prep_key`` — a retune or backend switch must keep
    warm-restoring segments. Shared by the streaming miner and the
    distributed workers — agreeing on this key is what lets a surviving
    worker warm-restore a dead peer's segments."""
    from repro.mining.service.store import SnapshotStore

    items_digest = hashlib.sha1(
        np.ascontiguousarray(local_items, np.int32).tobytes()
    ).hexdigest()
    return SnapshotStore.key_for(
        "hprepost-seg", digest, n_items,
        {"cfg": dataclasses.asdict(device_cfg.prep_key()),
         "stream_items": items_digest},
        n_shards,
    )


def build_segment(miner, store, n_items: int, rows: np.ndarray, n_rows_real: int,
                  hist: np.ndarray, local_items: np.ndarray, *, seg_id: int,
                  device_cfg, row_pad: int, stats: dict) -> tuple[Segment, str]:
    """Prepare one batch as a segment: snapshot warm-start when ``store``
    already holds this (rows, imposed item order, device config) triple,
    else run the prep stages on the batch. ``stats`` gets the
    ``seg_prepares`` / ``seg_snapshot_*`` counters bumped in place. The
    single implementation behind ``StreamingMiner.append`` and the
    distributed worker's prep op — both must build byte-identical
    segments (and snapshot keys) for failover to be zero-recompute."""
    R0 = len(rows)
    Rp = -(-R0 // row_pad) * row_pad
    if Rp != R0:
        padded = np.full((Rp, rows.shape[1]), enc.PAD, np.int32)
        padded[:R0] = rows
        rows = padded
    fl = enc.FList(
        items=local_items,
        supports=hist[local_items].astype(np.int64),
        n_items=n_items,
        min_count=1,
    )
    digest = _digest(rows)
    key = segment_key(digest, local_items, n_items, device_cfg, miner.D)
    prepared = None
    source = "built"
    if store is not None:
        try:
            payload = store.get(key)
        except Exception:
            payload = None
        if payload is not None:
            try:
                prepared = PreparedDB.from_host(payload, miner)
            except ValueError:
                prepared = None
        if prepared is not None:
            stats["seg_snapshot_hits"] += 1
            source = "snapshot"
        else:
            stats["seg_snapshot_misses"] += 1
    if prepared is None:
        prepared = miner.prepare(rows, n_items, 1, flist=fl)
        stats["seg_prepares"] += 1
        if store is not None:
            try:
                store.put(key, prepared.to_host())
            except Exception:
                stats["seg_snapshot_spill_failures"] += 1
    packed_ext, singleton_ext = miner.extend_with_sentinel(prepared)
    item_to_local = np.full(n_items, -1, np.int32)
    item_to_local[local_items] = np.arange(len(local_items), dtype=np.int32)
    seg = Segment(
        seg_id=seg_id, rows=rows, n_rows=int(n_rows_real),
        prepared=prepared, packed_ext=packed_ext, singleton_ext=singleton_ext,
        local_items=local_items, item_to_local=item_to_local,
        digest=digest[2],
    )
    return seg, source


class StreamingMiner:
    """One live, append-only mining stream bound to a ``MiningEngine``.

    ``spec`` fixes the device-level configuration (and so the resident
    ``HPrepostMiner``) for every segment and query of this stream; query
    specs may vary threshold / ``max_k`` / ``patterns`` freely but must
    agree on the device knobs. Appends and queries are serialized per
    stream by one lock; async compaction prepares outside it.
    """

    def __init__(self, engine, n_items: int, *, spec: MineSpec | None = None,
                 stream_spec: StreamSpec | None = None, name: str = "default"):
        self.engine = engine
        self.name = name
        self.n_items = int(n_items)
        self.spec = spec if spec is not None else MineSpec()
        self.stream_spec = stream_spec if stream_spec is not None else StreamSpec()
        self._fe = engine.frontend("hprepost")
        self._device_cfg = self._fe._device_config(self.spec)
        self.miner = self._fe.miner_for(self.spec)
        self.db = SegmentedDB(n_items)
        self._lock = threading.RLock()
        self._next_seg = 0
        self._tick = 0  # append ticks (decay ages segments off this)
        self.rows_appended = 0  # monotone: never decremented by expiry
        # window ledger for segment-less appends (all-PAD batches): their
        # rows count toward n_rows and must age out of the window like any
        # others, ordered by append tick against the segments
        self._empty_trail: list[list[int]] = []  # [tick, n_rows]
        self._compact_pending: set[int] | None = None
        self._compact_future = None
        self._compact_pool: ThreadPoolExecutor | None = None
        from repro.mining.continuous import StandingRegistry

        self.standing = StandingRegistry(self)
        self.stats = {
            "appends": 0, "queries": 0, "empty_batches": 0,
            "seg_prepares": 0,  # segment builds that ran real prep stages
            "seg_snapshot_hits": 0, "seg_snapshot_misses": 0,
            "seg_snapshot_spill_failures": 0,
            "compactions": 0, "segments_compacted": 0, "compact_errors": 0,
            "compact_discarded": 0,  # merges dropped: a victim expired mid-flight
            # sliding-window churn (ROADMAP item 3 operator surface)
            "expires": 0, "expired_segments": 0, "expired_rows": 0,
            "expire_errors": 0,
            # standing-query delivery telemetry
            "standing_queries": 0, "diffs_delivered": 0, "diff_errors": 0,
            "diff_latency_s_total": 0.0, "last_diff_latency_s": 0.0,
            "seed_pruned_candidates": 0,
        }

    # -------------------------------------------------------------- append
    def append(self, rows_batch) -> dict:
        """Ingest one batch of transactions (the map step on the new
        partition only). Returns per-append telemetry; the batch is
        copied, so callers may keep mutating their array."""
        rows = np.array(rows_batch, np.int32, copy=True)
        if rows.ndim != 2:
            raise ValueError(f"rows batch must be 2-D (R, L), got shape {rows.shape}")
        if rows.size and int(rows.max()) >= self.n_items:
            raise ValueError(
                f"batch contains item id {int(rows.max())} >= n_items={self.n_items}"
            )
        t0 = time.perf_counter()
        with trace.span("stream.append", stream=self.name), self._lock:
            self._reap_compaction()
            hist = enc.item_support(rows, self.n_items)
            new_items = self.db.register_batch(hist)
            self.db.n_rows += len(rows)
            self.stats["appends"] += 1
            self.rows_appended += len(rows)
            self._tick += 1  # one decay tick per append: history ages now
            source = "empty"
            if hist.sum() > 0:
                local_items = self.db.present_in_order(hist)
                seg, source = self._build_segment(rows, len(rows), hist, local_items)
                seg.tick = self._tick
                self.db.add_segment(seg)
            else:
                self.stats["empty_batches"] += 1
                if self.stream_spec.windowed and len(rows):
                    self._empty_trail.append([self._tick, len(rows)])
            n_seg_expired, n_rows_expired = self._expire()
            self._maybe_compact()
            diffs = self.standing.refresh_all(
                "expire" if n_rows_expired else "append")
            append_s = time.perf_counter() - t0
            self.engine.telemetry.histogram(
                f"stream.{self.name}.append_s").record(append_s)
            return {
                "rows": int(len(rows)),
                "total_rows": int(self.db.n_rows),
                "segments": len(self.db.segments),
                "new_items": int(len(new_items)),
                "expired": n_seg_expired,
                "expired_rows": n_rows_expired,
                "diffs": int(diffs),
                "prep_source": source,
                "append_s": append_s,
            }

    def _expire(self) -> tuple[int, int]:
        """Sliding-window expiry (lock held): drop the oldest appends —
        segments and segment-less all-PAD batches alike, ordered by their
        append tick — until the retained suffix is the minimal one still
        covering the window (``window_rows`` real rows /
        ``window_batches`` batches). The newest append always survives.
        Returns (segments dropped, rows dropped). An injected expiry
        failure (``stream.expire``) skips the pass and is only accounted —
        the window self-heals on the next append, and every answer in
        between is still exact over the (briefly wider) retained suffix."""
        ss = self.stream_spec
        if not ss.windowed:
            return 0, 0
        # (tick, size, segment-or-None, rows) in append order
        by_batches = bool(ss.window_batches)
        entries = [
            (s.tick, s.n_batches if by_batches else s.n_rows, s, s.n_rows)
            for s in self.db.segments
        ] + [(t, 1 if by_batches else n, None, n) for t, n in self._empty_trail]
        entries.sort(key=lambda e: e[0])
        if len(entries) <= 1:
            return 0, 0
        window = ss.window_batches or ss.window_rows
        total = sum(e[1] for e in entries)
        victims, i = [], 0
        while i < len(entries) - 1 and total - entries[i][1] >= window:
            total -= entries[i][1]
            victims.append(entries[i])
            i += 1
        if not victims:
            return 0, 0
        try:
            failures.fire("stream.expire")
        except Exception:
            self.stats["expire_errors"] += 1
            return 0, 0
        t_ex = time.perf_counter()
        seg_victims = {e[2].seg_id for e in victims if e[2] is not None}
        dropped = self.db.drop_segments(seg_victims) if seg_victims else []
        empty_ticks = {e[0] for e in victims if e[2] is None}
        empty_rows = sum(n for t, n in self._empty_trail if t in empty_ticks)
        if empty_ticks:
            self._empty_trail = [
                e for e in self._empty_trail if e[0] not in empty_ticks]
            self.db.n_rows -= empty_rows
        n_rows = sum(s.n_rows for s in dropped) + empty_rows
        self.stats["expires"] += 1
        self.stats["expired_segments"] += len(dropped)
        self.stats["expired_rows"] += n_rows
        self.engine.telemetry.histogram(f"stream.{self.name}.expire_s").record(
            time.perf_counter() - t_ex
        )
        return len(dropped), n_rows

    # ----------------------------------------------------- standing queries
    def register(self, spec: MineSpec):
        """Register a standing query: mined now (the initial delivery) and
        after every append/expiry from here on. Returns the
        ``StandingQuery`` whose ``next_diff()`` Futures resolve in
        arrival order with each delivered ``MineDiff``."""
        with self._lock:
            return self.standing.register(spec)

    def cancel(self, query) -> None:
        with self._lock:
            self.standing.cancel(query)

    def _build_segment(self, rows: np.ndarray, n_rows_real: int,
                       hist: np.ndarray, local_items: np.ndarray) -> tuple[Segment, str]:
        """Prepare one batch as a segment (module-level ``build_segment``
        with this stream's miner/store/config bound)."""
        # seg-id allocation must be atomic: an append (stream lock held)
        # and an async compaction job (deliberately outside the lock)
        # both build segments, and a duplicated id would let
        # replace_segments clobber a live segment
        with self._lock:
            seg_id = self._next_seg
            self._next_seg += 1
        seg, source = build_segment(
            self.miner, self.engine.snapshot_store, self.n_items,
            rows, n_rows_real, hist, local_items,
            seg_id=seg_id, device_cfg=self._device_cfg,
            row_pad=self.stream_spec.row_pad, stats=self.stats,
        )
        return seg, source

    def _segment_key(self, digest: tuple, local_items: np.ndarray) -> str:
        return segment_key(
            digest, local_items, self.n_items, self._device_cfg, self.miner.D
        )

    # --------------------------------------------------------------- query
    def mine(self, spec: MineSpec, _seed=None, _seed_out=None) -> MineResult:
        """Serve one query from the live ``SegmentedDB`` (the reduce step
        + cross-segment waves). Prep was paid at append time, so results
        carry ``prep_shared`` and zeroed prep stage keys.

        With ``StreamSpec.decay < 1`` the query runs the damped-window
        reduce instead: per-segment supports weighted by age in float64,
        float threshold post-reduce (``repro.mining.continuous.decay``).
        ``_seed`` / ``_seed_out`` are the standing-query refresh hooks —
        per-itemset support bounds from the previous answer's settled
        waves, passed through to the planner's upper-bound prune (exact
        integer mode only; never changes the answer)."""
        if spec.algorithm != "hprepost":
            raise ValueError(
                f"stream queries run on the hprepost backend, got {spec.algorithm!r}"
            )
        # only prep-level knobs are pinned by the packed segments;
        # execution-only knobs (blocks, backend, early_stop, tune) are free
        # to differ per query and are honored via the query's own miner
        if self._fe._prep_config(spec) != self._device_cfg.prep_key():
            raise ValueError(
                "query device config differs from the stream's; segments were "
                "packed under the stream spec — open a new stream to change knobs"
            )
        self._fe._check_patterns(spec)
        t0 = time.perf_counter()
        decay = self.stream_spec.decay
        weights = None
        with self._lock:
            self._reap_compaction()
            handles = self.db.handles()
            items = np.asarray(self.db.order, np.int32)
            n_rows = self.db.n_rows
            n_segs = len(handles)
            seg_digest = self.db.digest()
            if decay < 1.0:
                from repro.mining import continuous as cont

                spec.resolve(max(n_rows, 1))  # threshold-shape validation only
                weights = cont.segment_weights(self.db.segments, self._tick, decay)
                _, sups, C, wrows = cont.weighted_state(self.db, weights)
                min_count = cont.resolve_weighted(spec, wrows)
                peak_floor = max(int(min_count), 1)
                wrows_snapshot = float(wrows)
            else:
                sups = self.db.counts[items] if len(items) else np.zeros(0, np.int64)
                # private copy: concurrent appends fold new batches into
                # C/counts in place, and the wave loop reads its planning
                # tables many times
                C = self.db.C.copy()
                min_count = spec.resolve(max(n_rows, 1))
                peak_floor = min_count
            peak_base = sum(
                s.prepared.bytes_at(peak_floor, self.miner.D) for s in self.db.segments
            )
        if len(items) > spec.max_f1:
            raise ValueError(
                f"|stream F-list|={len(items)} exceeds max_f1={spec.max_f1}"
            )
        qminer = self._fe.miner_for(spec)  # honors execution-only knobs
        with trace.span("stream.query", stream=self.name, segments=n_segs):
            res = qminer.mine_prepared_segments(
                handles, items, sups, C, min_count, max_k=spec.max_k,
                peak_base=peak_base, weights=weights,
                seed=_seed if decay == 1.0 else None,
                seed_out=_seed_out if decay == 1.0 else None,
            )
        self.stats["queries"] += 1
        self.engine.telemetry.histogram(f"stream.{self.name}.query_s").record(
            time.perf_counter() - t0
        )
        out = self._fe._finish(
            res.itemsets, res.total_count, res.n_explicit, res.peak_bytes,
            dict(qminer.last_stage_times), res.flist_items,
            spec=spec, min_count=min_count, n_rows=n_rows, t0=t0, prep_shared=True,
        )
        out.service_stats.update(
            prep_source="stream", stream_segments=n_segs, stream_digest=seg_digest
        )
        if decay < 1.0:
            out.service_stats.update(decay=decay, weighted_rows=wrows_snapshot)
        return out

    # ---------------------------------------------------------- compaction
    def _needs_compaction(self) -> bool:
        ss = self.stream_spec
        segs = self.db.segments
        if ss.decay < 1.0:
            # decayed supports need per-segment ages; a merged segment has
            # none — the spec validated the triggers are compatible
            return False
        if len(segs) < 2:
            return False
        if len(segs) > ss.max_segments:
            return True
        if ss.small_rows > 0:
            total = sum(s.nbytes for s in segs)
            small = [s for s in segs if s.n_rows < ss.small_rows]
            if (len(small) >= 2 and total
                    and sum(s.nbytes for s in small) / total > ss.small_byte_frac):
                return True
        return False

    def _maybe_compact(self) -> None:  # lock held
        if self._compact_pending is None and self._needs_compaction():
            try:
                self._launch_compaction()
            except Exception:
                # an auto-triggered (possibly sync) compaction failure must
                # not fail the append that tripped it — the batch is already
                # ingested and the uncompacted layout answers exactly; the
                # job accounted the error in stats["compact_errors"]
                pass

    def compact(self, *, wait: bool = True) -> dict:
        """Force one compaction pass (merge the ``compact_fanin`` smallest
        segments), regardless of the thresholds. ``wait=False`` with
        ``compact_async`` returns once the pass is scheduled. Unlike the
        auto trigger (which swallows failures — appends must not break on
        a background merge), an explicit pass propagates a sync failure to
        its caller."""
        if self.stream_spec.decay < 1.0:
            raise ValueError(
                "decayed streams do not compact: a merged segment has no "
                "single age for the damping weight"
            )
        with self._lock:
            self._reap_compaction()
            if self._compact_pending is None and len(self.db.segments) >= 2:
                self._launch_compaction()
        if wait:
            self.flush()
        with self._lock:
            return {"segments": len(self.db.segments),
                    "compactions": self.stats["compactions"]}

    def _launch_compaction(self) -> None:  # lock held
        segs = self.db.segments
        fanin = min(self.stream_spec.compact_fanin, len(segs))
        if fanin < 2:
            return
        if self.stream_spec.windowed:
            # expiry is segment-granular off the append-order prefix: a
            # merge of non-adjacent segments would fuse rows of different
            # ages and break the window boundary — victims must be a
            # contiguous run (the lightest one)
            start = min(
                range(len(segs) - fanin + 1),
                key=lambda i: sum(s.n_rows for s in segs[i:i + fanin]),
            )
            victims = list(segs[start:start + fanin])
        else:
            victims = sorted(segs, key=lambda s: (s.n_rows, s.seg_id))[:fanin]
        self._compact_pending = {v.seg_id for v in victims}
        if self.stream_spec.compact_async:
            if self._compact_pool is None:
                self._compact_pool = ThreadPoolExecutor(
                    max_workers=1, thread_name_prefix="stream-compact"
                )
            self._compact_future = self._compact_pool.submit(self._compact_job, victims)
        else:
            try:
                self._compact_job(victims)
            except BaseException:
                # the job's own handler normally clears the in-flight marker,
                # but whatever failed, a dead sync pass must never leave the
                # stream wedged (unable to ever launch another)
                self._compact_pending = None
                raise

    def _compact_job(self, victims: list[Segment]) -> None:
        """Merge the victims' host rows and re-prepare them as one segment
        (possibly on the compaction thread — the expensive prepare runs
        outside the stream lock, so appends/queries proceed against the
        uncompacted layout, which answers identically)."""
        try:
            L = max(v.rows.shape[1] for v in victims)
            R = sum(len(v.rows) for v in victims)
            rows = np.full((R, L), enc.PAD, np.int32)
            at = 0
            for v in victims:
                rows[at:at + len(v.rows), : v.rows.shape[1]] = v.rows
                at += len(v.rows)
            hist = enc.item_support(rows, self.n_items)
            with self._lock:
                # ranks are append-only, so the victims' items (all ranked
                # when their batches arrived) have stable positions even if
                # appends landed since the pass was scheduled
                local_items = self.db.present_in_order(hist)
            merged, _ = self._build_segment(rows, sum(v.n_rows for v in victims),
                                            hist, local_items)
            merged.n_batches = sum(v.n_batches for v in victims)
            merged.tick = max(v.tick for v in victims)
            with self._lock:
                if self.db.replace_segments({v.seg_id for v in victims}, merged):
                    self.stats["compactions"] += 1
                    self.stats["segments_compacted"] += len(victims)
                else:
                    # a victim expired while the merge was in flight;
                    # installing it would resurrect retracted rows
                    self.stats["compact_discarded"] += 1
                self._compact_pending = None
                self._compact_future = None
        except BaseException:
            with self._lock:
                self.stats["compact_errors"] += 1
                self._compact_pending = None
                self._compact_future = None
            raise

    def _reap_compaction(self) -> None:  # lock held; non-blocking
        f = self._compact_future
        if f is not None and f.done():
            # a successful job cleared itself; only a failure lingers here
            exc = f.exception()
            self._compact_future = None
            self._compact_pending = None
            if exc is not None:
                self.stats["compact_errors"] += 1

    def flush(self) -> None:
        """Block until any in-flight compaction has swapped in (or
        failed). Never called with the stream lock held — the job needs
        the lock to swap."""
        f = self._compact_future
        if f is not None:
            try:
                f.result()
            except BaseException:
                pass  # accounted by the job / _reap_compaction
        with self._lock:
            self._reap_compaction()

    def close(self) -> None:
        self.flush()
        if self._compact_pool is not None:
            self._compact_pool.shutdown(wait=True)
            self._compact_pool = None
