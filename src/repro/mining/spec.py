"""MineSpec: the one typed request object every miner accepts.

A spec is frozen and hashable, so engines can key jit-warm miner instances
on it, and benchmarks can sweep thresholds by ``dataclasses.replace``.
Threshold is given *either* as a support fraction (``min_sup``, the paper's
x-axis) or an absolute count (``min_count``); ``resolve(n_rows)`` is the
single place the fraction-to-count conversion lives.
"""
from __future__ import annotations

import dataclasses
import math

PATTERN_KINDS = ("all", "closed", "maximal", "top_rank_k")


@dataclasses.dataclass(frozen=True)
class MineSpec:
    """What to mine, independent of which backend executes it.

    ``algorithm`` names a registered miner (see ``repro.mining.list_miners``).
    ``patterns`` selects a post-pass over the frequent-itemset dict:
    ``all`` (raw), ``closed`` / ``maximal`` / ``top_rank_k`` (the NAFCP /
    MFI / NTK result surfaces from the paper's lineage); ``rank_k`` is the
    k of ``top_rank_k``. The candidate/width knobs only matter to the
    distributed hprepost backend; host miners ignore them.
    """

    algorithm: str = "hprepost"
    min_sup: float | None = None  # support threshold as a fraction of rows
    min_count: int | None = None  # ... or as an absolute transaction count
    max_k: int | None = None  # cap on itemset size (None = unbounded)
    patterns: str = "all"
    rank_k: int = 10
    backend: str = "auto"  # a repro.mining.tune registry name; validated in
    # resolve() against registered_backends()
    candidate_unit: int = 256  # hprepost: candidate buffers, pow2 multiples
    nlist_width: int | None = None  # hprepost: static N-list width (None = auto)
    la_block: int = 512  # hprepost intersect kernel: A-codes per tile
    ly_block: int = 512  # hprepost intersect kernel: Y-codes per tile
    batch_block: int = 8  # hprepost intersect kernel: candidates per program
    partition_candidates: bool = True  # hprepost mode B (PFP groups)
    max_f1: int = 4096  # guard on |F-list|
    max_itemsets: int = 2_000_000
    early_stop: bool = True  # hprepost: early-stopping intersections (host
    # Apriori-closure pruning + in-kernel bound masking where sound); False
    # runs the exact legacy path bit-for-bit
    tune: bool = False  # hprepost: resolve block knobs via the persisted
    # KernelTuner instead of the static la/ly/batch_block fields
    # Service-level QoS, ignored by direct mine() calls: neither field
    # participates in device config / prep keys (execution-orthogonal).
    priority: int = 0  # MiningService: higher priority groups serve first
    deadline_s: float | None = None  # MiningService: drop (DeadlineExceeded)
    # if not *started* within this many seconds of submit

    def __post_init__(self):
        if self.min_sup is not None and self.min_count is not None:
            raise ValueError("MineSpec takes min_sup or min_count, not both")
        if self.min_sup is not None and not (0.0 < self.min_sup <= 1.0):
            raise ValueError(f"min_sup must be in (0, 1], got {self.min_sup}")
        if self.min_count is not None and self.min_count < 1:
            raise ValueError(f"min_count must be >= 1, got {self.min_count}")
        if self.patterns not in PATTERN_KINDS:
            raise ValueError(f"patterns must be one of {PATTERN_KINDS}, got {self.patterns!r}")
        if self.max_k is not None and self.max_k < 1:
            raise ValueError(f"max_k must be >= 1, got {self.max_k}")
        if self.rank_k < 1:
            raise ValueError(f"rank_k must be >= 1, got {self.rank_k}")
        for knob in ("la_block", "ly_block", "batch_block"):
            if getattr(self, knob) < 1:
                raise ValueError(f"{knob} must be >= 1, got {getattr(self, knob)}")
        if self.deadline_s is not None and not self.deadline_s > 0:
            raise ValueError(f"deadline_s must be > 0, got {self.deadline_s}")

    def resolve(self, n_rows: int) -> int:
        """Absolute support threshold for a database of ``n_rows`` rows.

        Ceiling semantics: an itemset is frequent iff ``support / n_rows >=
        min_sup``, i.e. ``support >= ceil(min_sup * n_rows)``. Flooring here
        would admit itemsets *below* the requested fraction (min_sup=0.25
        over 10 rows must demand count 3, not 2). The 1e-9 slack keeps exact
        fractions exact under float noise (``3/7 * 7`` is 3.0000000000000004
        and must resolve to 3, not 4).

        Also the choke point every execution path funnels through before
        any device work, so the backend name is validated here: unknown
        names fail with the registered list instead of silently running
        whatever the old string switch fell through to."""
        from repro.mining.tune import registered_backends

        if self.backend not in registered_backends():
            raise ValueError(
                f"unknown backend {self.backend!r}; registered backends: "
                f"{', '.join(registered_backends())}"
            )
        if self.min_count is not None:
            return int(self.min_count)
        if self.min_sup is None:
            raise ValueError("MineSpec needs min_sup or min_count to mine")
        return max(1, math.ceil(self.min_sup * n_rows - 1e-9))

    def with_(self, **changes) -> "MineSpec":
        """``dataclasses.replace`` that also lets a min_sup spec switch to
        min_count (and vice versa) without tripping the both-set check.

        Explicitly passing ``min_sup=None`` (or ``min_count=None``) does not
        silently clear the other kind; a change that would leave a
        previously-resolvable spec with no threshold at all raises here, at
        construction, instead of deep inside ``mine()``."""
        if changes.get("min_sup") is not None and "min_count" not in changes:
            changes["min_count"] = None
        if changes.get("min_count") is not None and "min_sup" not in changes:
            changes["min_sup"] = None
        new = dataclasses.replace(self, **changes)
        had_threshold = self.min_sup is not None or self.min_count is not None
        if had_threshold and new.min_sup is None and new.min_count is None:
            raise ValueError(
                "with_() cleared the support threshold (min_sup and min_count "
                "are both None now); set the other threshold kind in the same "
                "call, e.g. with_(min_sup=None, min_count=3)"
            )
        return new
