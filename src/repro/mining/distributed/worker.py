"""Worker process: the paper's TaskTracker. Owns a disjoint set of
prepared segments, answers the coordinator's RPCs over one channel.

A worker is deliberately the *streaming map step* extracted into its own
process: ``prep`` is exactly ``repro.mining.stream.build_segment`` (the
same snapshot keys, so a segment built by one worker warm-restores on any
other — the content-addressed ``SnapshotStore`` directory is the shared
filesystem the paper assumes of HDFS), and ``wave`` runs the fused
intersect kernel over the worker's segments via the same
``LocalSegmentExecutor`` the single-process miner uses, replying with the
per-candidate support sums over *its* partitions — its partial reduce.

The serve loop is single-threaded request/reply; the coordinator
pipelines by sending wave l+1 before collecting wave l's reply, and the
FIFO channel preserves matching. Deterministic fault injection
(``inject``) arms process death on the nth matching op — the chaos tests'
and ``make dist-smoke``'s worker-kill mechanism, mirroring
``repro.fault.failures``.
"""
from __future__ import annotations

import os

import numpy as np

from repro.mining.distributed import protocol as pr
from repro.mining.distributed.transport import dial


class _FaultPlan:
    """Die on the nth request whose op matches (before serving it, or
    right after the reply flushes)."""

    def __init__(self, op: str, after: int = 0, when: str = "before"):
        self.op = op
        self.remaining = int(after)
        self.when = when

    def matches(self, op: str) -> bool:
        if op != self.op:
            return False
        if self.remaining > 0:
            self.remaining -= 1
            return False
        return True


class Worker:
    """One TaskTracker: segments, wave state, and the serve loop."""

    def __init__(self, worker_id: int, *, n_items: int, spec, row_pad: int,
                 snapshot_dir: str | None):
        # imports deferred past process start so spawn cost is visible in
        # one place; jax initializes here, inside the worker process
        from repro.mining.engine import MiningEngine

        self.worker_id = worker_id
        self.n_items = int(n_items)
        self.row_pad = int(row_pad)
        self.engine = MiningEngine(snapshot_dir=snapshot_dir)
        self._fe = self.engine.frontend("hprepost")
        self.device_cfg = self._fe._device_config(spec)
        self.miner = self._fe.miner_for(spec)
        self.segments: dict[int, object] = {}  # seg_id -> stream.Segment
        self._executor = None
        self._query_segs: list = []
        self._fault: _FaultPlan | None = None
        self.stats = {
            "seg_prepares": 0,
            "seg_snapshot_hits": 0, "seg_snapshot_misses": 0,
            "seg_snapshot_spill_failures": 0,
            "preps": 0, "waves": 0, "queries": 0,
        }

    # ------------------------------------------------------------------ ops
    def _op_prep(self, msg):
        from repro.mining.stream.stream import build_segment

        from repro.core import encoding as enc

        rows = np.asarray(msg["rows"], np.int32)
        local_items = np.asarray(msg["local_items"], np.int32)
        hist = enc.item_support(rows, self.n_items)
        seg, source = build_segment(
            self.miner, self.engine.snapshot_store, self.n_items,
            rows, int(msg["n_rows_real"]), hist, local_items,
            seg_id=int(msg["seg_id"]), device_cfg=self.device_cfg,
            row_pad=self.row_pad, stats=self.stats,
        )
        self.segments[seg.seg_id] = seg
        self.stats["preps"] += 1
        return {
            "C": np.asarray(seg.prepared.C),
            "source": source,
            "nbytes": int(seg.nbytes),
            "prep_bytes": int(seg.prepared.prep_bytes),
        }

    def _op_drop(self, msg):
        for sid in msg["seg_ids"]:
            self.segments.pop(int(sid), None)
        return {}

    def _op_query_begin(self, msg):
        from repro.core.hprepost import LocalSegmentExecutor
        from repro.mining.stream.segmented import segment_handles

        order_arr = np.asarray(msg["items"], np.int32)
        self._query_segs = [self.segments[sid] for sid in sorted(self.segments)]
        handles = segment_handles(self._query_segs, order_arr)
        self._executor = LocalSegmentExecutor(self.miner, handles)
        self._executor.begin()
        self.stats["queries"] += 1
        return {"segments": len(handles)}

    def _op_wave(self, msg):
        ex = self._executor
        if ex is None:
            raise RuntimeError("wave before query_begin")
        token = ex.dispatch(
            int(msg["level"]), msg["parent_arr"], msg["base_idx"], msg["q_idx"],
            bool(msg["use_local"]), int(msg.get("stop_count", 0)),
        )
        sups = ex.collect(token)
        self.stats["waves"] += 1
        return {"sups": sups, "state_bytes": int(ex.state_bytes)}

    def _op_query_end(self, msg):
        self._executor = None
        self._query_segs = []
        return {}

    def _op_stats(self, msg):
        return {
            "stats": dict(self.stats),
            "segments": sorted(self.segments),
            "bytes": sum(s.nbytes for s in self.segments.values()),
        }

    def _op_inject(self, msg):
        self._fault = _FaultPlan(
            msg["fault_op"], after=int(msg.get("after", 0)),
            when=msg.get("when", "before"),
        )
        return {}

    # ------------------------------------------------------------- serving
    def serve(self, chan, *, idle_timeout_s: float = 30.0) -> None:
        handlers = {
            pr.OP_PREP: self._op_prep,
            "drop": self._op_drop,
            pr.OP_QUERY_BEGIN: self._op_query_begin,
            pr.OP_WAVE: self._op_wave,
            pr.OP_QUERY_END: self._op_query_end,
            pr.OP_PING: lambda msg: {},
            pr.OP_STATS: self._op_stats,
            pr.OP_INJECT: self._op_inject,
        }
        parent = os.getppid()
        while True:
            # idle-poll rather than block forever: the bounded recv
            # timeout lets a silently-dropped coordinator surface through
            # TCP keepalive as ConnectionClosed (the worker then exits via
            # worker_main) and gives us a beat to notice our parent died
            # without ever sending a FIN (kill -9 on the whole process
            # group leaves no one to close the socket; reparenting is the
            # one signal that always arrives)
            try:
                msg = chan.recv(idle_timeout_s)
            except TimeoutError:
                if os.getppid() != parent:  # reparented: coordinator is gone
                    return
                continue
            op = msg["op"]
            die_after = False
            if self._fault is not None and self._fault.matches(op):
                if self._fault.when == "before":
                    os._exit(1)  # SIGKILL-equivalent: no reply, no cleanup
                die_after = True
            if op == pr.OP_SHUTDOWN:
                chan.send({"seq": msg["seq"], "ok": True})
                return
            try:
                body = handlers[op](msg)
                reply = {"seq": msg["seq"], "ok": True, **body}
            except Exception as e:  # report, keep serving
                reply = {"seq": msg["seq"], "ok": False, "error": repr(e)}
            chan.send(reply)
            if die_after:
                os._exit(1)


def worker_main(address, worker_id: int, n_items: int, spec, row_pad: int,
                snapshot_dir: str | None) -> None:
    """Process entry point (multiprocessing spawn target): dial the
    coordinator, introduce ourselves, serve until shutdown or death."""
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    chan = dial(tuple(address))
    chan.send({"op": pr.OP_HELLO, "worker_id": worker_id, "pid": os.getpid()})
    w = Worker(worker_id, n_items=n_items, spec=spec, row_pad=row_pad,
               snapshot_dir=snapshot_dir)
    try:
        w.serve(chan)
    except pr.ConnectionClosed:
        pass  # coordinator went away: nothing to serve
    finally:
        chan.close()
