"""Wire protocol for the coordinator <-> worker RPC: length-prefixed
pickle frames over a stream socket, plus the op vocabulary.

Framing is the classic 8-byte big-endian length header followed by a
pickle payload (numpy arrays ride pickle's buffer protocol — no
re-encoding). Every request carries a per-worker monotonically increasing
``seq``; the worker echoes it in the reply. That one field is what makes
failover clean: when a query aborts mid-pipeline (another worker died),
surviving workers may still owe replies for waves the coordinator will
never use — the next request's reply is found by *skipping* frames with a
smaller ``seq`` instead of desynchronizing the channel.

Ops (all request dicts carry ``op`` and ``seq``):

  - ``hello``     worker -> coordinator, once, after dialing in
  - ``prep``      build one segment (snapshot-first) from rows + imposed order
  - ``query_begin``  reset wave state; carries the global rank->item order
  - ``wave``      one planned wave (parent/base/q index arrays); reply sums
                  the worker's per-segment supports — its partial reduce
  - ``query_end`` drop wave state
  - ``ping``      heartbeat
  - ``stats``     worker telemetry (seg_prepares / snapshot hits / ...)
  - ``inject``    arm a deterministic fault (die on the nth matching op)
  - ``shutdown``  orderly exit
"""
from __future__ import annotations

import pickle
import socket
import struct

_HEADER = struct.Struct(">Q")
MAX_FRAME = 1 << 34  # 16 GiB: sanity bound against corrupt headers

OP_HELLO = "hello"
OP_PREP = "prep"
OP_QUERY_BEGIN = "query_begin"
OP_WAVE = "wave"
OP_QUERY_END = "query_end"
OP_PING = "ping"
OP_STATS = "stats"
OP_INJECT = "inject"
OP_SHUTDOWN = "shutdown"


class ProtocolError(RuntimeError):
    """Malformed frame or out-of-order reply."""


class ConnectionClosed(ProtocolError):
    """Peer went away (EOF / reset) — the fast worker-death signal."""


def send_msg(sock: socket.socket, obj) -> None:
    payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    sock.sendall(_HEADER.pack(len(payload)) + payload)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    while n:
        try:
            chunk = sock.recv(min(n, 1 << 20))
        except (ConnectionResetError, BrokenPipeError) as e:
            raise ConnectionClosed(str(e)) from e
        if not chunk:
            raise ConnectionClosed("peer closed the connection")
        chunks.append(chunk)
        n -= len(chunk)
    return b"".join(chunks)


def recv_msg(sock: socket.socket):
    (n,) = _HEADER.unpack(_recv_exact(sock, _HEADER.size))
    if n > MAX_FRAME:
        raise ProtocolError(f"frame of {n} bytes exceeds MAX_FRAME")
    return pickle.loads(_recv_exact(sock, n))
