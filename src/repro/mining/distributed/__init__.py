"""Distributed multi-host mining: the paper's MapReduce roles as live
processes.

The source paper runs HPrepost on Hadoop: a **JobTracker** schedules map
tasks (per-partition PPC-tree / N-list construction) onto
**TaskTrackers**, each map output stays node-local, and the reduce sums
per-candidate supports across nodes — exact because the transaction
partitions are disjoint and support is additive over them. This package
makes that split literal over the PR 5 streaming layer:

  =====================  ====================================================
  Paper / Hadoop role     Here
  =====================  ====================================================
  JobTracker              ``coordinator.DistributedMiner`` — owns the global
                          stream item order, summed F1 counts and F2 matrix,
                          plans every candidate wave once, broadcasts it,
                          sums the per-worker supports before thresholding,
                          and replays queries after a failover.
  TaskTracker             ``worker.Worker`` (own process, own jax runtime)
                          — builds and owns a disjoint set of prepared
                          segments, answers wave RPCs with its partial
                          support sums (its local reduce contribution).
  Task scheduling         ``placement`` — byte-balanced greedy bin-packing
                          of segments onto workers, best-fit-decreasing
                          re-planning when the topology changes.
  Heartbeats /            coordinator heartbeat thread + RPC failure
  speculative re-exec     detection; a dead worker's segments re-place onto
                          survivors and an in-flight query replays.
  HDFS                    the shared content-addressed ``SnapshotStore``
                          directory: segments built by any worker
                          warm-restore on any other with zero prep
                          recompute (``seg_prepares == 0`` on reassignment).
  Shuffle / wire          ``protocol`` + ``transport`` — length-prefixed
                          pickle frames over loopback TCP, FIFO per worker,
                          waves pipelined one ahead.
  =====================  ====================================================

Exactness is inherited, not re-proven: the coordinator drives the same
``HPrepostMiner.mine_prepared_segments`` planning loop as the
single-process streaming path, with only the executor swapped
(``LocalSegmentExecutor`` -> ``RemoteSegmentExecutor``), so distributed
answers are bit-identical to ``StreamingMiner`` on the same rows.
"""
from repro.mining.distributed.coordinator import (
    DistributedMiner,
    NoLiveWorkers,
    RemoteSegmentExecutor,
    WorkerDied,
)
from repro.mining.distributed.placement import choose_worker, replan

__all__ = [
    "DistributedMiner",
    "NoLiveWorkers",
    "RemoteSegmentExecutor",
    "WorkerDied",
    "choose_worker",
    "replan",
]
