"""Socket plumbing for the RPC layer: a ``Listener`` the coordinator
binds on loopback, ``dial`` for workers to connect back, and a ``Channel``
wrapping one connected socket with framed send/recv (``protocol``).

Loopback TCP rather than multiprocessing pipes on purpose: the framing +
dial-in shape is exactly what a multi-host deployment needs — moving a
worker to another machine changes the address, not the protocol.
"""
from __future__ import annotations

import socket
import threading
import time

from repro.fault import failures
from repro.mining.distributed.protocol import ConnectionClosed, recv_msg, send_msg


def _harden(sock: socket.socket) -> None:
    """Socket-level liveness: TCP_NODELAY (small RPC frames must not sit
    in Nagle buffers) plus SO_KEEPALIVE with aggressive probe timing where
    the platform exposes it, so a silently-dropped peer (power loss,
    network partition — no FIN ever arrives) surfaces as an ``OSError`` on
    the next blocking recv instead of hanging forever. The TCP_KEEP*
    constants are Linux-specific; elsewhere keepalive runs with kernel
    defaults (hours), and the per-call recv timeouts above carry liveness."""
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    sock.setsockopt(socket.SOL_SOCKET, socket.SO_KEEPALIVE, 1)
    for opt, val in (("TCP_KEEPIDLE", 30), ("TCP_KEEPINTVL", 10), ("TCP_KEEPCNT", 3)):
        if hasattr(socket, opt):
            try:
                sock.setsockopt(socket.IPPROTO_TCP, getattr(socket, opt), val)
            except OSError:
                pass


class Channel:
    """One connected peer. ``send`` is locked (heartbeat and caller
    threads may both write); ``recv`` is single-consumer by design.

    Both directions carry chaos points (``rpc.send`` / ``rpc.recv``): an
    installed injector can fail any frame with any exception type, which
    is how the soak proves the coordinator's timeout/retry/failover
    ladder without real packet loss."""

    def __init__(self, sock: socket.socket):
        self.sock = sock
        _harden(self.sock)
        self._send_lock = threading.Lock()
        self._closed = False

    def send(self, obj) -> None:
        with self._send_lock:
            if self._closed:
                raise ConnectionClosed("channel closed")
            failures.fire("rpc.send")  # chaos: frame lost on the way out
            try:
                send_msg(self.sock, obj)
            except (ConnectionResetError, BrokenPipeError, OSError) as e:
                raise ConnectionClosed(str(e)) from e

    def recv(self, timeout: float | None = None):
        failures.fire("rpc.recv")  # chaos: reply lost / delayed past timeout
        self.sock.settimeout(timeout)
        try:
            return recv_msg(self.sock)
        except socket.timeout as e:
            raise TimeoutError("rpc reply timed out") from e
        except OSError as e:
            raise ConnectionClosed(str(e)) from e

    def close(self) -> None:
        self._closed = True
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass


class Listener:
    """Coordinator-side accept socket on an OS-assigned loopback port."""

    def __init__(self, host: str = "127.0.0.1"):
        self.sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self.sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self.sock.bind((host, 0))
        self.sock.listen(64)
        self.address: tuple[str, int] = self.sock.getsockname()

    def accept(self, timeout: float | None = None) -> Channel:
        self.sock.settimeout(timeout)
        try:
            conn, _ = self.sock.accept()
        except socket.timeout as e:
            raise TimeoutError("no worker dialed in before the deadline") from e
        return Channel(conn)

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


def dial(address: tuple[str, int], *, timeout: float = 30.0) -> Channel:
    """Worker-side connect with retry (the coordinator's listener is up
    before workers spawn, so retries only cover transient refusals)."""
    deadline = time.monotonic() + timeout
    while True:
        try:
            sock = socket.create_connection(address, timeout=5.0)
            sock.settimeout(None)
            return Channel(sock)
        except OSError:
            if time.monotonic() >= deadline:
                raise
            time.sleep(0.05)
