"""Socket plumbing for the RPC layer: a ``Listener`` the coordinator
binds on loopback, ``dial`` for workers to connect back, and a ``Channel``
wrapping one connected socket with framed send/recv (``protocol``).

Loopback TCP rather than multiprocessing pipes on purpose: the framing +
dial-in shape is exactly what a multi-host deployment needs — moving a
worker to another machine changes the address, not the protocol.
"""
from __future__ import annotations

import socket
import threading
import time

from repro.mining.distributed.protocol import ConnectionClosed, recv_msg, send_msg


class Channel:
    """One connected peer. ``send`` is locked (heartbeat and caller
    threads may both write); ``recv`` is single-consumer by design."""

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._send_lock = threading.Lock()
        self._closed = False

    def send(self, obj) -> None:
        with self._send_lock:
            if self._closed:
                raise ConnectionClosed("channel closed")
            try:
                send_msg(self.sock, obj)
            except (ConnectionResetError, BrokenPipeError, OSError) as e:
                raise ConnectionClosed(str(e)) from e

    def recv(self, timeout: float | None = None):
        self.sock.settimeout(timeout)
        try:
            return recv_msg(self.sock)
        except socket.timeout as e:
            raise TimeoutError("rpc reply timed out") from e
        except OSError as e:
            raise ConnectionClosed(str(e)) from e

    def close(self) -> None:
        self._closed = True
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass


class Listener:
    """Coordinator-side accept socket on an OS-assigned loopback port."""

    def __init__(self, host: str = "127.0.0.1"):
        self.sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self.sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self.sock.bind((host, 0))
        self.sock.listen(64)
        self.address: tuple[str, int] = self.sock.getsockname()

    def accept(self, timeout: float | None = None) -> Channel:
        self.sock.settimeout(timeout)
        try:
            conn, _ = self.sock.accept()
        except socket.timeout as e:
            raise TimeoutError("no worker dialed in before the deadline") from e
        return Channel(conn)

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


def dial(address: tuple[str, int], *, timeout: float = 30.0) -> Channel:
    """Worker-side connect with retry (the coordinator's listener is up
    before workers spawn, so retries only cover transient refusals)."""
    deadline = time.monotonic() + timeout
    while True:
        try:
            sock = socket.create_connection(address, timeout=5.0)
            sock.settimeout(None)
            return Channel(sock)
        except OSError:
            if time.monotonic() >= deadline:
                raise
            time.sleep(0.05)
