"""Coordinator: the paper's JobTracker. Plans every query once against
the global F-lists, broadcasts waves to the workers, sums their partial
supports, and owns placement + failover.

``DistributedMiner`` is a drop-in for ``StreamingMiner`` behind
``MiningEngine.distribute`` — same ``append(rows) -> dict`` /
``mine(spec) -> MineResult`` surface, so the ``MiningService`` submit
path is unchanged for callers. Internally:

  - global state (stream item ranks, summed counts, summed F2 matrix,
    row totals) lives in a ``SegmentedDB`` used *without* device
    segments — the coordinator holds plans, never N-lists;
  - each appended batch is placed on one worker (byte-balanced greedy,
    ``placement``) which builds the segment via the shared
    ``build_segment`` (snapshot-first against the shared store dir);
  - ``mine`` runs ``HPrepostMiner.mine_prepared_segments`` with a
    ``RemoteSegmentExecutor``: the identical planning loop the local
    path uses, with wave execution swapped for a broadcast + reduce
    over workers — results are bit-identical by construction;
  - failover: a dead worker's segments (the coordinator retains every
    batch's host rows, its append log) are re-placed over survivors,
    who warm-restore them from the content-addressed snapshots with
    zero prep recompute; an in-flight query is then replayed from
    level 2 — deterministic planning makes the retry bit-identical.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import multiprocessing as mp
import os
import threading
import time

import numpy as np

from repro.checkpoint.atomic import (
    fsync_write, replace_file_atomic, save_array, write_dir_atomic,
)
from repro.core import encoding as enc
from repro.fault import failures
from repro.mining.distributed import placement
from repro.mining.distributed import protocol as pr
from repro.mining.distributed.transport import Listener
from repro.mining.distributed.worker import worker_main
from repro.mining.engine import MiningEngine
from repro.mining.result import MineResult
from repro.mining.spec import MineSpec
from repro.mining.stream.segmented import SegmentedDB
from repro.mining.stream.spec import StreamSpec

_digest = MiningEngine._digest


class WorkerDied(RuntimeError):
    """One worker stopped answering (EOF, reset, or reply timeout).

    ``timeout`` distinguishes a reply that never came (retryable: resend
    with a fresh seq; a late duplicate reply is skipped as a stale frame)
    from a connection that is provably gone (resending cannot help)."""

    def __init__(self, worker_id: int, why: str = "", *, timeout: bool = False):
        super().__init__(f"worker {worker_id} died" + (f": {why}" if why else ""))
        self.worker_id = worker_id
        self.timeout = timeout


class NoLiveWorkers(RuntimeError):
    """Every worker is gone; the database cannot answer waves."""


@dataclasses.dataclass
class WorkerHandle:
    wid: int
    chan: object
    proc: object
    alive: bool = True
    next_seq: int = 0


@dataclasses.dataclass
class SegmentMeta:
    """Coordinator-side record of one placed segment: enough to re-prep
    it anywhere (host rows + imposed item order), never device state."""

    seg_id: int
    rows: np.ndarray  # raw (unpadded) host batch — the append log entry
    n_rows_real: int
    local_items: np.ndarray
    worker: int
    seq: int = 0  # append-order position, shared with empty-batch entries
    nbytes: int = 0
    prep_bytes: int = 0
    digest: str = ""
    # the worker-reported local F2 block, kept so window expiry can
    # subtract it from the global C exactly (the retraction half of the
    # reduce) without a round-trip
    C_block: np.ndarray | None = None


class RemoteSegmentExecutor:
    """Wave execution over RPC: ``dispatch`` broadcasts one planned wave
    to every participating worker without blocking (the coordinator's
    pipelined planner keeps running), ``collect`` gathers the per-worker
    support sums and adds them — the cross-machine reduce."""

    def __init__(self, coord: "DistributedMiner", items: np.ndarray):
        self.coord = coord
        self.items = items
        owners = {m.worker for m in coord._segments.values()}
        self.workers = [w for w in coord._live() if w.wid in owners]
        self.n_segments = len(coord._segments)
        self.state_bytes = 0

    def begin(self) -> None:
        c = self.coord
        seqs = [
            (w, c._send(w, {"op": pr.OP_QUERY_BEGIN, "items": self.items}))
            for w in self.workers
        ]
        for w, seq in seqs:
            c._expect(w, seq)

    def dispatch(self, level, parent_arr, base_idx, q_idx, use_local,
                 stop_count=0):
        # stop_count rides along for contract parity with
        # LocalSegmentExecutor; the planner always passes 0 for segmented
        # mining (per-worker supports are partial until the cross-machine
        # reduce, so an in-kernel stop would be unsound)
        c = self.coord
        msg = {
            "op": pr.OP_WAVE, "level": int(level), "parent_arr": parent_arr,
            "base_idx": base_idx, "q_idx": q_idx, "use_local": bool(use_local),
            "stop_count": int(stop_count),
        }
        c._miner.stage_counters["waves"] += 1
        c._miner.stage_counters["seg_waves"] = (
            c._miner.stage_counters.get("seg_waves", 0) + self.n_segments
        )
        t_disp = time.perf_counter()
        return [(w, c._send(w, msg)) for w in self.workers], len(parent_arr), t_disp

    def collect(self, token) -> np.ndarray:
        pairs, cpad, t_disp = token
        total = np.zeros(cpad, np.int64)
        state_bytes = 0
        tel = self.coord.engine.telemetry
        name = self.coord.name
        for w, seq in pairs:
            rep = self.coord._expect(w, seq)
            # dispatch -> reply-consumed latency per worker: the raw
            # material for straggler detection. Collection order skews a
            # later worker's reading upward by at most the time spent
            # summing earlier replies (its reply was already buffered).
            tel.histogram(f"dist.{name}.worker{w.wid}.wave_rpc_s").record(
                time.perf_counter() - t_disp
            )
            total += np.asarray(rep["sups"], np.int64)
            state_bytes += int(rep.get("state_bytes", 0))
        self.state_bytes = state_bytes
        return total

    def finish(self) -> None:
        for w in self.workers:
            if w.alive:
                try:
                    self.coord._request(w, {"op": pr.OP_QUERY_END})
                except WorkerDied:
                    pass  # the next op will notice and fail over


class DistributedMiner:
    """One distributed, append-only mining database: N spawned worker
    processes behind a ``StreamingMiner``-shaped front."""

    def __init__(self, engine, n_items: int, *, workers: int = 2,
                 spec: MineSpec | None = None, stream_spec: StreamSpec | None = None,
                 snapshot_dir: str | None = None, heartbeat_s: float = 0.0,
                 rpc_timeout_s: float = 180.0, spawn_timeout_s: float = 120.0,
                 rpc_attempts: int = 3, rpc_backoff_s: float = 0.05,
                 restart_budget: int = 0, checkpoint_dir: str | None = None,
                 name: str = "default"):
        if workers < 1:
            raise ValueError(f"need at least 1 worker, got {workers}")
        self.engine = engine
        self.name = name
        self.n_items = int(n_items)
        self.spec = spec if spec is not None else MineSpec()
        self.stream_spec = stream_spec if stream_spec is not None else StreamSpec()
        self._fe = engine.frontend("hprepost")
        self._device_cfg = self._fe._device_config(self.spec)
        # planner only: the coordinator never runs wave kernels itself
        self._miner = self._fe.miner_for(self.spec)
        if self._miner._Mb != 1:
            # workers always run their own single-host mesh; a coordinator
            # planning model-partitioned slot layouts would disagree with
            # how workers interpret the wave's local parent rows
            raise ValueError(
                "distributed mining plans in an unpartitioned candidate "
                "space; use a 1x1 coordinator mesh (model shards stay "
                "inside each worker)"
            )
        if snapshot_dir is None and engine.snapshot_store is not None:
            snapshot_dir = engine.snapshot_store.dir
        self.snapshot_dir = snapshot_dir
        self.rpc_timeout_s = float(rpc_timeout_s)
        self.rpc_attempts = max(1, int(rpc_attempts))
        self.rpc_backoff_s = float(rpc_backoff_s)
        self.heartbeat_s = float(heartbeat_s)
        self.spawn_timeout_s = float(spawn_timeout_s)
        # workers re-spawned after death, total, before the pool is allowed
        # to shrink permanently. Default 0 = PR 6 behavior (tests assert a
        # killed worker stays gone); production serves pass a real budget.
        self.restart_budget = int(restart_budget)
        self.checkpoint_dir = checkpoint_dir
        if self.stream_spec.decay < 1.0:
            raise ValueError(
                "decayed supports are a single-process stream mode; "
                "distributed databases mine the exact integer path only"
            )
        self.db = SegmentedDB(n_items)  # global ranks/counts/C/n_rows only
        self._segments: dict[int, SegmentMeta] = {}
        self._next_seg = 0
        self._append_seq = 0  # append-order clock over segments AND empties
        # (seq, row count) of segment-less (all-PAD) appends: their rows
        # joined db.n_rows, so sliding windows must age them out too
        self._empty_rows: list[list[int]] = []
        self._expired: set[int] = set()  # window-expired seg ids (log stays)
        self.rows_appended = 0  # monotone: never decremented by expiry
        self._op_lock = threading.RLock()
        from repro.mining.continuous import StandingRegistry

        self.standing = StandingRegistry(self)
        self.stats = {
            "appends": 0, "queries": 0, "empty_batches": 0,
            "workers_spawned": int(workers), "workers_lost": 0,
            "failovers": 0, "query_retries": 0,
            "reassigned_segments": 0, "reassign_snapshot_restores": 0,
            "reassign_rebuilds": 0,
            "rpc_timeouts": 0, "rpc_retries": 0,
            "respawns": 0, "respawn_failures": 0,
            "restored_appends": 0, "checkpoint_failures": 0,
            # sliding-window churn + standing-query delivery telemetry
            "expires": 0, "expired_segments": 0, "expired_rows": 0,
            "expire_errors": 0,
            "standing_queries": 0, "diffs_delivered": 0, "diff_errors": 0,
            "diff_latency_s_total": 0.0, "last_diff_latency_s": 0.0,
            "seed_pruned_candidates": 0,
        }
        self._listener = Listener()
        self._workers: dict[int, WorkerHandle] = {}
        self._spawn_workers(workers, spawn_timeout_s)
        self._stop = threading.Event()
        self._monitor = None
        if self.heartbeat_s > 0:
            self._monitor = threading.Thread(
                target=self._monitor_loop, name=f"dist-hb-{name}", daemon=True
            )
            self._monitor.start()
        if self.checkpoint_dir is not None:
            self._restore_checkpoint()

    # ------------------------------------------------------------ lifecycle
    def _spawn_procs(self, wids: list[int]):
        """Start worker processes for ``wids`` (spawn, not fork: each
        worker initializes its own jax runtime)."""
        ctx = mp.get_context("spawn")
        src_root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
        path = os.environ.get("PYTHONPATH", "")
        if src_root not in path.split(os.pathsep):
            os.environ["PYTHONPATH"] = (
                src_root + (os.pathsep + path if path else "")
            )
        procs = {}
        for wid in wids:
            p = ctx.Process(
                target=worker_main,
                args=(self._listener.address, wid, self.n_items, self.spec,
                      self.stream_spec.row_pad, self.snapshot_dir),
                daemon=True, name=f"mine-worker-{wid}",
            )
            p.start()
            procs[wid] = p
        return procs

    def _accept_hellos(self, procs: dict, spawn_timeout_s: float) -> None:
        deadline = time.monotonic() + spawn_timeout_s
        for _ in range(len(procs)):
            chan = self._listener.accept(max(deadline - time.monotonic(), 0.1))
            hello = chan.recv(max(deadline - time.monotonic(), 0.1))
            if hello.get("op") != pr.OP_HELLO:
                raise pr.ProtocolError(f"expected hello, got {hello!r}")
            wid = int(hello["worker_id"])
            self._workers[wid] = WorkerHandle(wid=wid, chan=chan, proc=procs[wid])

    def _spawn_workers(self, n: int, spawn_timeout_s: float) -> None:
        self._accept_hellos(self._spawn_procs(list(range(n))), spawn_timeout_s)

    def close(self) -> None:
        self._stop.set()
        if self._monitor is not None:
            self._monitor.join(timeout=5)
        for w in self._workers.values():
            if w.alive:
                try:
                    self._request(w, {"op": pr.OP_SHUTDOWN}, timeout=5)
                except Exception:
                    pass
            w.chan.close()
        for w in self._workers.values():
            w.proc.join(timeout=5)
            if w.proc.is_alive():
                w.proc.kill()
                w.proc.join(timeout=5)
        self._listener.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # --------------------------------------------------------------- rpc
    def _live(self) -> list[WorkerHandle]:
        return [w for w in self._workers.values() if w.alive]

    def _loads(self) -> dict[int, int]:
        loads = {w.wid: 0 for w in self._live()}
        for m in self._segments.values():
            if m.worker in loads:
                loads[m.worker] += m.nbytes
        return loads

    def _send(self, w: WorkerHandle, body: dict) -> int:
        if not w.alive:
            raise WorkerDied(w.wid, "already marked dead")
        msg = dict(body)
        msg["seq"] = w.next_seq
        w.next_seq += 1
        try:
            w.chan.send(msg)
        except (pr.ConnectionClosed, OSError) as e:
            raise WorkerDied(w.wid, str(e)) from e
        return msg["seq"]

    def _expect(self, w: WorkerHandle, seq: int, timeout: float | None = None):
        """The reply for ``seq``, skipping stale frames: after an aborted
        (failed-over) query — or a timed-out-and-retried request — a
        worker may still flush replies for seqs this coordinator stopped
        caring about."""
        timeout = self.rpc_timeout_s if timeout is None else timeout
        while True:
            try:
                rep = w.chan.recv(timeout)
            except TimeoutError as e:
                raise WorkerDied(w.wid, str(e), timeout=True) from e
            except (pr.ConnectionClosed, pr.ProtocolError) as e:
                raise WorkerDied(w.wid, str(e)) from e
            got = rep.get("seq", -1)
            if got < seq:
                continue  # stale reply from an aborted pipeline
            if got > seq:
                raise pr.ProtocolError(
                    f"worker {w.wid}: reply seq {got} overtook expected {seq}"
                )
            if not rep.get("ok", False):
                raise RuntimeError(f"worker {w.wid} op failed: {rep.get('error')}")
            return rep

    def _request(self, w: WorkerHandle, body: dict, timeout: float | None = None):
        """One request/reply exchange, with bounded exponential-backoff
        retries on reply *timeouts* (``rpc_attempts`` sends total).

        Only request/reply ops route through here — ping, stats, prep,
        inject, drop, query_end, shutdown — and all of them are idempotent
        on the worker (a re-prep rebuilds the same content-addressed
        segment). A retry resends under a fresh seq, so a late duplicate
        reply for the timed-out send is discarded by ``_expect``'s
        stale-frame skip. Pipelined wave traffic deliberately does NOT
        retry: ``dispatch`` advances per-segment merged state on the
        worker, so the only sound recovery for a lost wave is failover +
        full deterministic query replay (see ``mine``). A dead connection
        (reset/EOF) is also never retried — resending cannot help."""
        attempt = 0
        while True:
            try:
                return self._expect(w, self._send(w, body), timeout)
            except WorkerDied as e:
                if not e.timeout:
                    raise
                self.stats["rpc_timeouts"] += 1
                attempt += 1
                if attempt >= self.rpc_attempts:
                    raise
                self.stats["rpc_retries"] += 1
                time.sleep(min(self.rpc_backoff_s * (2 ** (attempt - 1)), 2.0))

    # ------------------------------------------------------------ failover
    def _mark_dead(self, wid: int) -> None:
        w = self._workers[wid]
        if not w.alive:
            return
        w.alive = False
        w.chan.close()
        self.stats["workers_lost"] += 1

    def _failover(self, wid: int) -> None:
        """Topology change: retire ``wid``, re-place its segments over the
        survivors (best-fit decreasing), each restored snapshot-first —
        same build_segment, same key, so zero recompute when the store
        holds it. Survivor deaths during the re-place loop fold in.

        With a ``restart_budget``, a replacement worker is then spawned
        and the displaced segments migrate back onto it (PR 6's failover
        in reverse, also snapshot-first) — the pool only shrinks once the
        budget is spent."""
        self._mark_dead(wid)
        self.stats["failovers"] += 1
        displaced: list[int] = []
        while True:
            orphans = [
                m for m in self._segments.values()
                if not self._workers[m.worker].alive
            ]
            if not orphans:
                break
            loads = self._loads()
            if not loads:
                if self._respawn() is None:
                    raise NoLiveWorkers(
                        f"all {self.stats['workers_spawned']} workers are gone"
                    )
                continue  # the fresh worker re-preps the orphans directly
            plan = placement.replan([(m.seg_id, m.nbytes) for m in orphans], loads)
            try:
                for seg_id in sorted(plan):
                    m = self._segments[seg_id]
                    rep = self._prep_on(self._workers[plan[seg_id]], m)
                    m.worker = plan[seg_id]
                    displaced.append(seg_id)
                    self.stats["reassigned_segments"] += 1
                    if rep["source"] == "snapshot":
                        self.stats["reassign_snapshot_restores"] += 1
                    else:
                        self.stats["reassign_rebuilds"] += 1
                break
            except WorkerDied as e:
                self._mark_dead(e.worker_id)
                continue
        new_wid = self._respawn()
        if new_wid is not None:
            self._rebalance_to(new_wid, displaced)
        self._checkpoint_manifest()  # placement map changed

    # ------------------------------------------------------------- respawn
    def _respawn(self) -> int | None:
        """Spawn one replacement worker (fresh wid — seq state and process
        handles never alias a dead worker's). None when the budget is
        spent or the spawn itself failed."""
        if self.restart_budget <= 0:
            return None
        self.restart_budget -= 1
        wid = max(self._workers) + 1
        try:
            self._accept_hellos(self._spawn_procs([wid]), self.spawn_timeout_s)
        except Exception:
            self.stats["respawn_failures"] += 1
            return None
        self.stats["respawns"] += 1
        self.stats["workers_spawned"] += 1
        return wid

    def _rebalance_to(self, wid: int, seg_ids: list[int]) -> None:
        """Migrate ``seg_ids`` onto worker ``wid``: re-prep there
        (snapshot-first — the store still holds every segment the dead
        worker built, so this is a restore, not a rebuild), then drop the
        temporary copy from the survivor that carried it. Any failure
        leaves the segment where it was — correctness never depends on
        the migration, only balance does. A death mid-migration (of the
        new worker or of a survivor we ask to drop) routes back through
        ``_failover``, which re-places every dead owner's segments — a
        segment is never left on a worker nobody serves from."""
        w = self._workers[wid]
        for seg_id in seg_ids:
            m = self._segments.get(seg_id)
            if m is None:
                continue
            old = m.worker
            try:
                rep = self._prep_on(w, m)
            except WorkerDied:
                self.stats["respawn_failures"] += 1
                # the fresh worker may already own earlier migrations:
                # full repair, not just a mark (recursion is bounded by
                # the restart budget + live worker count)
                self._failover(wid)
                return
            m.worker = wid
            if rep["source"] == "snapshot":
                self.stats["reassign_snapshot_restores"] += 1
            else:
                self.stats["reassign_rebuilds"] += 1
            old_w = self._workers.get(old)
            if old_w is not None and old_w.alive:
                try:
                    self._request(old_w, {"op": "drop", "seg_ids": [seg_id]})
                except WorkerDied as e:
                    self._failover(e.worker_id)

    def _prep_on(self, w: WorkerHandle, m: SegmentMeta):
        return self._request(w, {
            "op": pr.OP_PREP, "seg_id": m.seg_id, "rows": m.rows,
            "local_items": m.local_items, "n_rows_real": m.n_rows_real,
        })

    def kill_worker(self, wid: int) -> None:
        """Hard-kill one worker process (chaos / smoke hook). The death is
        *not* marked here — detection is the coordinator's job, via the
        next RPC failure or a missed heartbeat."""
        self._workers[wid].proc.kill()
        self._workers[wid].proc.join(timeout=10)

    def inject_fault(self, wid: int, fault_op: str, *, after: int = 0,
                     when: str = "before") -> None:
        """Arm a deterministic in-worker death (repro.fault posture): the
        worker exits on its ``after``-th next request matching
        ``fault_op`` — ``when='before'`` drops the request mid-op (no
        reply), ``when='after_reply'`` dies between ops."""
        with self._op_lock:
            self._request(self._workers[wid], {
                "op": pr.OP_INJECT, "fault_op": fault_op,
                "after": after, "when": when,
            })

    def worker_stats(self) -> dict[int, dict]:
        """Per-live-worker telemetry (prep/snapshot/wave counters)."""
        with self._op_lock:
            out = {}
            for w in self._live():
                out[w.wid] = self._request(w, {"op": pr.OP_STATS})
            return out

    # -------------------------------------------------------------- append
    def append(self, rows_batch) -> dict:
        """Ingest one batch: register it in the global rank space, place
        it on the least-loaded worker, fold the returned F2 block into
        the global C — the map step runs remotely, the Job 1/F2 reduce
        here."""
        rows = np.array(rows_batch, np.int32, copy=True)
        if rows.ndim != 2:
            raise ValueError(f"rows batch must be 2-D (R, L), got shape {rows.shape}")
        if rows.size and int(rows.max()) >= self.n_items:
            raise ValueError(
                f"batch contains item id {int(rows.max())} >= n_items={self.n_items}"
            )
        t0 = time.perf_counter()
        with self._op_lock:
            hist = enc.item_support(rows, self.n_items)
            new_items = self.db.register_batch(hist)
            self.db.n_rows += len(rows)
            self.stats["appends"] += 1
            self.rows_appended += len(rows)
            source = "empty"
            worker = -1
            seq = self._append_seq
            self._append_seq += 1
            if hist.sum() > 0:
                local_items = self.db.present_in_order(hist)
                seg_id = self._next_seg
                self._next_seg += 1
                m = SegmentMeta(
                    seg_id=seg_id, rows=rows, n_rows_real=len(rows),
                    local_items=local_items, worker=-1, seq=seq,
                )
                wid, rep = self._place_segment(m)
                gr = self.db.rank_of[local_items]
                m.C_block = np.asarray(rep["C"], np.int64)
                self.db.C[np.ix_(gr, gr)] += m.C_block
                m.worker = wid
                m.nbytes = int(rep["nbytes"])
                m.prep_bytes = int(rep["prep_bytes"])
                m.digest = self._padded_digest(rows)
                self._segments[seg_id] = m
                source = rep["source"]
                worker = wid
                self._checkpoint_append(m)
            else:
                self.stats["empty_batches"] += 1
                self._empty_rows.append([seq, len(rows)])
                self._checkpoint_manifest()
            n_exp_seg, n_exp_rows = self._expire()
            diffs = self.standing.refresh_all(
                "expire" if n_exp_rows else "append"
            )
            append_s = time.perf_counter() - t0
            self.engine.telemetry.histogram(
                f"dist.{self.name}.append_s").record(append_s)
            return {
                "rows": int(len(rows)),
                "total_rows": int(self.db.n_rows),
                "segments": len(self._segments),
                "new_items": int(len(new_items)),
                "expired": int(n_exp_seg),
                "expired_rows": int(n_exp_rows),
                "diffs": int(diffs),
                "prep_source": source,
                "worker": worker,
                "append_s": append_s,
            }

    def _expire(self) -> "tuple[int, int]":
        """Sliding-window expiry (lock held): a placement-aware drop over
        the append-order ledger of segments AND segment-less (all-PAD)
        appends. Victims are the oldest entries beyond the minimal suffix
        covering the window; each segment drop subtracts its histogram and
        recorded F2 block from the global reduce (exact retraction), frees
        the device copy on its owning worker (best-effort — a dead owner
        folds into failover), and is recorded in the checkpoint manifest so
        a restore replays expired batches rank-only; an empty-entry drop
        just releases its rows from ``db.n_rows``. An injected
        ``stream.expire`` failure skips the pass; the window self-heals on
        the next append. Returns (segments expired, rows expired)."""
        ss = self.stream_spec
        if not ss.windowed:
            return 0, 0
        by_batches = bool(ss.window_batches)
        # distributed databases never compact: one segment == one batch
        entries = [
            (m.seq, 1 if by_batches else m.n_rows_real, m)
            for m in self._segments.values()
        ] + [
            (q, 1 if by_batches else n, None)
            for q, n in self._empty_rows if n
        ]
        entries.sort(key=lambda e: e[0])
        if len(entries) <= 1:
            return 0, 0
        window = ss.window_batches or ss.window_rows
        total = sum(e[1] for e in entries)
        victims, i = [], 0
        while i < len(entries) - 1 and total - entries[i][1] >= window:
            total -= entries[i][1]
            victims.append(entries[i])
            i += 1
        if not victims:
            return 0, 0
        try:
            failures.fire("stream.expire")
        except Exception:
            self.stats["expire_errors"] += 1
            return 0, 0
        seg_victims = [e[2] for e in victims if e[2] is not None]
        by_worker: dict[int, list[int]] = {}
        for m in seg_victims:
            del self._segments[m.seg_id]
            self._expired.add(m.seg_id)
            gr = self.db.rank_of[m.local_items]
            self.db.C[np.ix_(gr, gr)] -= m.C_block
            self.db.counts -= enc.item_support(m.rows, self.n_items)
            self.db.n_rows -= m.n_rows_real
            by_worker.setdefault(m.worker, []).append(m.seg_id)
        empty_seqs = {e[0] for e in victims if e[2] is None}
        empty_rows = sum(n for q, n in self._empty_rows if q in empty_seqs)
        if empty_seqs:
            self._empty_rows = [
                e for e in self._empty_rows if e[0] not in empty_seqs
            ]
            self.db.n_rows -= empty_rows
        for wid, seg_ids in by_worker.items():
            w = self._workers.get(wid)
            if w is None or not w.alive:
                continue  # its device copies died with it; the log is here
            try:
                self._request(w, {"op": "drop", "seg_ids": seg_ids})
            except WorkerDied as e:
                try:
                    self._failover(e.worker_id)
                except NoLiveWorkers:
                    pass  # surfaced by the next append/mine
        n_rows = sum(m.n_rows_real for m in seg_victims) + empty_rows
        self.stats["expires"] += 1
        self.stats["expired_segments"] += len(seg_victims)
        self.stats["expired_rows"] += n_rows
        self._checkpoint_manifest()
        return len(seg_victims), n_rows

    # ----------------------------------------------------- standing queries
    def register(self, spec: MineSpec):
        """Register a standing query against the distributed database:
        mined now and re-answered (with a ``MineDiff``) after every
        append/expiry — same semantics as ``StreamingMiner.register``."""
        with self._op_lock:
            return self.standing.register(spec)

    def cancel(self, query) -> None:
        with self._op_lock:
            self.standing.cancel(query)

    def _place_segment(self, m: SegmentMeta, prefer: int | None = None):
        """Place (prep) one segment on a live worker: ``(wid, reply)``.
        ``prefer`` pins the first attempt (checkpoint replay honors the
        recorded placement when that worker still exists); deaths fold
        into failover and the placement is retried on the survivors."""
        while True:
            loads = self._loads()
            if not loads:
                raise NoLiveWorkers("no live workers to place the batch on")
            wid = prefer if prefer in loads else placement.choose_worker(loads)
            try:
                return wid, self._prep_on(self._workers[wid], m)
            except WorkerDied as e:
                prefer = None
                self._failover(e.worker_id)

    # ----------------------------------------------------------- checkpoint
    # The coordinator's durable state is tiny and host-only: the append
    # log (each batch's raw rows) plus a manifest (append order, empty-
    # batch row counts, placement map). Everything else — ranks, counts,
    # C, segment N-lists — is deterministically derivable by replaying
    # appends, with the workers' content-addressed snapshot store making
    # the replay a warm restore instead of a recompute. Entry dirs are
    # written with ``write_dir_atomic`` and the manifest with
    # ``replace_file_atomic``, so a crash mid-checkpoint can only lose
    # the latest append, never corrupt the log.
    CK_SCHEMA = 1

    def _ck_entry(self, seg_id: int) -> str:
        return os.path.join(self.checkpoint_dir, f"seg-{int(seg_id):06d}")

    def _checkpoint_append(self, m: SegmentMeta) -> None:
        """Persist one appended batch + the updated manifest. Best-effort:
        a full/readonly disk degrades durability, never the append."""
        if self.checkpoint_dir is None:
            return
        try:
            os.makedirs(self.checkpoint_dir, exist_ok=True)

            def writer(tmp):
                save_array(os.path.join(tmp, "rows.npy"), np.asarray(m.rows, np.int32))
                fsync_write(os.path.join(tmp, "meta.json"), json.dumps({
                    "seg_id": int(m.seg_id), "n_rows_real": int(m.n_rows_real),
                }).encode())

            write_dir_atomic(self._ck_entry(m.seg_id), writer)
        except Exception:
            self.stats["checkpoint_failures"] += 1
            return
        self._checkpoint_manifest()

    def _checkpoint_manifest(self) -> None:
        if self.checkpoint_dir is None:
            return
        try:
            os.makedirs(self.checkpoint_dir, exist_ok=True)
            manifest = {
                "schema": self.CK_SCHEMA,
                "n_items": int(self.n_items),
                "segments": [int(s) for s in sorted(self._segments)],
                "expired": [int(s) for s in sorted(self._expired)],
                "placement": {
                    str(s): int(self._segments[s].worker)
                    for s in sorted(self._segments)
                },
                "seg_seq": {
                    str(s): int(self._segments[s].seq)
                    for s in sorted(self._segments)
                },
                "empty_rows": [
                    [int(q), int(n)] for q, n in self._empty_rows
                ],
            }
            replace_file_atomic(
                os.path.join(self.checkpoint_dir, "manifest.json"),
                json.dumps(manifest, sort_keys=True).encode(),
            )
        except Exception:
            self.stats["checkpoint_failures"] += 1

    def _restore_checkpoint(self) -> None:
        """Replay the append log into this (fresh) coordinator: same batch
        order -> same rank space, counts, C, and seg_ids — an identical
        ``SegmentedDB``. Placement honors the recorded map where those
        worker ids exist, and segment preps restore snapshot-first, so a
        restart of a large database is I/O, not recompute."""
        path = os.path.join(self.checkpoint_dir, "manifest.json")
        try:
            with open(path) as f:
                manifest = json.load(f)
        except OSError:
            os.makedirs(self.checkpoint_dir, exist_ok=True)
            return  # nothing recorded yet: a fresh database
        if manifest.get("schema") != self.CK_SCHEMA:
            raise ValueError(
                f"checkpoint schema {manifest.get('schema')!r} unsupported"
            )
        if int(manifest.get("n_items", -1)) != self.n_items:
            raise ValueError(
                f"checkpoint was written for n_items={manifest.get('n_items')}, "
                f"this coordinator has n_items={self.n_items}"
            )
        placed = {int(k): int(v) for k, v in manifest.get("placement", {}).items()}
        seqs = {int(k): int(v) for k, v in manifest.get("seg_seq", {}).items()}
        expired = {int(s) for s in manifest.get("expired", [])}
        live = {int(s) for s in manifest.get("segments", [])}
        with self._op_lock:
            for seg_id in sorted(live | expired):
                rows = np.load(os.path.join(self._ck_entry(seg_id), "rows.npy"))
                if seg_id in expired:
                    self._replay_expired(seg_id, rows)
                else:
                    self._replay_append(
                        seg_id, rows, prefer=placed.get(seg_id),
                        seq=seqs.get(seg_id),
                    )
                self.stats["restored_appends"] += 1
            for entry in manifest.get("empty_rows", []):
                q, n = int(entry[0]), int(entry[1])
                self.db.n_rows += n
                self._empty_rows.append([q, n])
                self._append_seq = max(self._append_seq, q + 1)
                self.stats["appends"] += 1
                self.stats["empty_batches"] += 1
                self.stats["restored_appends"] += 1

    def _replay_append(self, seg_id: int, rows: np.ndarray,
                       prefer: int | None, seq: int | None = None) -> None:
        """One checkpointed append, re-registered and re-placed — the body
        of ``append`` minus validation (the original append did it) and
        minus re-checkpointing what is already on disk."""
        hist = enc.item_support(rows, self.n_items)
        self.db.register_batch(hist)
        self.db.n_rows += len(rows)
        self.stats["appends"] += 1
        self.rows_appended += len(rows)
        local_items = self.db.present_in_order(hist)
        self._next_seg = max(self._next_seg, seg_id + 1)
        if seq is None:
            seq = self._append_seq
        self._append_seq = max(self._append_seq, seq + 1)
        m = SegmentMeta(
            seg_id=seg_id, rows=rows, n_rows_real=len(rows),
            local_items=local_items, worker=-1, seq=seq,
        )
        wid, rep = self._place_segment(m, prefer=prefer)
        gr = self.db.rank_of[local_items]
        m.C_block = np.asarray(rep["C"], np.int64)
        self.db.C[np.ix_(gr, gr)] += m.C_block
        m.worker = wid
        m.nbytes = int(rep["nbytes"])
        m.prep_bytes = int(rep["prep_bytes"])
        m.digest = self._padded_digest(rows)
        self._segments[seg_id] = m

    def _replay_expired(self, seg_id: int, rows: np.ndarray) -> None:
        """One checkpointed append that later expired: replayed rank-only.
        The original append registered the batch's items (extending the
        append-only rank space) and its later expiry subtracted the
        histogram back out — so the replay registers then subtracts,
        reconstructing identical ranks with net-zero counts, and never
        places anything on a worker."""
        hist = enc.item_support(rows, self.n_items)
        self.db.register_batch(hist)
        self.db.counts -= hist
        self.stats["appends"] += 1
        self.rows_appended += len(rows)
        self._next_seg = max(self._next_seg, seg_id + 1)
        self._expired.add(seg_id)

    def _padded_digest(self, rows: np.ndarray) -> str:
        pad = self.stream_spec.row_pad
        rp = -(-len(rows) // pad) * pad
        if rp != len(rows):
            padded = np.full((rp, rows.shape[1]), enc.PAD, np.int32)
            padded[: len(rows)] = rows
            rows = padded
        return _digest(rows)[2]

    # --------------------------------------------------------------- query
    def mine(self, spec: MineSpec, _seed: dict | None = None,
             _seed_out: dict | None = None) -> MineResult:
        """One exact query: plan centrally, execute waves on the workers,
        sum supports, threshold. A worker death mid-query triggers
        failover and a full replay — planning is deterministic, so the
        replayed query answers bit-identically."""
        if spec.algorithm != "hprepost":
            raise ValueError(
                f"distributed queries run on the hprepost backend, got {spec.algorithm!r}"
            )
        # only prep-level knobs are pinned by the packed segments;
        # execution-only knobs (blocks, backend, early_stop, tune) are free
        # to differ per query and are honored via the query's own miner
        if self._fe._prep_config(spec) != self._device_cfg.prep_key():
            raise ValueError(
                "query device config differs from the database's; segments were "
                "packed under the creation spec — open a new database to change knobs"
            )
        self._fe._check_patterns(spec)
        t0 = time.perf_counter()
        with self._op_lock:
            while True:
                try:
                    out = self._mine_once(spec, t0, _seed, _seed_out)
                except WorkerDied as e:
                    self._failover(e.worker_id)
                    self.stats["query_retries"] += 1
                    continue
                self.engine.telemetry.histogram(
                    f"dist.{self.name}.query_s").record(time.perf_counter() - t0)
                return out

    def _mine_once(self, spec: MineSpec, t0: float,
                   seed: dict | None = None,
                   seed_out: dict | None = None) -> MineResult:
        items = np.asarray(self.db.order, np.int32)
        sups = self.db.counts[items] if len(items) else np.zeros(0, np.int64)
        C = self.db.C.copy()
        n_rows = self.db.n_rows
        min_count = spec.resolve(max(n_rows, 1))
        if len(items) > spec.max_f1:
            raise ValueError(
                f"|stream F-list|={len(items)} exceeds max_f1={spec.max_f1}"
            )
        executor = RemoteSegmentExecutor(self, items)
        qminer = self._fe.miner_for(spec)  # honors execution-only knobs
        res = qminer.mine_prepared_segments(
            None, items, sups, C, min_count, max_k=spec.max_k,
            peak_base=sum(m.prep_bytes for m in self._segments.values()),
            executor=executor, seed=seed, seed_out=seed_out,
        )
        executor.finish()
        self.stats["queries"] += 1
        out = self._fe._finish(
            res.itemsets, res.total_count, res.n_explicit, res.peak_bytes,
            dict(qminer.last_stage_times), res.flist_items,
            spec=spec, min_count=min_count, n_rows=n_rows, t0=t0, prep_shared=True,
        )
        out.service_stats.update(
            prep_source="distributed",
            stream_segments=len(self._segments),
            stream_digest=self._db_digest(),
            workers=len(self._live()),
        )
        return out

    def _db_digest(self) -> str:
        h = hashlib.sha1()
        for sid in sorted(self._segments):
            h.update(self._segments[sid].digest.encode())
        h.update(str(self.db.n_rows).encode())
        return h.hexdigest()

    # ------------------------------------------------------------ heartbeat
    def _monitor_loop(self) -> None:
        """Ping live workers every ``heartbeat_s``; a missed beat retires
        the worker and re-places its segments. Skips a cycle whenever an
        operation holds the lock — a busy worker is not a dead worker."""
        while not self._stop.wait(self.heartbeat_s):
            if not self._op_lock.acquire(blocking=False):
                continue
            try:
                for w in list(self._live()):
                    try:
                        self._request(
                            w, {"op": pr.OP_PING},
                            timeout=max(self.heartbeat_s * 4, 2.0),
                        )
                    except WorkerDied as e:
                        try:
                            self._failover(e.worker_id)
                        except NoLiveWorkers:
                            pass  # surfaced by the next append/mine
            finally:
                self._op_lock.release()

    def flush(self) -> None:  # StreamingMiner surface parity (no-op here)
        return None
