"""Segment -> worker placement: byte-balanced greedy bin-packing.

The coordinator calls ``choose_worker`` per append (place the new segment
on the least-loaded live worker) and ``replan`` on topology change (a
worker died: redistribute its segments over the survivors, best-fit
decreasing, so the heaviest orphan lands on the emptiest node first).
Pure host arithmetic — no sockets, no device state — so the policy is
unit-testable in isolation.
"""
from __future__ import annotations


def choose_worker(loads: dict[int, int]) -> int:
    """Worker id with the fewest placed bytes (ties: lowest id —
    deterministic placement makes failures replayable)."""
    if not loads:
        raise ValueError("no live workers to place on")
    return min(loads, key=lambda w: (loads[w], w))


def replan(lost: list[tuple[int, int]], loads: dict[int, int]) -> dict[int, int]:
    """Re-home orphaned segments: ``lost`` is ``[(seg_id, nbytes), ...]``,
    ``loads`` the survivors' current placed bytes. Best-fit decreasing:
    heaviest segment first, each onto the currently lightest survivor.
    Returns ``{seg_id: worker_id}``; ``loads`` is updated in place so
    successive calls compose."""
    if not loads:
        raise ValueError("no live workers to replan onto")
    plan: dict[int, int] = {}
    for seg_id, nbytes in sorted(lost, key=lambda t: (-t[1], t[0])):
        w = choose_worker(loads)
        plan[seg_id] = w
        loads[w] += int(nbytes)
    return plan
